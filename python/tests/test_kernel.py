"""Kernel-vs-ref correctness: the CORE numerics signal of the repo.

Hypothesis sweeps shapes/dtypes of the Pallas kernels against the pure-jnp
oracles in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, saa
from compile.kernels.matmul import matmul, matmul_pallas, vmem_bytes, mxu_utilization

jax.config.update("jax_enable_x64", False)

dims = st.integers(min_value=1, max_value=96)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32).astype(dtype)


# ---------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_shapes(m, k, n, seed):
    x = rand(seed, (m, k))
    y = rand(seed + 1, (k, n))
    got = matmul_pallas(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40), k=st.integers(1, 40), n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bf16_inputs(m, k, n, seed):
    x = rand(seed, (m, k), jnp.bfloat16)
    y = rand(seed + 1, (k, n), jnp.bfloat16)
    got = matmul_pallas(x.astype(jnp.float32), y.astype(jnp.float32))
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("block", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
def test_matmul_blocks_tile_and_accumulate(block):
    # shapes forcing multi-step K accumulation and padding
    x = rand(7, (33, 70))
    y = rand(8, (70, 17))
    got = matmul_pallas(x, y, block=block)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)


def test_matmul_grad_matches_jnp_grad():
    x = rand(1, (6, 10))
    y = rand(2, (10, 3))

    def f_pallas(x, y):
        return jnp.sum(jnp.sin(matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(ref.matmul_ref(x, y)))

    gx_p, gy_p = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gy_p, gy_r, rtol=1e-5, atol=1e-5)


def test_matmul_vmem_estimate_under_budget():
    # Default block must fit VMEM (16 MiB) with double buffering headroom.
    assert vmem_bytes() * 2 < 16 * 1024 * 1024
    assert 0.0 < mxu_utilization() <= 1.0
    assert mxu_utilization((128, 128, 128)) == 1.0
    assert mxu_utilization((64, 128, 128)) == 0.5


# ---------------------------------------------------------------- saa


@settings(max_examples=25, deadline=None)
@given(u=st.integers(1, 16), p=st.integers(1, 500), seed=st.integers(0, 2**31 - 1))
def test_weighted_agg_matches_ref(u, p, seed):
    upd = rand(seed, (u, p))
    w = rand(seed + 1, (u,))
    got = saa.weighted_agg(upd, w)
    want = ref.weighted_agg_ref(upd, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(s=st.integers(1, 16), p=st.integers(1, 500), seed=st.integers(0, 2**31 - 1))
def test_deviation_matches_ref(s, p, seed):
    f = rand(seed, (p,))
    stale = rand(seed + 1, (s, p))
    got = saa.deviation(f, stale)
    want = ref.deviation_ref(f, stale)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bp", [8, 64, 4096])
def test_deviation_block_sweep(bp):
    f = rand(3, (1000,))
    stale = rand(4, (5, 1000))
    got = saa.deviation(f, stale, bp=bp)
    np.testing.assert_allclose(got, ref.deviation_ref(f, stale), rtol=1e-4, atol=1e-4)


def test_weighted_agg_zero_weight_rows_are_inert():
    # Padding rows with w=0 must not change the aggregate (static-shape AOT).
    upd = rand(5, (8, 100))
    w = jnp.array([0.5, 0.5, 0, 0, 0, 0, 0, 0], jnp.float32)
    got = saa.weighted_agg(upd, w)
    want = 0.5 * upd[0] + 0.5 * upd[1]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_lambda_matches_paper_formula():
    # Lambda_s = ||f - (u_s + nF f)/(nF+1)||^2 / ||f||^2 (paper 4.2.4)
    f = rand(9, (50,))
    stale = rand(10, (3, 50))
    nf = 4.0
    lam = ref.lambda_ref(f, stale, nf)
    for s in range(3):
        direct = jnp.sum((f - (stale[s] + nf * f) / (nf + 1.0)) ** 2) / jnp.sum(f * f)
        np.testing.assert_allclose(lam[s], direct, rtol=1e-5)


def test_relay_weights_eq2_properties():
    taus = jnp.array([0.0, 1.0, 5.0])
    lams = jnp.array([0.1, 0.5, 1.0])
    beta = 0.35
    w = ref.relay_weights_ref(taus, lams, beta)
    # fresher -> larger staleness term; max-deviation stale gets full boost
    assert w[0] > w[2] - beta  # staleness component decays
    # all weights within (0, 1]
    assert jnp.all(w > 0) and jnp.all(w <= 1.0 + 1e-6)
    # beta=0 reduces to DynSGD
    w0 = ref.relay_weights_ref(taus, lams, 0.0)
    np.testing.assert_allclose(w0, 1.0 / (taus + 1.0), rtol=1e-6)
