"""AOT pipeline tests: lowering emits valid HLO text + a coherent manifest."""

import json
import os
import tempfile

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def tiny_artifacts():
    d = tempfile.mkdtemp(prefix="relay_aot_test_")
    entries = []
    aot.lower_variant(M.VARIANTS["tiny"], d, entries)
    return d, entries


def test_all_computations_emitted(tiny_artifacts):
    d, entries = tiny_artifacts
    names = {e["computation"] for e in entries}
    assert names == {"train", "eval", "init", "agg", "dev"}
    for e in entries:
        assert os.path.exists(os.path.join(d, e["file"]))


def test_hlo_text_is_parsable_module(tiny_artifacts):
    d, entries = tiny_artifacts
    for e in entries:
        text = open(os.path.join(d, e["file"])).read()
        assert text.startswith("HloModule"), e["file"]
        assert "ENTRY" in text
        # the interchange contract: text, never a serialized proto
        assert "\x00" not in text


def test_train_arg_shapes_match_variant(tiny_artifacts):
    _, entries = tiny_artifacts
    v = M.VARIANTS["tiny"]
    train = next(e for e in entries if e["computation"] == "train")
    assert train["arg_shapes"] == [
        [v.num_params],
        [v.batch, v.input_dim],
        [v.batch],
        [v.batch],
        [],
    ]
    assert train["arg_dtypes"][2] == "int32"


def test_agg_shapes_are_padded_static(tiny_artifacts):
    _, entries = tiny_artifacts
    v = M.VARIANTS["tiny"]
    agg = next(e for e in entries if e["computation"] == "agg")
    assert agg["arg_shapes"] == [[v.max_updates, v.num_params], [v.max_updates]]


def test_sha256_stable_across_lowerings():
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    e1, e2 = [], []
    aot.lower_variant(M.VARIANTS["tiny"], d1, e1)
    aot.lower_variant(M.VARIANTS["tiny"], d2, e2)
    assert [e["sha256"] for e in e1] == [e["sha256"] for e in e2]


def test_repo_manifest_consistent_if_built():
    """If `make artifacts` ran, the manifest must match the model registry."""
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    man = json.load(open(path))
    for name, info in man["variants"].items():
        v = M.VARIANTS[name]
        assert info["num_params"] == v.num_params
        assert info["batch"] == v.batch
        assert info["max_updates"] == v.max_updates
