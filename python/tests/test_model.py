"""L2 model tests: shapes, gradient correctness, training signal, packing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

V = M.VARIANTS["tiny"]


def batch(v, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (v.batch, v.input_dim))
    y = jax.random.randint(k2, (v.batch,), 0, v.num_classes)
    mask = jnp.ones((v.batch,))
    return x, y, mask


def test_variant_param_counts():
    # hand-check tiny: 16*8+8 + 8*4+4 = 136 + 36 = 172
    assert V.num_params == 172
    for v in M.VARIANTS.values():
        assert v.num_params == sum(i * o + o for i, o in v.layer_shapes)


def test_pack_unpack_roundtrip():
    flat = M.init_params(V)(0)
    assert flat.shape == (V.num_params,)
    repacked = M.pack(M.unpack(V, flat))
    np.testing.assert_array_equal(flat, repacked)


def test_forward_shape_and_finite():
    flat = M.init_params(V)(1)
    x, _, _ = batch(V)
    logits = M.forward(V, flat, x)
    assert logits.shape == (V.batch, V.num_classes)
    assert jnp.all(jnp.isfinite(logits))


def test_train_step_gradient_matches_numerical():
    """Finite-difference check of the full fwd/bwd through the Pallas matmul."""
    flat = M.init_params(V)(2)
    x, y, mask = batch(V, 3)

    def loss_of(p):
        logits = M.forward(V, p, x)
        loss, _ = M.masked_ce(logits, y, mask)
        return loss

    g = jax.grad(loss_of)(flat)
    eps = 1e-3
    rng = np.random.default_rng(0)
    for idx in rng.choice(V.num_params, size=8, replace=False):
        e = jnp.zeros_like(flat).at[idx].set(eps)
        num = (loss_of(flat + e) - loss_of(flat - e)) / (2 * eps)
        np.testing.assert_allclose(g[idx], num, rtol=5e-2, atol=5e-3)


def test_train_step_descends():
    step = M.train_step(V)
    flat = M.init_params(V)(4)
    x, y, mask = batch(V, 5)
    losses = []
    for _ in range(30):
        flat, loss, _ = step(flat, x, y, mask, jnp.float32(0.1))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, f"no learning: {losses[0]} -> {losses[-1]}"


def test_train_step_mask_excludes_padding():
    step = M.train_step(V)
    flat = M.init_params(V)(6)
    x, y, _ = batch(V, 7)
    full = jnp.ones((V.batch,))
    # Corrupt the masked-out row wildly; results must be identical.
    part = full.at[-1].set(0.0)
    x2 = x.at[-1].set(1e3)
    p1, l1, c1 = step(flat, x, y, part, jnp.float32(0.05))
    p2, l2, c2 = step(flat, x2, y, part, jnp.float32(0.05))
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)


def test_eval_batch_counts():
    ev = M.eval_batch(V)
    flat = M.init_params(V)(8)
    x, y, mask = batch(V, 9)
    sum_loss, correct = ev(flat, x, y, mask)
    assert 0 <= float(correct) <= V.batch
    assert float(sum_loss) > 0


def test_eval_perfect_model_gets_all_correct():
    # train to (near) memorization on one batch, then eval it
    step = M.train_step(V)
    ev = M.eval_batch(V)
    flat = M.init_params(V)(10)
    x, y, mask = batch(V, 11)
    for _ in range(300):
        flat, loss, _ = step(flat, x, y, mask, jnp.float32(0.2))
    _, correct = ev(flat, x, y, mask)
    assert float(correct) >= V.batch - 1


def test_init_deterministic_per_seed():
    i = M.init_params(V)
    np.testing.assert_array_equal(i(42), i(42))
    assert not np.array_equal(np.asarray(i(1)), np.asarray(i(2)))


@pytest.mark.parametrize("name", list(M.VARIANTS))
def test_every_variant_one_step(name):
    v = M.VARIANTS[name]
    step = M.train_step(v)
    flat = M.init_params(v)(0)
    x, y, mask = batch(v, 1)
    flat2, loss, correct = step(flat, x, y, mask, jnp.float32(0.01))
    assert flat2.shape == (v.num_params,)
    assert jnp.isfinite(loss)
    assert float(correct) <= v.batch
