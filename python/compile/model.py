"""L2: the federated model, as pure JAX over a FLAT parameter vector.

The rust coordinator (L3) only ever sees ``f32[P]`` parameter/update vectors
plus fixed-shape batches, which keeps the AOT interface static and makes the
aggregation path (L1 ``saa`` kernels) shape-trivial. All dense layers go
through the L1 Pallas ``matmul`` kernel so the training FLOP hot-spot lowers
into the same HLO module.

Exported computations (per benchmark variant, see ``VARIANTS``):

* ``train_step(params, x, y, mask, lr)`` -> (params', loss, correct)
    one masked-SGD step (forward, softmax-CE, backward, update).
* ``eval_batch(params, x, y, mask)``     -> (sum_loss, correct)
* ``init_params(seed)``                  -> params (layer-scaled normal init)
* ``agg_combine(updates[U,P], w[U])``    -> weighted sum      (L1 kernel)
* ``agg_dev(fresh[P], stale[U,P])``      -> distances + norm  (L1 kernel)
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.matmul import matmul
from compile.kernels import saa


@dataclass(frozen=True)
class Variant:
    """One benchmark model configuration (mirrors paper Table 1 scales)."""

    name: str
    input_dim: int
    num_classes: int
    hidden: Tuple[int, ...]
    batch: int
    # Max update rows the aggregation kernels accept (padded; static shape).
    max_updates: int = 32
    # Perplexity-style task (NLP benchmarks report test perplexity).
    perplexity: bool = False

    @property
    def layer_shapes(self) -> List[Tuple[int, int]]:
        dims = (self.input_dim, *self.hidden, self.num_classes)
        return [(dims[i], dims[i + 1]) for i in range(len(dims) - 1)]

    @property
    def num_params(self) -> int:
        return sum(i * o + o for i, o in self.layer_shapes)


# Stand-ins for the paper's five benchmarks (Table 1), scaled for a CPU
# testbed. DESIGN.md 2 records the substitution.
VARIANTS = {
    "tiny": Variant("tiny", 16, 4, (8,), 4, max_updates=8),
    "speech": Variant("speech", 256, 35, (128, 64), 20),
    "cifar": Variant("cifar", 256, 10, (128, 64), 10),
    "openimage": Variant("openimage", 256, 60, (128, 64), 30),
    "nlp": Variant("nlp", 128, 64, (128,), 40, perplexity=True),
}


def unpack(v: Variant, flat):
    """Split flat f32[P] into [(W, b), ...]."""
    layers, off = [], 0
    for i, o in v.layer_shapes:
        w = flat[off : off + i * o].reshape(i, o)
        off += i * o
        b = flat[off : off + o]
        off += o
        layers.append((w, b))
    return layers


def pack(layers):
    return jnp.concatenate([jnp.concatenate([w.reshape(-1), b]) for w, b in layers])


def forward(v: Variant, flat, x):
    """MLP forward: relu hidden layers, linear head. Uses the L1 matmul."""
    layers = unpack(v, flat)
    h = x
    for li, (w, b) in enumerate(layers):
        h = matmul(h, w) + b
        if li + 1 < len(layers):
            h = jax.nn.relu(h)
    return h  # logits (B, C)


def masked_ce(logits, y, mask):
    """Mean masked softmax cross-entropy, and #correct (masked)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y) * mask)
    return loss, correct


def train_step(v: Variant):
    def step(flat, x, y, mask, lr):
        def loss_fn(p):
            logits = forward(v, p, x)
            loss, correct = masked_ce(logits, y, mask)
            return loss, correct

        (loss, correct), g = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        return flat - lr * g, loss, correct

    return step


def eval_batch(v: Variant):
    def ev(flat, x, y, mask):
        logits = forward(v, flat, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        sum_loss = jnp.sum(nll * mask)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y) * mask)
        return sum_loss, correct

    return ev


def init_params(v: Variant):
    def init(seed):
        key = jax.random.PRNGKey(seed)
        parts = []
        for i, o in v.layer_shapes:
            key, k1 = jax.random.split(key)
            scale = jnp.sqrt(2.0 / i)  # He init for relu stacks
            parts.append((jax.random.normal(k1, (i, o)) * scale, jnp.zeros(o)))
        return pack(parts)

    return init


def agg_combine(v: Variant):
    def combine(updates, weights):
        return saa.weighted_agg(updates, weights)

    return combine


def agg_dev(v: Variant):
    def dev(fresh_avg, stale):
        return saa.deviation(fresh_avg, stale)

    return dev
