"""AOT: lower every (variant x computation) to HLO *text* + manifest.json.

HLO text -- NOT ``lowered.compiler_ir('hlo')`` protos or ``.serialize()`` --
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the rust
``xla`` 0.1.6 crate) rejects (``proto.id() <= INT_MAX``). The HLO text
parser on the rust side reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
The Makefile `artifacts` target drives this; rust never imports python.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def computations(v: M.Variant):
    """(name, fn, example_args) for every export of one variant."""
    P, B, D, U = v.num_params, v.batch, v.input_dim, v.max_updates
    return [
        ("train", M.train_step(v), (f32(P), f32(B, D), i32(B), f32(B), f32())),
        ("eval", M.eval_batch(v), (f32(P), f32(B, D), i32(B), f32(B))),
        ("init", M.init_params(v), (i32(),)),
        ("agg", M.agg_combine(v), (f32(U, P), f32(U))),
        ("dev", M.agg_dev(v), (f32(P), f32(U, P))),
    ]


def lower_variant(v: M.Variant, out_dir: str, entries: list):
    for name, fn, args in computations(v):
        path = os.path.join(out_dir, f"{v.name}_{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "variant": v.name,
                "computation": name,
                "file": os.path.basename(path),
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "arg_shapes": [list(a.shape) for a in args],
                "arg_dtypes": [str(a.dtype) for a in args],
            }
        )
        print(f"  {path}  ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="all", help="comma list or 'all'")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = (
        list(M.VARIANTS) if args.variants == "all" else args.variants.split(",")
    )
    entries = []
    for n in names:
        v = M.VARIANTS[n]
        print(f"variant {n}: P={v.num_params} B={v.batch} D={v.input_dim} "
              f"C={v.num_classes} U={v.max_updates}")
        lower_variant(v, args.out_dir, entries)

    manifest = {
        "format": "hlo-text-v1",
        "variants": {
            n: {
                "num_params": M.VARIANTS[n].num_params,
                "input_dim": M.VARIANTS[n].input_dim,
                "num_classes": M.VARIANTS[n].num_classes,
                "hidden": list(M.VARIANTS[n].hidden),
                "batch": M.VARIANTS[n].batch,
                "max_updates": M.VARIANTS[n].max_updates,
                "perplexity": M.VARIANTS[n].perplexity,
            }
            for n in names
        },
        "computations": entries,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
