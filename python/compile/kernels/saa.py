"""L1 Pallas kernels for Staleness-Aware Aggregation (paper Eq. 2, 4.2.4).

Two kernels, both gridded over the parameter dimension so update matrices
stream HBM->VMEM in row blocks (the TPU analogue of the server's streaming
aggregation loop):

* ``weighted_agg`` -- given up to ``U`` stacked update vectors and one weight
  per update, produce the weighted sum ``sum_i w_i * u_i``. The rust
  coordinator pre-normalizes weights (fresh w=1, stale w from Eq. 2) and
  zero-pads unused rows, so shapes stay static for AOT.

* ``deviation`` -- given the fresh-update average ``f`` and stacked stale
  updates, produce per-stale squared L2 distances ``||f - u_s||^2`` plus
  ``||f||^2`` (last output slot), from which the coordinator computes
  Lambda_s = ||f - u_s||^2 / ((n_F + 1)^2 ||f||^2)   (paper 4.2.4).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Parameter-dimension block: one row-block of the update matrix.
#
# TPU tiling would use 4096 (VMEM-sized blocks, streamed HBM->VMEM by the
# grid). For the CPU-PJRT artifacts we use a block large enough to cover
# the whole parameter vector of every variant in ONE grid step: XLA-CPU
# executes interpret-mode grid loops via while+dynamic-slice, which costs
# ~4 ms/step on 10 MB operands (measured; EXPERIMENTS.md Perf), so grid=1
# turns the server merge from ~40 ms into a single fused dot. The tiled
# path (small bp) stays covered by the pytest block sweeps.
DEFAULT_BP = 65536
TPU_BP = 4096


def _ceil_to(a: int, b: int) -> int:
    return -(-a // b) * b


def _weighted_agg_kernel(w_ref, u_ref, o_ref):
    # (1, U) @ (U, bp) -> (1, bp): the weight row times one column block.
    o_ref[...] = jnp.dot(
        w_ref[...], u_ref[...], preferred_element_type=jnp.float32
    )


def weighted_agg(updates, weights, *, bp=DEFAULT_BP, interpret=True):
    """``sum_i weights[i] * updates[i]`` -> shape (P,).

    updates: (U, P) f32, weights: (U,) f32.
    """
    u, p = updates.shape
    bp = min(bp, _ceil_to(p, 8))
    pp = _ceil_to(p, bp)
    up = jnp.pad(updates, ((0, 0), (0, pp - p))) if pp != p else updates
    w2 = weights.reshape(1, u)
    out = pl.pallas_call(
        _weighted_agg_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((1, u), lambda i: (0, 0)),
            pl.BlockSpec((u, bp), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pp), jnp.float32),
        interpret=interpret,
    )(w2, up)
    return out[0, :p]


def _deviation_kernel(f_ref, s_ref, o_ref, *, np_blocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    f = f_ref[...]  # (1, bp)
    s = s_ref[...]  # (S, bp)
    d = f - s  # broadcast over rows
    # per-stale squared distance contribution of this column block
    dist = jnp.sum(d * d, axis=1)  # (S,)
    fnorm = jnp.sum(f * f)  # scalar
    o_ref[...] += jnp.concatenate([dist, fnorm[None]]).reshape(1, -1)


def deviation(fresh_avg, stale, *, bp=DEFAULT_BP, interpret=True):
    """Squared distances ``||f - u_s||^2`` for each stale row, and ``||f||^2``.

    fresh_avg: (P,) f32, stale: (S, P) f32.
    Returns (S+1,): first S entries are distances, last is ||f||^2.
    """
    s, p = stale.shape
    bp = min(bp, _ceil_to(p, 8))
    pp = _ceil_to(p, bp)
    fp = jnp.pad(fresh_avg, (0, pp - p)).reshape(1, pp) if pp != p else fresh_avg.reshape(1, p)
    sp = jnp.pad(stale, ((0, 0), (0, pp - p))) if pp != p else stale
    out = pl.pallas_call(
        functools.partial(_deviation_kernel, np_blocks=pp // bp),
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((1, bp), lambda i: (0, i)),
            pl.BlockSpec((s, bp), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, s + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, s + 1), jnp.float32),
        interpret=interpret,
    )(fp, sp)
    return out[0]


def vmem_bytes(u: int, bp: int = DEFAULT_BP, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one weighted_agg grid step."""
    return dtype_bytes * (u + u * bp + bp)
