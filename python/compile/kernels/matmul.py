"""L1 Pallas kernel: tiled matmul (the FLOP hot-spot of local training).

TPU-idiomatic tiling: blocks are multiples of the (8, 128) f32 VREG tile and
sized so the A-, B- and accumulator-blocks fit the ~16 MiB VMEM budget while
feeding the 128x128 MXU. On this CPU testbed the kernel is lowered with
``interpret=True`` so it becomes plain HLO (runnable by the rust PJRT CPU
client); the BlockSpec structure is what carries to real TPU.

Autodiff: ``pallas_call`` is not differentiable, so ``matmul`` carries a
``custom_vjp`` whose backward pass reuses the same kernel
(dx = g @ W^T, dW = x^T @ g) -- the production pattern.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shape: (128, 128, 128) covers the MXU and keeps
# 3 * 128*128*4 B = 192 KiB in VMEM -- far under budget, leaving room for
# double-buffering by the pipeline emitter.
DEFAULT_BLOCK = (128, 128, 128)


def _matmul_kernel_single(x_ref, y_ref, o_ref):
    """K fits in one block: no accumulator needed."""
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_kernel_acc(x_ref, y_ref, o_ref, *, nk: int):
    """Grid dim 2 walks K; o_ref block is revisited and accumulated."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(a: int, b: int) -> int:
    return -(-a // b) * b


def matmul_pallas(x, y, *, block=DEFAULT_BLOCK, interpret=True):
    """``x @ y`` via the tiled Pallas kernel. Pads to block multiples."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    bm, bk, bn = block
    bm, bk, bn = min(bm, _ceil_to(m, 8)), min(bk, _ceil_to(k, 8)), min(
        bn, _ceil_to(n, 8)
    )
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else y

    nk = kp // bk
    if nk == 1:
        out = pl.pallas_call(
            _matmul_kernel_single,
            grid=(mp // bm, np_ // bn),
            in_specs=[
                pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
                pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=interpret,
        )(xp, yp)
    else:
        out = pl.pallas_call(
            functools.partial(_matmul_kernel_acc, nk=nk),
            grid=(mp // bm, np_ // bn, nk),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            interpret=interpret,
        )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, y):
    """Differentiable Pallas matmul used by every dense layer in L2."""
    return matmul_pallas(x, y)


def _matmul_fwd(x, y):
    return matmul_pallas(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    dx = matmul_pallas(g, y.T)
    dy = matmul_pallas(x.T, g)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(block=DEFAULT_BLOCK, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (A+B+O blocks)."""
    bm, bk, bn = block
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(block=DEFAULT_BLOCK) -> float:
    """Fraction of the 128x128 MXU fed by one block-matmul step."""
    bm, _, bn = block
    return min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
