"""Pure-jnp oracles for the L1 Pallas kernels (the correctness anchor)."""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain jnp matmul in f32 accumulation."""
    return jnp.dot(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def weighted_agg_ref(updates, weights):
    """sum_i weights[i] * updates[i] -> (P,)."""
    return jnp.einsum("u,up->p", weights, updates)


def deviation_ref(fresh_avg, stale):
    """(S+1,): ||f - u_s||^2 per stale row, then ||f||^2."""
    d = fresh_avg[None, :] - stale
    dist = jnp.sum(d * d, axis=1)
    fnorm = jnp.sum(fresh_avg * fresh_avg)
    return jnp.concatenate([dist, fnorm[None]])


def lambda_ref(fresh_avg, stale, n_fresh):
    """Paper 4.2.4: Lambda_s = ||f - (u_s + nF f)/(nF+1)||^2 / ||f||^2.

    Algebraically ||f - u_s||^2 / ((nF+1)^2 ||f||^2).
    """
    dev = deviation_ref(fresh_avg, stale)
    dist, fnorm = dev[:-1], dev[-1]
    return dist / ((n_fresh + 1.0) ** 2 * jnp.maximum(fnorm, 1e-12))


def relay_weights_ref(taus, lambdas, beta):
    """Eq. 2: w_s = (1-beta)/(tau_s+1) + beta*(1 - exp(-Lambda_s/Lambda_max))."""
    lam_max = jnp.maximum(jnp.max(lambdas), 1e-12)
    return (1.0 - beta) / (taus + 1.0) + beta * (1.0 - jnp.exp(-lambdas / lam_max))
