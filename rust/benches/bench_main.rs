//! `cargo bench` — the performance harness (hand-rolled; criterion is
//! unavailable offline). Covers the paper's hot paths end-to-end:
//!
//! * runtime: PJRT vs native train-step / eval / aggregation kernels
//! * SAA merge at realistic update counts (the per-round server hot path)
//! * the discrete-event kernel (schedule/drain under heavy time ties)
//! * selectors at 1k/10k/100k checked-in learners
//! * availability trace queries + forecaster probes (per check-in cost)
//! * one full coordinator round (the paper's end-to-end unit) and a
//!   buffered-async run (per-departure selection + K-arrival merges)
//! * lazy 100k-learner construction + the sweep engine at 1 vs N workers
//!
//! Results feed EXPERIMENTS.md §Perf.

use std::sync::Arc;
use std::time::Duration;

use relay::aggregation::saa::{merge, UpdateEntry};
use relay::aggregation::scaling::ScalingRule;
use relay::config::{preset, AvailMode, ExpConfig, RoundMode};
use relay::coordinator::Coordinator;
use relay::data::partition::PartitionScheme;
use relay::forecast::SeasonalForecaster;
use relay::population::{AvailabilityIndex, CandidateSet};
use relay::runtime::{builtin_variant, Executor, NativeExecutor};
use relay::selection::index::ScoreIndex;
use relay::selection::{
    Candidate, ProbeSource, RoundFeedback, SelectPool, SelectionCtx, SlotSig,
};
use relay::sim::{Availability, EventClass, EventKernel};
use relay::sweep::{run_grid, GridSpec, SweepOpts};
use relay::trace::{LazyTraceSet, TraceConfig, TraceSet};
use relay::util::bench;
use relay::util::rng::Rng;
use relay::util::threadpool;

fn pjrt_speech() -> Option<Arc<dyn Executor>> {
    relay::runtime::load_executor("artifacts", "speech", relay::runtime::Backend::Pjrt).ok()
}

fn native_speech() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("speech")))
}

fn batch(exec: &dyn Executor, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<i32>, Vec<f32>) {
    let v = exec.variant();
    let mut rng = Rng::new(seed);
    let params = exec.init_params(seed as i32).unwrap();
    let x: Vec<f32> = (0..v.batch * v.input_dim).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..v.batch).map(|_| rng.below(v.num_classes) as i32).collect();
    (params, x, y, vec![1.0; v.batch])
}

fn bench_runtime() {
    println!("\n== runtime: model math (speech variant, P={}) ==", builtin_variant("speech").num_params);
    let native = native_speech();
    let (p, x, y, m) = batch(native.as_ref(), 1);
    bench::run("train_step/native", || {
        native.train_step(&p, &x, &y, &m, 0.05).unwrap();
    });
    bench::run("eval_batch/native", || {
        native.eval_batch(&p, &x, &y, &m).unwrap();
    });
    if let Some(pjrt) = pjrt_speech() {
        bench::run("train_step/pjrt", || {
            pjrt.train_step(&p, &x, &y, &m, 0.05).unwrap();
        });
        bench::run("eval_batch/pjrt", || {
            pjrt.eval_batch(&p, &x, &y, &m).unwrap();
        });
    } else {
        println!("(pjrt skipped: run `make artifacts`)");
    }
}

fn bench_saa() {
    println!("\n== SAA merge (server per-round hot path) ==");
    let execs: Vec<(&str, Arc<dyn Executor>)> = {
        let mut v: Vec<(&str, Arc<dyn Executor>)> = vec![("native", native_speech())];
        if let Some(p) = pjrt_speech() {
            v.push(("pjrt", p));
        }
        v
    };
    let pdim = builtin_variant("speech").num_params;
    let mut rng = Rng::new(2);
    for (name, exec) in execs {
        for (nf, ns) in [(10usize, 3usize), (26, 13)] {
            let fresh: Vec<UpdateEntry> = (0..nf)
                .map(|i| UpdateEntry {
                    learner: i,
                    delta: (0..pdim).map(|_| rng.normal() as f32 * 0.01).collect(),
                    origin_round: 10,
                })
                .collect();
            let stale: Vec<UpdateEntry> = (0..ns)
                .map(|i| UpdateEntry {
                    learner: 100 + i,
                    delta: (0..pdim).map(|_| rng.normal() as f32 * 0.01).collect(),
                    origin_round: 8,
                })
                .collect();
            bench::run(&format!("saa_merge/{name}/fresh={nf},stale={ns}"), || {
                merge(exec.as_ref(), &fresh, &stale, ScalingRule::Relay { beta: 0.35 }, 10)
                    .unwrap();
            });
        }
    }
}

fn bench_selectors() {
    println!("\n== participant selection at scale ==");
    for n in [1_000usize, 10_000, 100_000] {
        let candidates: Vec<Candidate> = (0..n)
            .map(|i| Candidate {
                id: i,
                avail_prob: (i % 97) as f64 / 97.0,
                expected_duration: 10.0 + (i % 31) as f64,
            })
            .collect();
        for name in ["random", "priority", "oort"] {
            let mut sel = relay::selection::by_name(name).unwrap();
            let mut rng = Rng::new(3);
            bench::run(&format!("select/{name}/n={n}"), || {
                let mut ctx = SelectionCtx {
                    round: 1,
                    now: 0.0,
                    target: 100,
                    candidates: &candidates,
                    rng: &mut rng,
                };
                let picked = sel.select(&mut ctx);
                std::hint::black_box(picked);
            });
        }
    }
}

fn bench_selection_index() {
    println!("\n== selection index (samplable utility structures) ==");
    // score-tree ops at 1M ids / ~333k entries
    let n = 1_000_000usize;
    let mut idx = ScoreIndex::new(n);
    for id in (0..n).step_by(3) {
        idx.insert(id, (id % 97) as f64 * 0.5);
    }
    // step stays inside the seeded residue class (multiples of 3) so every
    // iteration is a true re-score of an existing entry, and the index the
    // later top-k/sample benches measure keeps its ~333k size
    let mut i = 0usize;
    let mut tick = 0usize;
    bench::run("selection/score_index_update_1M", || {
        i = (i + 39) % n;
        tick += 1;
        idx.insert(i, ((i + tick) % 89) as f64 * 0.25);
    });
    bench::run("selection/score_index_top100_of_333k", || {
        let mut c = 0usize;
        idx.top_k_desc(100, |_, _| c += 1);
        std::hint::black_box(c);
    });
    let mut rng = Rng::new(9);
    bench::run("selection/score_index_weighted_sample", || {
        std::hint::black_box(idx.weighted_sample(&mut rng));
    });

    // indexed select_from for the rank-the-pool selectors at 100k eligible:
    // the cost that used to be O(|eligible|) materialize-and-rank per
    // selection (compare select/{oort,priority}/n=100000 above)
    struct FlatProbes;
    impl ProbeSource for FlatProbes {
        fn avail_prob(&self, id: usize, _now: f64, _mu: f64) -> f64 {
            (id % 5) as f64 * 0.25
        }
        fn expected_duration(&self, id: usize) -> f64 {
            10.0 + (id % 31) as f64
        }
        fn slot_sig(&self, _now: f64, _mu: f64) -> SlotSig {
            SlotSig::Const
        }
    }
    for name in ["oort", "priority", "safa"] {
        let n = 100_000usize;
        let mut set = relay::population::CandidateSet::new(n);
        for id in 0..n {
            set.insert(id);
        }
        let probes = FlatProbes;
        let mut sel = relay::selection::by_name(name).unwrap();
        if name == "oort" {
            let completed: Vec<(usize, f64, f64)> = (0..n)
                .step_by(50)
                .map(|id| (id, (id % 83) as f64, 20.0))
                .collect();
            sel.feedback(&RoundFeedback {
                round: 0,
                completed: &completed,
                missed: &[],
                round_duration: 60.0,
            });
        }
        let mut rng = Rng::new(4);
        let mut round = 0usize;
        bench::run(&format!("selection/indexed/{name}/n=100000"), || {
            round += 1;
            let pool = SelectPool { set: &set, probes: &probes, mu: 100.0 };
            std::hint::black_box(sel.select_from(&pool, round, 0.0, 100, &mut rng).unwrap());
        });
    }
}

fn bench_trace_forecast() {
    println!("\n== availability substrate (per check-in costs) ==");
    let trace = TraceSet::generate(1000, 4, TraceConfig::default());
    let mut t = 0.0f64;
    bench::run("trace/available_query", || {
        t += 13.7;
        std::hint::black_box(trace.available(((t as usize) * 7) % 1000, t));
    });
    let mut f = SeasonalForecaster::default();
    let series = trace.sample_series(0, 1800.0);
    for (i, &v) in series.iter().enumerate() {
        f.observe(i as f64 * 1800.0, v > 0.5);
    }
    let mut q = 0.0f64;
    bench::run("forecast/prob_slot", || {
        q += 211.3;
        std::hint::black_box(f.prob_slot(q, q + 200.0));
    });
    bench::run("trace/generate_1000_learners", || {
        std::hint::black_box(TraceSet::generate(1000, 5, TraceConfig::default()));
    });
}

fn bench_kernel() {
    println!("\n== discrete-event kernel ==");
    // schedule + drain 10k events with heavy time ties (worst case for the
    // (time, class, seq) comparator)
    bench::run("kernel/schedule_drain_10k", || {
        let mut k = EventKernel::default();
        for i in 0..10_000usize {
            let class = match i % 3 {
                0 => EventClass::Delivery,
                1 => EventClass::Departure,
                _ => EventClass::CheckIn,
            };
            k.schedule((i % 97) as f64, class, i);
        }
        while let Some(ev) = k.pop_next() {
            std::hint::black_box(ev.payload);
        }
    });
}

fn bench_async_round() {
    println!("\n== buffered-async regime (tiny variant, native) ==");
    let cfg = ExpConfig {
        variant: "tiny".into(),
        total_learners: 100,
        rounds: 3,
        target_participants: 10,
        mode: RoundMode::Async { buffer_k: 10, max_staleness: Some(5) },
        avail: AvailMode::AllAvail,
        mean_samples: 20,
        test_per_class: 4,
        eval_every: 1000,
        cooldown_rounds: 1,
        lr: 0.1,
        ..Default::default()
    };
    let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new(builtin_variant("tiny")));
    bench::run("coordinator/async_3_merges/tiny/native", || {
        let mut c = Coordinator::new(cfg.clone(), Arc::clone(&exec)).unwrap();
        std::hint::black_box(c.run().unwrap());
    });
}

fn bench_round() {
    println!("\n== end-to-end coordinator round (tiny variant, native) ==");
    let cfg = ExpConfig {
        variant: "tiny".into(),
        total_learners: 100,
        rounds: 1,
        target_participants: 10,
        avail: AvailMode::AllAvail,
        mean_samples: 20,
        test_per_class: 4,
        eval_every: 1000,
        ..Default::default()
    };
    let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new(builtin_variant("tiny")));
    bench::run("coordinator/full_round/tiny/native", || {
        let mut c = Coordinator::new(cfg.clone(), Arc::clone(&exec)).unwrap();
        std::hint::black_box(c.run().unwrap());
    });
    if let Ok(pjrt) =
        relay::runtime::load_executor("artifacts", "speech", relay::runtime::Backend::Pjrt)
    {
        let mut cfg = preset("speech").unwrap();
        cfg.total_learners = 100;
        cfg.rounds = 1;
        cfg.avail = AvailMode::AllAvail;
        cfg.eval_every = 1000;
        bench::run("coordinator/full_round/speech/pjrt", || {
            let mut c = Coordinator::new(cfg.clone(), Arc::clone(&pjrt)).unwrap();
            std::hint::black_box(c.run().unwrap());
        });
    }
}

fn bench_substrates() {
    println!("\n== substrates ==");
    let json_src = std::fs::read_to_string("artifacts/manifest.json").unwrap_or_else(|_| {
        r#"{"format":"hlo-text-v1","variants":{},"computations":[]}"#.to_string()
    });
    bench::run("json/parse_manifest", || {
        std::hint::black_box(relay::util::json::Json::parse(&json_src).unwrap());
    });
    let mut rng = Rng::new(6);
    bench::run("rng/normal_x1000", || {
        for _ in 0..1000 {
            std::hint::black_box(rng.normal());
        }
    });
    let part = relay::data::partition::Partitioner::new(
        relay::data::partition::PartitionScheme::FedScale,
        35,
        100,
    );
    bench::run("partition/fedscale_1000_learners", || {
        std::hint::black_box(part.assign(1000, 7));
    });
}

fn bench_scale_path() {
    println!("\n== scale path: lazy construction + sweep engine ==");
    // lazy handle vs eager materialization of a large population
    bench::run("trace/lazy_construct_100k", || {
        std::hint::black_box(LazyTraceSet::new(100_000, 7, TraceConfig::default()));
    });
    bench::run("trace/eager_generate_10k", || {
        std::hint::black_box(TraceSet::generate(10_000, 7, TraceConfig::default()));
    });
    let big = ExpConfig {
        variant: "tiny".into(),
        total_learners: 100_000,
        rounds: 1,
        target_participants: 10,
        avail: AvailMode::DynAvail,
        mean_samples: 4,
        test_per_class: 2,
        eval_every: 1000,
        lr: 0.1,
        ..Default::default()
    };
    let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new(builtin_variant("tiny")));
    bench::run("coordinator/new_100k_dynavail_lazy", || {
        std::hint::black_box(Coordinator::new(big.clone(), Arc::clone(&exec)).unwrap());
    });

    // a small grid end-to-end, experiment-level parallelism off vs on
    let spec = GridSpec {
        label: "bench".into(),
        selectors: vec!["random".into(), "priority".into()],
        modes: vec![RoundMode::OverCommit { factor: 1.3 }],
        avails: vec![AvailMode::AllAvail],
        partitions: vec![PartitionScheme::UniformIid],
        coord_shards: vec![0],
        jobs: vec![1],
        seeds: vec![1, 1001],
        base: ExpConfig {
            variant: "tiny".into(),
            total_learners: 12,
            rounds: 3,
            target_participants: 4,
            mean_samples: 8,
            test_per_class: 2,
            eval_every: 1000,
            lr: 0.1,
            ..Default::default()
        },
    };
    for workers in [1usize, threadpool::default_workers().min(8)] {
        bench::run(&format!("sweep/grid_4runs/workers={workers}"), || {
            let opts = SweepOpts { workers, progress: false };
            std::hint::black_box(run_grid(&spec, Arc::clone(&exec), &opts).unwrap());
        });
    }
}

fn bench_population() {
    println!("\n== population substrate (candidate set + availability index) ==");
    // candidate-set ops at 1M ids: the per-event cost of the async engine
    let n = 1_000_000usize;
    let mut set = CandidateSet::new(n);
    for id in (0..n).step_by(7) {
        set.insert(id);
    }
    let mut i = 0usize;
    bench::run("population/candidate_set_toggle_1M", || {
        i = (i + 13) % n;
        if !set.insert(i) {
            set.remove(i);
        }
    });
    let mut rng = Rng::new(9);
    bench::run("population/candidate_set_sample100_of_1M", || {
        std::hint::black_box(set.sample_k(&mut rng, 100));
    });
    // per-advance cost of the availability index at 10k vs 100k learners:
    // transitions due dominate, not population size (the sub-linear claim)
    for n in [10_000usize, 100_000] {
        let mut idx = AvailabilityIndex::new(
            Availability::Lazy(LazyTraceSet::new(n, 4, TraceConfig::default())),
            n,
            8,
        );
        idx.advance_to(0.0, threadpool::default_workers()); // one-time build
        let mut t = 0.0f64;
        bench::run(&format!("population/index_advance_1s/n={n}"), || {
            t += 1.0;
            std::hint::black_box(idx.advance_to(t, 1).len());
        });
    }
}

fn main() {
    println!("relay benchmark suite (hand-rolled harness; budget ~1.5s per bench)");
    let t0 = std::time::Instant::now();
    bench_substrates();
    bench_kernel();
    bench_trace_forecast();
    bench_population();
    bench_scale_path();
    bench_selectors();
    bench_selection_index();
    bench_runtime();
    bench_saa();
    bench_round();
    bench_async_round();
    println!("\ntotal bench wallclock: {:.1}s", t0.elapsed().as_secs_f64());
    let _ = Duration::from_secs(0);
}
