//! Parallel experiment-grid engine.
//!
//! The paper's results are *grids* — selector × round-mode × availability ×
//! partition, replicated over seeds — and the client-selection literature
//! (PAPERS.md, arXiv 2306.04862) stresses that selector comparisons are only
//! meaningful across many seeds and scenarios. [`GridSpec`] declares such a
//! grid; [`run_grid`] expands it into `ExpConfig`s, executes whole
//! experiments concurrently on `util::threadpool` (experiment-level
//! parallelism: each run's RNG streams derive from its own config seed and
//! the executor is a shared read-only `Arc`), streams progress/ETA lines to
//! stderr, and aggregates per-cell mean/std metrics into one JSON report.
//!
//! Determinism: results depend only on each run's config, never on worker
//! interleaving — `run_parallel` returns results in job order and nothing
//! wall-clock-dependent enters the report — so the aggregated JSON is
//! byte-identical across `workers` settings (tests/sweep_determinism.rs
//! locks this in).

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{AvailMode, ExpConfig, RoundMode};
use crate::coordinator::run_experiment;
use crate::jobs::run_jobset;
use crate::data::partition::PartitionScheme;
use crate::metrics::{CellSummary, ExperimentResult};
use crate::runtime::Executor;
use crate::telemetry::ProgressMeter;
use crate::util::json::{arr, num, obj, Json};
use crate::util::threadpool;

/// Declarative experiment grid: the cross product of every axis, replicated
/// for every seed. `base` supplies all knobs an axis doesn't override.
#[derive(Clone, Debug)]
pub struct GridSpec {
    pub label: String,
    pub base: ExpConfig,
    /// Selector axis; "relay" expands to the full RELAY stack (IPS+SAA+APT).
    pub selectors: Vec<String>,
    pub modes: Vec<RoundMode>,
    pub avails: Vec<AvailMode>,
    pub partitions: Vec<PartitionScheme>,
    /// Coordinator shard counts (perf axis: results are byte-identical for
    /// any K, so multi-K grids measure coordination cost, never accuracy).
    /// Cells carry a `-k{K}` label suffix only when this axis has > 1 entry.
    pub coord_shards: Vec<usize>,
    /// Concurrent-job counts (multi-job axis: cells with > 1 job run the
    /// whole set through `jobs::run_jobset` over one shared fleet). Cells
    /// carry a `-j{J}` label suffix only when this axis has > 1 entry.
    pub jobs: Vec<usize>,
    pub seeds: Vec<u64>,
}

impl GridSpec {
    /// A 1-cell grid around `base` (each axis defaults to the base value).
    pub fn new(base: ExpConfig) -> GridSpec {
        GridSpec {
            label: "sweep".into(),
            selectors: vec![base.selector.clone()],
            modes: vec![base.mode],
            avails: vec![base.avail],
            partitions: vec![base.partition],
            coord_shards: vec![base.coord_shards],
            jobs: vec![base.jobs],
            seeds: vec![base.seed],
            base,
        }
    }

    pub fn cells(&self) -> usize {
        self.selectors.len()
            * self.modes.len()
            * self.avails.len()
            * self.partitions.len()
            * self.coord_shards.len().max(1)
            * self.jobs.len().max(1)
    }

    pub fn total_runs(&self) -> usize {
        self.cells() * self.seeds.len()
    }

    /// Expand into per-cell config groups, cell-major / seed-minor, in a
    /// fixed axis order (selector, mode, avail, partition, coord-shards,
    /// jobs) so reports are reproducible run-to-run.
    ///
    /// Labels are injective over the grid: axes that degrade to a single
    /// point suppress their token (`-k{K}`, `-j{J}`, the fault suffix), so
    /// two distinct cells *can* render the same base label — e.g. a
    /// repeated axis value, or two `RoundMode`s that format alike. Any
    /// repeat gets a `#2`, `#3`, … disambiguator ('#' never occurs in
    /// axis-derived tokens), so a report never silently merges cells.
    pub fn expand(&self) -> Vec<GridCell> {
        // a legacy spec constructed with an empty coord/jobs axis behaves
        // like the single-point axis at the base value
        let shard_axis: Vec<usize> = if self.coord_shards.is_empty() {
            vec![self.base.coord_shards]
        } else {
            self.coord_shards.clone()
        };
        let jobs_axis: Vec<usize> = if self.jobs.is_empty() {
            vec![self.base.jobs]
        } else {
            self.jobs.clone()
        };
        let mut seen: std::collections::HashMap<String, usize> = std::collections::HashMap::new();
        let mut cells = Vec::with_capacity(self.cells());
        for sel in &self.selectors {
            for mode in &self.modes {
                for avail in &self.avails {
                    for part in &self.partitions {
                        for &shards in &shard_axis {
                            for &jobs in &jobs_axis {
                                let mut label = format!(
                                    "{sel}-{}-{}-{}",
                                    mode_label(mode),
                                    avail_label(*avail),
                                    part.label()
                                );
                                // a multi-K grid is a coordination-perf sweep:
                                // keep the K in the cell key (single-K grids
                                // keep their pre-axis labels)
                                if shard_axis.len() > 1 {
                                    label = format!("{label}-k{shards}");
                                }
                                if jobs_axis.len() > 1 {
                                    label = format!("{label}-j{jobs}");
                                }
                                // fault-injected grids carry the fault mix in
                                // the cell key, so faulty and clean sweeps
                                // never collide in a report
                                if self.base.faults.is_active() {
                                    label = format!("{label}-{}", self.base.faults.label());
                                }
                                let n = seen.entry(label.clone()).or_insert(0);
                                *n += 1;
                                if *n > 1 {
                                    label = format!("{label}#{n}");
                                }
                                let mut runs = Vec::with_capacity(self.seeds.len());
                                for &seed in &self.seeds {
                                    let mut c = self.base.clone();
                                    if sel == "relay" {
                                        c = c.relay();
                                    } else {
                                        c.selector = sel.clone();
                                    }
                                    c.mode = *mode;
                                    c.avail = *avail;
                                    c.partition = *part;
                                    c.coord_shards = shards;
                                    c.jobs = jobs;
                                    // per-job override vectors must be empty
                                    // or jobs-long; when the axis moves the
                                    // job count away from the base's, the
                                    // base overrides no longer apply
                                    if c.job_priorities.len() != jobs {
                                        c.job_priorities.clear();
                                    }
                                    if c.job_selectors.len() != jobs {
                                        c.job_selectors.clear();
                                    }
                                    if c.job_modes.len() != jobs {
                                        c.job_modes.clear();
                                    }
                                    if c.job_targets.len() != jobs {
                                        c.job_targets.clear();
                                    }
                                    c.seed = seed;
                                    c.label = format!("{label}/s{seed}");
                                    runs.push(c);
                                }
                                cells.push(GridCell {
                                    label,
                                    selector: sel.clone(),
                                    mode: mode_label(mode),
                                    avail: avail_label(*avail).to_string(),
                                    partition: part.label(),
                                    runs,
                                });
                            }
                        }
                    }
                }
            }
        }
        cells
    }
}

/// One expanded grid cell: its report key plus the per-seed configs.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub label: String,
    pub selector: String,
    pub mode: String,
    pub avail: String,
    pub partition: String,
    pub runs: Vec<ExpConfig>,
}

fn mode_label(m: &RoundMode) -> String {
    match m {
        RoundMode::OverCommit { factor } => format!("oc{factor}"),
        RoundMode::Deadline { deadline } => format!("dl{deadline}"),
        RoundMode::Async { buffer_k, max_staleness } => match max_staleness {
            Some(s) => format!("async{buffer_k}s{s}"),
            None => format!("async{buffer_k}"),
        },
    }
}

fn avail_label(a: AvailMode) -> &'static str {
    match a {
        AvailMode::AllAvail => "all",
        AvailMode::DynAvail => "dyn",
    }
}

/// Sweep execution knobs.
#[derive(Clone, Copy, Debug)]
pub struct SweepOpts {
    /// Concurrent experiments (0 = one per core, capped at 8).
    pub workers: usize,
    /// Stream per-run progress/ETA lines to stderr.
    pub progress: bool,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts { workers: 0, progress: false }
    }
}

/// The aggregated result of one grid run.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub label: String,
    pub cells: Vec<CellSummary>,
    /// Total experiments executed (cells × seeds).
    pub runs: usize,
}

impl SweepReport {
    /// Deterministic report JSON: everything here is a pure function of the
    /// grid spec + seeds (no wall-clock, no worker count).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("format", Json::Str("relay-sweep-v1".into())),
            ("label", Json::Str(self.label.clone())),
            ("runs", num(self.runs as f64)),
            ("cells", arr(self.cells.iter().map(|c| c.to_json()))),
        ])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path.as_ref(), self.to_json().to_string())
            .with_context(|| format!("writing sweep report {:?}", path.as_ref()))
    }

    /// Paper-style comparison table over cells.
    pub fn print_table(&self) {
        println!(
            "  {:<36} {:>5} {:>8} {:>7} {:>8} {:>7}",
            "cell", "seeds", "acc", "±std", "res(h)", "waste%"
        );
        for c in &self.cells {
            println!(
                "  {:<36} {:>5} {:>8} {:>7} {:>8.2} {:>6.1}%",
                c.label,
                c.seeds,
                c.mean_accuracy
                    .map(|a| format!("{:.1}%", 100.0 * a))
                    .unwrap_or_else(|| "n/a".into()),
                c.std_accuracy
                    .map(|s| format!("{:.2}", 100.0 * s))
                    .unwrap_or_else(|| "-".into()),
                c.mean_resource_hours,
                100.0 * c.mean_waste_fraction,
            );
        }
    }
}

/// Run every config on the worker pool; results come back in input order, so
/// downstream grouping/aggregation is independent of scheduling. When
/// experiments themselves run concurrently, each run's inner per-learner
/// training pool is pinned to one thread (nested pools oversubscribe the
/// machine without helping wall-clock; results are unaffected either way).
pub fn run_many(
    runs: Vec<(ExpConfig, Arc<dyn Executor>)>,
    workers: usize,
    progress: bool,
) -> Result<Vec<ExperimentResult>> {
    let workers = if workers == 0 {
        threadpool::default_workers().min(8)
    } else {
        workers
    };
    let total = runs.len();
    // Experiments only truly run concurrently when both the pool and the
    // run list allow it; only then pin the inner training pools (a single
    // experiment on a wide pool should keep its inner parallelism).
    let concurrent = workers.min(total.max(1)) > 1;
    let done = AtomicUsize::new(0);
    let done_ref = &done;
    let meter = ProgressMeter::start("sweep", total);
    let meter_ref = &meter;
    let jobs: Vec<_> = runs
        .into_iter()
        .map(|(mut cfg, exec)| {
            if concurrent {
                cfg.workers = 1;
                cfg.train_workers = 1;
            }
            let label = if cfg.label.is_empty() {
                cfg.selector.clone()
            } else {
                cfg.label.clone()
            };
            move || {
                // multi-job cells run the whole job set over one shared
                // fleet and flatten its books into the common result shape
                let r = if cfg.jobs > 1 {
                    run_jobset(cfg, exec).map(|r| r.summary_result())
                } else {
                    run_experiment(cfg, exec)
                }
                .with_context(|| format!("sweep run '{label}' failed"));
                let k = done_ref.fetch_add(1, Ordering::SeqCst) + 1;
                if progress {
                    match &r {
                        Ok(res) => eprintln!("{}", meter_ref.line_at(k, &res.summary())),
                        Err(e) => eprintln!(
                            "{}",
                            meter_ref.stalled_at(k, &format!("{label} FAILED: {e:#}"))
                        ),
                    }
                }
                r
            }
        })
        .collect();
    threadpool::run_parallel(workers, jobs).into_iter().collect()
}

/// Execute a whole grid and return the expanded cells plus every per-run
/// [`ExperimentResult`], cell-major / seed-minor
/// (`results[cell_idx * seeds.len() + seed_idx]`). This is the layer the
/// per-run JSON regression suite (`tests/sweep_json_valid.rs`) hooks into:
/// every result a sweep produces must serialize to *parseable* JSON.
pub fn run_grid_results(
    spec: &GridSpec,
    exec: Arc<dyn Executor>,
    opts: &SweepOpts,
) -> Result<(Vec<GridCell>, Vec<ExperimentResult>)> {
    let cells = spec.expand();
    let mut flat = Vec::with_capacity(spec.total_runs());
    for cell in &cells {
        for cfg in &cell.runs {
            flat.push((cfg.clone(), Arc::clone(&exec)));
        }
    }
    if opts.progress {
        let meter = ProgressMeter::start("sweep", flat.len());
        eprintln!(
            "{}",
            meter.banner(&format!(
                "{}: {} cells x {} seeds = {} runs",
                spec.label,
                cells.len(),
                spec.seeds.len(),
                flat.len()
            ))
        );
    }
    let results = run_many(flat, opts.workers, opts.progress)?;
    Ok((cells, results))
}

/// Execute a whole grid and aggregate per-cell summaries.
pub fn run_grid(
    spec: &GridSpec,
    exec: Arc<dyn Executor>,
    opts: &SweepOpts,
) -> Result<SweepReport> {
    let (cells, results) = run_grid_results(spec, exec, opts)?;
    let per_cell = spec.seeds.len();
    let mut summaries = Vec::with_capacity(cells.len());
    for (i, cell) in cells.iter().enumerate() {
        let group = &results[i * per_cell..(i + 1) * per_cell];
        let mut s = CellSummary::from_results(cell.label.clone(), group);
        s.selector = cell.selector.clone();
        s.mode = cell.mode.clone();
        s.avail = cell.avail.clone();
        s.partition = cell.partition.clone();
        summaries.push(s);
    }
    Ok(SweepReport {
        label: spec.label.clone(),
        cells: summaries,
        runs: results.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ExpConfig {
        ExpConfig {
            variant: "tiny".into(),
            total_learners: 12,
            rounds: 3,
            target_participants: 3,
            mean_samples: 8,
            test_per_class: 2,
            eval_every: 2,
            lr: 0.1,
            ..Default::default()
        }
    }

    #[test]
    fn expansion_is_cell_major_and_counts_match() {
        let spec = GridSpec {
            label: "x".into(),
            selectors: vec!["random".into(), "oort".into()],
            modes: vec![
                RoundMode::OverCommit { factor: 1.3 },
                RoundMode::Deadline { deadline: 60.0 },
            ],
            avails: vec![AvailMode::AllAvail],
            partitions: vec![PartitionScheme::UniformIid],
            coord_shards: vec![0],
            jobs: vec![1],
            seeds: vec![1, 2, 3],
            base: base(),
        };
        assert_eq!(spec.cells(), 4);
        assert_eq!(spec.total_runs(), 12);
        let cells = spec.expand();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].label, "random-oc1.3-all-iid");
        assert_eq!(cells[1].label, "random-dl60-all-iid");
        assert_eq!(cells[2].label, "oort-oc1.3-all-iid");
        for c in &cells {
            assert_eq!(c.runs.len(), 3);
            assert_eq!(
                c.runs.iter().map(|r| r.seed).collect::<Vec<_>>(),
                vec![1, 2, 3]
            );
        }
    }

    #[test]
    fn async_mode_cells_get_descriptive_labels() {
        let mut spec = GridSpec::new(base());
        spec.modes = vec![
            RoundMode::Async { buffer_k: 4, max_staleness: Some(8) },
            RoundMode::Async { buffer_k: 10, max_staleness: None },
        ];
        let cells = spec.expand();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].mode, "async4s8");
        assert_eq!(cells[1].mode, "async10");
        assert!(cells[0].label.contains("async4s8"), "{}", cells[0].label);
    }

    #[test]
    fn fault_active_grids_label_their_cells() {
        use crate::scenario::faults::FaultConfig;
        let mut b = base();
        b.faults = FaultConfig { flap: 0.1, crash: 0.25, fault_seed: 3, ..Default::default() };
        let spec = GridSpec::new(b);
        let cells = spec.expand();
        assert_eq!(cells.len(), 1);
        assert!(
            cells[0].label.ends_with("-flap0.1+crash0.25"),
            "fault mix missing from cell label: {}",
            cells[0].label
        );
        // and a clean grid stays exactly as before
        let clean = GridSpec::new(base()).expand();
        assert_eq!(clean[0].label, "random-oc1.3-dyn-iid");
    }

    #[test]
    fn coord_shards_axis_expands_and_labels() {
        let mut spec = GridSpec::new(base());
        spec.coord_shards = vec![1, 8];
        let cells = spec.expand();
        assert_eq!(spec.cells(), 2);
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].label, "random-oc1.3-dyn-iid-k1");
        assert_eq!(cells[1].label, "random-oc1.3-dyn-iid-k8");
        assert_eq!(cells[0].runs[0].coord_shards, 1);
        assert_eq!(cells[1].runs[0].coord_shards, 8);
        // a single-point axis keeps the pre-axis labels and an empty axis
        // degrades to the base value
        let single = GridSpec::new(base()).expand();
        assert_eq!(single[0].label, "random-oc1.3-dyn-iid");
        let mut legacy = GridSpec::new(base());
        legacy.coord_shards = Vec::new();
        let cells = legacy.expand();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].runs[0].coord_shards, legacy.base.coord_shards);
    }

    #[test]
    fn jobs_axis_expands_labels_and_routes_overrides() {
        let mut b = base();
        b.jobs = 2;
        b.job_targets = vec![3, 2];
        let mut spec = GridSpec::new(b);
        spec.jobs = vec![1, 2];
        let cells = spec.expand();
        assert_eq!(spec.cells(), 2);
        assert_eq!(cells[0].label, "random-oc1.3-dyn-iid-j1");
        assert_eq!(cells[1].label, "random-oc1.3-dyn-iid-j2");
        // jobs=1 cells drop the now-mismatched per-job overrides; jobs=2
        // cells keep them — both expansions must pass validation
        assert_eq!(cells[0].runs[0].jobs, 1);
        assert!(cells[0].runs[0].job_targets.is_empty());
        assert_eq!(cells[1].runs[0].job_targets, vec![3, 2]);
        for c in &cells {
            c.runs[0].validate().unwrap();
        }
        // a single-point axis keeps the pre-axis labels
        let single = GridSpec::new(base()).expand();
        assert_eq!(single[0].label, "random-oc1.3-dyn-iid");
    }

    #[test]
    fn degraded_mixed_grids_keep_labels_injective() {
        // Every way the label tokens can degrade at once: a repeated mode
        // that formats identically, a repeated shard value whose -k token
        // matches, and a repeated jobs value. Distinct cells must never
        // share a report key.
        let spec = GridSpec {
            label: "clash".into(),
            selectors: vec!["random".into(), "random".into()],
            modes: vec![
                RoundMode::OverCommit { factor: 1.3 },
                RoundMode::OverCommit { factor: 1.3 },
            ],
            avails: vec![AvailMode::AllAvail],
            partitions: vec![PartitionScheme::UniformIid],
            coord_shards: vec![4, 4],
            jobs: vec![2, 2],
            seeds: vec![1],
            base: base(),
        };
        let cells = spec.expand();
        assert_eq!(cells.len(), 16);
        let mut labels: Vec<&str> = cells.iter().map(|c| c.label.as_str()).collect();
        labels.sort_unstable();
        let before = labels.len();
        labels.dedup();
        assert_eq!(labels.len(), before, "sweep cell labels collided: {labels:?}");
        // per-run labels inherit the disambiguated cell key
        assert!(cells[1].runs[0].label.contains('#'), "{}", cells[1].runs[0].label);
    }

    #[test]
    fn multijob_cells_run_through_the_jobset_engine() {
        use crate::runtime::{builtin_variant, NativeExecutor};
        let mut spec = GridSpec::new(base());
        spec.jobs = vec![1, 2];
        spec.avails = vec![AvailMode::AllAvail];
        let exec: Arc<dyn Executor> =
            Arc::new(NativeExecutor::new(builtin_variant("tiny")));
        let r = run_grid(&spec, exec, &SweepOpts { workers: 2, progress: false }).unwrap();
        assert_eq!(r.runs, 2);
        assert_eq!(r.cells.len(), 2);
        for c in &r.cells {
            assert_eq!(c.seeds, 1);
            assert!(c.mean_resource_hours > 0.0, "cell {} spent nothing", c.label);
        }
        assert!(Json::parse(&r.to_json().to_string()).is_ok());
    }

    #[test]
    fn relay_axis_enables_full_stack() {
        let mut spec = GridSpec::new(base());
        spec.selectors = vec!["relay".into()];
        let cells = spec.expand();
        let cfg = &cells[0].runs[0];
        assert_eq!(cfg.selector, "priority");
        assert!(cfg.use_saa && cfg.apt);
        assert!(cells[0].label.starts_with("relay-"));
    }

    #[test]
    fn run_many_handles_empty_input() {
        let out = run_many(Vec::new(), 4, false).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn single_cell_grid_runs_and_reports() {
        use crate::runtime::{builtin_variant, NativeExecutor};
        let spec = GridSpec {
            seeds: vec![5, 6],
            ..GridSpec::new(base())
        };
        let exec: Arc<dyn Executor> =
            Arc::new(NativeExecutor::new(builtin_variant("tiny")));
        let r = run_grid(&spec, exec, &SweepOpts { workers: 2, progress: false }).unwrap();
        assert_eq!(r.runs, 2);
        assert_eq!(r.cells.len(), 1);
        assert_eq!(r.cells[0].seeds, 2);
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(
            parsed.get("format").and_then(|f| f.as_str()),
            Some("relay-sweep-v1")
        );
        assert_eq!(parsed.get("runs").and_then(|x| x.as_usize()), Some(2));
    }
}
