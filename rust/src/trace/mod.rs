//! Availability-trace substrate, substituting the 136k-user week-long
//! behaviour trace of Yang et al. that the paper replays (§5.1, §C).
//!
//! A learner is *available* while "connected to a charger" (the paper's
//! definition). The generator reproduces the trace's published marginals:
//!
//! * **diurnal cycle** (Fig. 14a): charging sessions concentrate at night in
//!   each device's local timezone;
//! * **long-tail session lengths** (Fig. 14b): ~70% of sessions are shorter
//!   than 10 minutes, median ≈ 5 minutes (lognormal body + heavy tail for
//!   overnight charging).
//!
//! Traces span one week and wrap cyclically for longer experiments; they can
//! be saved/loaded as JSON for replay.

pub mod generator;

pub use generator::{LazyTraceSet, TraceConfig, TraceSet};

pub const DAY: f64 = 86_400.0;
pub const WEEK: f64 = 7.0 * DAY;
