//! Synthetic diurnal availability traces + replay queries + trace file IO.
//!
//! Two representations share one generator (`learner_sessions`, a pure
//! function of the population root RNG + learner id + config, so both are
//! bit-identical):
//!
//! * [`TraceSet`] — every learner's week materialized up front (figure
//!   harness, trace statistics, file IO);
//! * [`LazyTraceSet`] — sessions generated at first touch: construction does
//!   no trace work, and memory is bounded by the learners actually queried
//!   (the coordinator's scale path; a run that probes the whole population
//!   still materializes everyone by its first check-in sweep).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::{DAY, WEEK};
use crate::util::json::{arr, num, obj, Json};
use crate::util::lazy::LazySlots;
use crate::util::rng::Rng;
use crate::util::stats;

/// Generation knobs. Defaults reproduce the Yang et al. marginals the paper
/// reports (70% of sessions < 10 min, median ~5 min, night-time peak).
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Median charging-session length (seconds).
    pub median_session: f64,
    /// Lognormal sigma of session length.
    pub session_sigma: f64,
    /// Fraction of sessions that are long "overnight" charges.
    pub overnight_frac: f64,
    /// Mean gap between sessions at the *diurnal peak* (seconds).
    pub peak_gap: f64,
    /// Ratio of off-peak to peak session rate (>= 1; larger = stronger cycle).
    pub diurnal_strength: f64,
    /// Stddev (seconds) of each device's personal night-peak phase around
    /// the common ~2am peak. Small = strong aggregate diurnality (Fig. 14a).
    pub phase_jitter: f64,
    /// If set, each device also charges in a near-deterministic nightly
    /// block: (mean duration secs, start jitter secs). Models the "plugged
    /// in overnight" users that dominate the Stunner forecast experiment.
    pub nightly_block: Option<(f64, f64)>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            median_session: 300.0,  // 5 minutes
            session_sigma: 1.1,     // P(< 600s) ~ 0.74
            overnight_frac: 0.12,
            peak_gap: 3_600.0,      // ~1 charge/h at night
            diurnal_strength: 5.0,  // daytime gaps ~5x longer
            phase_jitter: 3.0 * 3600.0,
            nightly_block: None,
        }
    }
}

impl TraceConfig {
    /// "Regular charger" population: the kind of heavily-observed,
    /// strongly-periodic devices the paper's 5.2 forecast experiment
    /// selects from the Stunner trace (>= 1000 samples, nightly charging).
    pub fn regular() -> Self {
        TraceConfig {
            median_session: 900.0,
            session_sigma: 0.8,
            overnight_frac: 0.0,
            peak_gap: 16.0 * 3600.0, // only occasional daytime top-ups
            diurnal_strength: 2.0,
            phase_jitter: 1800.0,
            nightly_block: Some((5.0 * 3600.0, 300.0)),
        }
    }
}

/// Per-learner week-long charging sessions, wrap-around replay.
pub struct TraceSet {
    /// sessions[l] = sorted, non-overlapping (start, end) within [0, WEEK).
    pub sessions: Vec<Vec<(f64, f64)>>,
    pub config: TraceConfig,
}

/// One learner's week of charging sessions, drawn from the population root
/// RNG (`Rng::new(seed ^ 0x7EAC_E5E7)`). Pure function of
/// (root, learner, config): [`TraceSet::generate`] and [`LazyTraceSet`] both
/// go through here, so eager and lazy traces are bit-identical.
fn learner_sessions(root: &Rng, learner: usize, config: &TraceConfig) -> Vec<(f64, f64)> {
    let mut rng = root.stream(learner as u64);
    // Device-local night peak: common ~2am peak with per-device
    // jitter (timezones, habits) -> pronounced aggregate diurnal
    // cycle like the paper's Fig. 14a.
    let phase = (2.0 * 3600.0 + rng.normal() * config.phase_jitter).rem_euclid(DAY);
    let mut s = Vec::new();
    // near-deterministic nightly charging block (regular devices)
    if let Some((dur_mean, jitter)) = config.nightly_block {
        let start_of_day = (phase - dur_mean / 2.0).rem_euclid(DAY);
        for day in 0..7 {
            let start = (day as f64 * DAY + start_of_day + rng.normal() * jitter).max(0.0);
            let dur = (dur_mean + rng.normal() * jitter).max(1800.0);
            let end = (start + dur).min(WEEK);
            if start < WEEK {
                s.push((start, end));
            }
        }
    }
    let mut t = rng.uniform(0.0, config.peak_gap);
    while t < WEEK {
        // diurnal gap modulation: cosine bump, peak at `phase`
        let day_pos = (t - phase).rem_euclid(DAY) / DAY; // 0 at peak
        let cycle = 0.5 * (1.0 - (2.0 * std::f64::consts::PI * day_pos).cos());
        let gap_scale = 1.0 + (config.diurnal_strength - 1.0) * cycle;
        let dur = if rng.bool(config.overnight_frac) {
            // overnight charge: hours-long
            rng.lognormal((4.0 * 3600.0f64).ln(), 0.5)
        } else {
            rng.lognormal(config.median_session.ln(), config.session_sigma)
        };
        let dur = dur.clamp(20.0, 12.0 * 3600.0);
        let end = (t + dur).min(WEEK);
        s.push((t, end));
        let gap = rng.exponential(1.0 / (config.peak_gap * gap_scale));
        t = end + gap.max(30.0);
    }
    // sort + merge overlaps (nightly block vs random sessions)
    s.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut merged: Vec<(f64, f64)> = Vec::with_capacity(s.len());
    for (a, b) in s {
        match merged.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => merged.push((a, b)),
        }
    }
    merged
}

// ---- session-list queries shared by the eager and lazy trace types ------

#[inline]
fn wrap_week(t: f64) -> f64 {
    t.rem_euclid(WEEK)
}

/// Session containing wrapped time `tw`, if any.
fn session_at_in(s: &[(f64, f64)], tw: f64) -> Option<(f64, f64)> {
    let idx = s.partition_point(|&(start, _)| start <= tw);
    if idx == 0 {
        return None;
    }
    let (start, end) = s[idx - 1];
    (tw < end).then_some((start, end))
}

/// Available for the whole interval [t, t+dur]? Conservative: the session
/// containing t must extend past t+dur (crossing the week boundary is
/// handled by re-querying).
fn available_through_in(s: &[(f64, f64)], t: f64, dur: f64) -> bool {
    let tw = wrap_week(t);
    match session_at_in(s, tw) {
        None => false,
        Some((_, end)) => {
            if tw + dur <= end {
                true
            } else if end >= WEEK - 1e-9 {
                // session clipped at week end: continue into next cycle
                available_through_in(s, 0.0, dur - (end - tw))
            } else {
                false
            }
        }
    }
}

/// Sampled 0/1 availability series over one week (forecaster input).
fn sample_series_in(s: &[(f64, f64)], step: f64) -> Vec<f64> {
    let n = (WEEK / step) as usize;
    (0..n)
        .map(|i| {
            if session_at_in(s, wrap_week(i as f64 * step)).is_some() {
                1.0
            } else {
                0.0
            }
        })
        .collect()
}

impl TraceSet {
    /// Generate traces for `n` learners, deterministic per seed.
    pub fn generate(n: usize, seed: u64, config: TraceConfig) -> TraceSet {
        let root = Rng::new(seed ^ 0x7EAC_E5E7);
        let sessions = (0..n).map(|l| learner_sessions(&root, l, &config)).collect();
        TraceSet { sessions, config }
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Wrap absolute time into the one-week trace window.
    #[inline]
    fn wrap(t: f64) -> f64 {
        wrap_week(t)
    }

    /// Session containing wrapped time `tw`, if any.
    fn session_at(&self, learner: usize, tw: f64) -> Option<(f64, f64)> {
        session_at_in(&self.sessions[learner], tw)
    }

    /// Is the learner available (charging) at absolute time `t`?
    pub fn available(&self, learner: usize, t: f64) -> bool {
        self.session_at(learner, Self::wrap(t)).is_some()
    }

    /// Is the learner available for the whole interval [t, t+dur]?
    /// (Used to decide whether a participant completes training or drops.)
    pub fn available_through(&self, learner: usize, t: f64, dur: f64) -> bool {
        available_through_in(&self.sessions[learner], t, dur)
    }

    /// Empirical probability the learner is available throughout
    /// [t+a, t+b] given ground truth (used by the ORACLE availability
    /// baseline and tests; learners themselves use `forecast`).
    pub fn true_slot_availability(&self, learner: usize, a: f64, b: f64) -> f64 {
        let steps = 16;
        let mut avail = 0usize;
        for i in 0..steps {
            let t = a + (b - a) * (i as f64 + 0.5) / steps as f64;
            if self.available(learner, t) {
                avail += 1;
            }
        }
        avail as f64 / steps as f64
    }

    /// All session lengths (seconds), for Fig. 14b.
    pub fn session_lengths(&self) -> Vec<f64> {
        self.sessions
            .iter()
            .flat_map(|s| s.iter().map(|&(a, b)| b - a))
            .collect()
    }

    /// Number of available learners at each bin over one week (Fig. 14a).
    pub fn availability_timeline(&self, bin: f64) -> Vec<usize> {
        let bins = (WEEK / bin).ceil() as usize;
        let mut counts = vec![0usize; bins];
        for l in 0..self.len() {
            for &(a, b) in &self.sessions[l] {
                let first = (a / bin) as usize;
                let last = ((b / bin) as usize).min(bins - 1);
                for c in counts.iter_mut().take(last + 1).skip(first) {
                    *c += 1;
                }
            }
        }
        counts
    }

    /// Sampled 0/1 availability series for one learner (forecaster input).
    pub fn sample_series(&self, learner: usize, step: f64) -> Vec<f64> {
        sample_series_in(&self.sessions[learner], step)
    }

    // ---- file IO (replayable trace artifacts) ---------------------------

    pub fn to_json(&self) -> Json {
        arr(self.sessions.iter().map(|s| {
            arr(s.iter().flat_map(|&(a, b)| [num(a), num(b)]).collect::<Vec<_>>())
        }))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let j = obj(vec![("format", Json::Str("relay-trace-v1".into())), ("sessions", self.to_json())]);
        std::fs::write(path.as_ref(), j.to_string())
            .with_context(|| format!("writing trace {:?}", path.as_ref()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TraceSet> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading trace {:?}", path.as_ref()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        if j.get("format").and_then(|f| f.as_str()) != Some("relay-trace-v1") {
            return Err(anyhow!("not a relay trace file"));
        }
        let mut sessions = Vec::new();
        for learner in j.get("sessions").and_then(|s| s.as_arr()).unwrap_or(&[]) {
            let flat = learner.as_arr().ok_or_else(|| anyhow!("bad sessions row"))?;
            let mut s = Vec::with_capacity(flat.len() / 2);
            for pair in flat.chunks(2) {
                let a = pair[0].as_f64().ok_or_else(|| anyhow!("bad number"))?;
                let b = pair
                    .get(1)
                    .and_then(|x| x.as_f64())
                    .ok_or_else(|| anyhow!("odd session list"))?;
                s.push((a, b));
            }
            sessions.push(s);
        }
        Ok(TraceSet { sessions, config: TraceConfig::default() })
    }
}

/// Per-learner traces generated on demand (at most once each, thread-safe).
///
/// `TraceSet::generate` materializes all `n` learners' sessions at
/// construction — tens of seconds and gigabytes at 100k+ learners even
/// though an experiment only replays the learners it actually touches.
/// `LazyTraceSet` keeps the population root RNG and generates a learner's
/// week at first touch, bit-identically to the eager path (both call
/// `learner_sessions`).
pub struct LazyTraceSet {
    root: Rng,
    config: TraceConfig,
    slots: LazySlots<Vec<(f64, f64)>>,
}

impl LazyTraceSet {
    /// Lazy population handle; does no trace generation.
    pub fn new(n: usize, seed: u64, config: TraceConfig) -> LazyTraceSet {
        LazyTraceSet {
            root: Rng::new(seed ^ 0x7EAC_E5E7),
            config,
            slots: LazySlots::new(n),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn config(&self) -> &TraceConfig {
        &self.config
    }

    /// This learner's sessions, generating them at first touch.
    pub fn sessions(&self, learner: usize) -> &[(f64, f64)] {
        self.slots
            .get_or_init(learner, || learner_sessions(&self.root, learner, &self.config))
    }

    /// How many learners' traces have been generated so far.
    pub fn materialized(&self) -> usize {
        self.slots.initialized()
    }

    /// Is the learner available (charging) at absolute time `t`?
    pub fn available(&self, learner: usize, t: f64) -> bool {
        session_at_in(self.sessions(learner), wrap_week(t)).is_some()
    }

    /// Is the learner available for the whole interval [t, t+dur]?
    pub fn available_through(&self, learner: usize, t: f64, dur: f64) -> bool {
        available_through_in(self.sessions(learner), t, dur)
    }

    /// Sampled 0/1 availability series for one learner (forecaster input).
    pub fn sample_series(&self, learner: usize, step: f64) -> Vec<f64> {
        sample_series_in(self.sessions(learner), step)
    }
}

/// Fig. 14b summary: fraction of sessions below each duration checkpoint.
pub fn session_cdf_checkpoints(trace: &TraceSet) -> Vec<(f64, f64)> {
    let lens = trace.session_lengths();
    [60.0, 300.0, 600.0, 1800.0, 3600.0, 6.0 * 3600.0]
        .iter()
        .map(|&p| (p, stats::ecdf(&lens, &[p])[0]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TraceSet {
        TraceSet::generate(300, 11, TraceConfig::default())
    }

    #[test]
    fn deterministic() {
        let a = TraceSet::generate(10, 4, TraceConfig::default());
        let b = TraceSet::generate(10, 4, TraceConfig::default());
        assert_eq!(a.sessions, b.sessions);
    }

    #[test]
    fn sessions_sorted_non_overlapping() {
        let t = small();
        for s in &t.sessions {
            for w in s.windows(2) {
                assert!(w[0].1 <= w[1].0, "overlap: {:?}", w);
            }
            for &(a, b) in s {
                assert!(a < b && b <= WEEK + 1e-9);
            }
        }
    }

    #[test]
    fn session_length_marginals_match_paper() {
        let t = small();
        let lens = t.session_lengths();
        assert!(lens.len() > 1000, "need enough sessions, got {}", lens.len());
        let under_10min = stats::ecdf(&lens, &[600.0])[0];
        let under_5min = stats::ecdf(&lens, &[300.0])[0];
        // paper: ~70% < 10 min; ~50% >= 5 min
        assert!((0.55..=0.85).contains(&under_10min), "P(<10min)={under_10min}");
        assert!((0.30..=0.60).contains(&under_5min), "P(<5min)={under_5min}");
    }

    #[test]
    fn diurnal_cycle_visible() {
        let t = small();
        let timeline = t.availability_timeline(1800.0);
        // aggregate over 7 days into 48 half-hour-of-day bins
        let per_day: Vec<f64> = (0..48)
            .map(|b| {
                (0..7).map(|d| timeline[d * 48 + b] as f64).sum::<f64>() / 7.0
            })
            .collect();
        let max = per_day.iter().cloned().fold(0.0, f64::max);
        let min = per_day.iter().cloned().fold(f64::INFINITY, f64::min);
        // per-device phases are uniform, so the aggregate cycle is muted but
        // availability must vary over the day
        assert!(max > 0.0);
        assert!(min < max, "no variation: {per_day:?}");
    }

    #[test]
    fn available_matches_sessions() {
        let t = small();
        let (a, b) = t.sessions[0][0];
        assert!(t.available(0, (a + b) / 2.0));
        assert!(!t.available(0, b + 1.0) || t.session_at(0, b + 1.0).is_some());
    }

    #[test]
    fn wraps_cyclically() {
        let t = small();
        let (a, b) = t.sessions[5][0];
        let mid = (a + b) / 2.0;
        assert!(t.available(5, mid + WEEK));
        assert!(t.available(5, mid + 3.0 * WEEK));
    }

    #[test]
    fn available_through_checks_whole_interval() {
        let t = small();
        let (a, b) = t.sessions[2][0];
        assert!(t.available_through(2, a + 1.0, (b - a) / 2.0));
        assert!(!t.available_through(2, a + 1.0, (b - a) + 10_000.0));
        assert!(!t.available_through(2, b + 1e-6, 10.0) || t.available(2, b + 1e-6));
    }

    #[test]
    fn true_slot_availability_bounds() {
        let t = small();
        for l in 0..5 {
            let p = t.true_slot_availability(l, 100.0, 400.0);
            assert!((0.0..=1.0).contains(&p));
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let t = TraceSet::generate(5, 8, TraceConfig::default());
        let path = std::env::temp_dir().join("relay_trace_test.json");
        t.save(&path).unwrap();
        let l = TraceSet::load(&path).unwrap();
        assert_eq!(t.sessions.len(), l.sessions.len());
        for (a, b) in t.sessions.iter().zip(&l.sessions) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x.0 - y.0).abs() < 1e-9 && (x.1 - y.1).abs() < 1e-9);
            }
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn lazy_is_actually_lazy_and_identical() {
        let eager = TraceSet::generate(20, 6, TraceConfig::default());
        let lazy = LazyTraceSet::new(20, 6, TraceConfig::default());
        assert_eq!(lazy.materialized(), 0);
        // out-of-order touches must not perturb anything
        assert_eq!(eager.sessions[13].as_slice(), lazy.sessions(13));
        assert_eq!(lazy.materialized(), 1);
        for l in 0..20 {
            assert_eq!(eager.sessions[l].as_slice(), lazy.sessions(l), "learner {l}");
        }
        assert_eq!(lazy.materialized(), 20);
        // query surface agrees too
        for l in (0..20).step_by(3) {
            for t in [0.0, 1234.5, 3.2 * DAY, WEEK + 777.0] {
                assert_eq!(eager.available(l, t), lazy.available(l, t));
                assert_eq!(
                    eager.available_through(l, t, 600.0),
                    lazy.available_through(l, t, 600.0)
                );
            }
            assert_eq!(eager.sample_series(l, 1800.0), lazy.sample_series(l, 1800.0));
        }
    }

    #[test]
    fn sample_series_binary() {
        let t = small();
        let s = t.sample_series(0, 600.0);
        assert_eq!(s.len(), (WEEK / 600.0) as usize);
        assert!(s.iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(s.iter().sum::<f64>() > 0.0);
    }
}
