//! # RELAY — Resource-Efficient Federated Learning
//!
//! Rust implementation of the RELAY federated-learning system
//! (Abdelmoniem et al.): intelligent participant selection (IPS) +
//! staleness-aware aggregation (SAA) over a FedAvg/YoGi stack, plus every
//! substrate the paper's evaluation depends on (device-heterogeneity
//! profiles, availability traces, data partitioners, the Oort and SAFA
//! baselines, an availability forecaster, and an event-driven simulator).
//!
//! Model math is AOT-compiled from JAX/Pallas to HLO (`make artifacts`) and
//! executed through the PJRT CPU client (`runtime`); Python never runs on
//! the round path.
//!
//! See `DESIGN.md` for the full inventory and the per-figure experiment
//! index, and `examples/` for entry points.

// Style-only lints that are endemic to this codebase and noisy under CI's
// `clippy -D warnings`: kernel-style numeric code favors explicit indexed
// loops, the no-deps `util::json::Json` ships an inherent `to_string`, and
// config-heavy tests build values by mutating `Default::default()`.
#![allow(
    clippy::needless_range_loop,
    clippy::inherent_to_string,
    clippy::field_reassign_with_default
)]

pub mod util;
pub mod runtime;

pub mod data;
pub mod learners;
pub mod trace;
pub mod forecast;
pub mod sim;
pub mod selection;
pub mod population;
pub mod aggregation;
pub mod metrics;
pub mod config;
pub mod coordinator;
pub mod jobs;
pub mod runlog;
pub mod scenario;
pub mod sweep;
pub mod telemetry;
pub mod figures;
