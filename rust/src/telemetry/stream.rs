//! The streaming reducer: one event in, live metrics out.
//!
//! [`TelemetryStream`] wraps the *same* [`RunReducer`] that powers
//! `runlog::replay` — the stream never re-implements any accounting, it
//! only layers metrics on top (distributions, per-cause waste attribution,
//! event-kind counters). Feeding a complete log through [`step`] and
//! calling [`result`] therefore produces the byte-identical
//! `ExperimentResult` that `replay()` would — tested against every
//! golden-matrix cell.
//!
//! Waste attribution works by observing the reducer's cumulative `wasted`
//! total across each step: whatever one event added is charged to that
//! event's cause (crash, dropout, corrupt, doomed, stale-discard,
//! leftover). The deltas telescope, so the per-cause gauges always sum to
//! the reducer's total — no thresholds or staleness rules are duplicated
//! here.
//!
//! [`step`]: TelemetryStream::step
//! [`result`]: TelemetryStream::result

use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::jobs::{MultiJobReducer, MultiJobResult};
use crate::metrics::ExperimentResult;
use crate::runlog::replay::{LiveStats, RunReducer};
use crate::runlog::{EventObserver, RunEvent, FATE_DOOMED, FATE_TRAINED};
use crate::scenario::faults::FaultKind;
use crate::util::json::{num, obj, s, Json};

use super::metrics::MetricsRegistry;

/// Staleness (rounds/versions behind) bucket edges.
pub const STALENESS_BUCKETS: &[f64] =
    &[0.0, 1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0];

/// Per-task device-seconds bucket edges.
pub const TASK_SECS_BUCKETS: &[f64] =
    &[1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0, 2000.0];

/// Per-round simulated-duration bucket edges.
pub const ROUND_SECS_BUCKETS: &[f64] =
    &[1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0, 5000.0];

/// Per-round selection-size bucket edges.
pub const SELECTED_BUCKETS: &[f64] =
    &[1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0];

fn fault_counter_name(kind: u8) -> &'static str {
    match FaultKind::from_code(kind) {
        Some(FaultKind::Flap) => "faults.flap",
        Some(FaultKind::Crash) => "faults.crash",
        Some(FaultKind::Delay) => "faults.delay",
        Some(FaultKind::Corrupt) => "faults.corrupt",
        Some(FaultKind::Duplicate) => "faults.duplicate",
        None => "faults.unknown",
    }
}

/// Incremental telemetry over a run-log event stream. Infallible by
/// design: a malformed stream records an `error` string and degrades to
/// raw event counting (a live dashboard must keep rendering even when the
/// log turns out broken; the strictness lives in [`result`]).
///
/// [`result`]: TelemetryStream::result
pub struct TelemetryStream {
    reducer: RunReducer,
    /// Multi-job logs (`JobSetStart` header) route here instead of the
    /// single-job reducer; decided by the stream's first event.
    multi: Option<MultiJobReducer>,
    registry: MetricsRegistry,
    events: u64,
    error: Option<String>,
    /// Learners whose most recent fault decision was a crash — used to
    /// attribute their eventual dropout's waste to `waste.crash`.
    crash_flagged: HashSet<u64>,
    started_wall: Option<Instant>,
}

impl Default for TelemetryStream {
    fn default() -> Self {
        TelemetryStream::new()
    }
}

impl TelemetryStream {
    pub fn new() -> TelemetryStream {
        TelemetryStream {
            reducer: RunReducer::new(),
            multi: None,
            registry: MetricsRegistry::new(),
            events: 0,
            error: None,
            crash_flagged: HashSet::new(),
            started_wall: None,
        }
    }

    /// Consume one event: metrics first (they only read the pre-step
    /// reducer), then the shared reducer itself.
    pub fn step(&mut self, ev: &RunEvent) {
        self.events += 1;
        self.started_wall.get_or_insert_with(Instant::now);
        self.observe_event(ev);
        if self.error.is_some() {
            return;
        }
        // A `JobSetStart` opening the stream routes everything to the
        // multi-job reducer; mid-stream it falls through to the single-job
        // reducer, whose header check rejects it with a pointed message.
        if self.multi.is_none()
            && self.reducer.header().is_none()
            && matches!(ev, RunEvent::JobSetStart { .. })
        {
            match MultiJobReducer::start(ev) {
                Ok(m) => self.multi = Some(m),
                Err(e) => self.error = Some(format!("{e:#}")),
            }
            return;
        }
        if let Some(multi) = &mut self.multi {
            if let Err(e) = multi.step(ev) {
                self.error = Some(format!("{e:#}"));
                return;
            }
            let book = multi.book();
            for j in 0..book.len() {
                if let Some(b) = book.job(j) {
                    self.registry.set_gauge(&format!("job{j}.spent"), b.spent_secs);
                    self.registry
                        .set_gauge(&format!("job{j}.aggregated"), b.aggregated_secs);
                    self.registry.set_gauge(&format!("job{j}.wasted"), b.wasted_secs);
                    self.registry
                        .set_gauge(&format!("job{j}.in_flight"), b.in_flight_secs);
                }
            }
            return;
        }
        let wasted_before = self.reducer.wasted();
        let recs_before = self.reducer.records().len();
        if let Err(e) = self.reducer.step(ev) {
            self.error = Some(format!("{e:#}"));
            return;
        }
        let wasted_delta = self.reducer.wasted() - wasted_before;
        if wasted_delta > 0.0 {
            let cause = self.waste_cause(ev);
            self.registry.add_gauge(cause, wasted_delta);
        }
        let new_recs: Vec<(f64, usize)> = self.reducer.records()[recs_before..]
            .iter()
            .map(|r| (r.round_duration, r.selected))
            .collect();
        for (dur, selected) in new_recs {
            self.registry.observe("round_secs", ROUND_SECS_BUCKETS, dur);
            self.registry
                .observe("round_selected", SELECTED_BUCKETS, selected as f64);
        }
    }

    /// Pre-step metrics: counters, distributions, fault bookkeeping. Uses
    /// only the event and the reducer's *pre-step* state (e.g. the current
    /// round for staleness), never its post-step state.
    fn observe_event(&mut self, ev: &RunEvent) {
        match ev {
            RunEvent::Eligibility { count } => {
                self.registry.set_gauge("eligible", *count as f64);
            }
            RunEvent::Selected { .. } => self.registry.inc("selected"),
            RunEvent::FaultDecision { kind, learner, .. } => {
                self.registry.inc(fault_counter_name(*kind));
                if FaultKind::from_code(*kind) == Some(FaultKind::Crash) {
                    self.crash_flagged.insert(*learner);
                }
            }
            RunEvent::TaskDropout { spent, .. } => {
                self.registry.inc("dropouts");
                self.registry.observe("task_secs", TASK_SECS_BUCKETS, *spent);
            }
            RunEvent::StragglerSpend { duration, .. } => {
                self.registry.observe("task_secs", TASK_SECS_BUCKETS, *duration);
            }
            RunEvent::FreshSpend { duration, .. } => {
                self.registry.observe("task_secs", TASK_SECS_BUCKETS, *duration);
            }
            RunEvent::Trained { .. } => self.registry.inc("trained"),
            RunEvent::StaleDelivery { origin_round, .. } => {
                self.registry.inc("stale_deliveries");
                if let Some(cur) = self.reducer.current_round() {
                    let tau = cur.saturating_sub(*origin_round);
                    self.registry
                        .observe("staleness", STALENESS_BUCKETS, tau as f64);
                }
            }
            RunEvent::EvalDone { .. } => self.registry.inc("evals"),
            RunEvent::RoundEnd { .. } => self.registry.inc("rounds_closed"),
            RunEvent::AsyncSpawn { duration, dropped_after, .. } => {
                self.registry.inc("selected");
                let secs = dropped_after.unwrap_or(*duration);
                self.registry.observe("task_secs", TASK_SECS_BUCKETS, secs);
            }
            RunEvent::AsyncDropout { .. } => self.registry.inc("dropouts"),
            RunEvent::AsyncDelivery { origin_version, corrupt, .. } => {
                if !corrupt {
                    self.registry.inc("trained");
                    if let Some(version) = self.reducer.current_round() {
                        let tau = version.saturating_sub(*origin_version);
                        self.registry
                            .observe("staleness", STALENESS_BUCKETS, tau as f64);
                    }
                }
            }
            RunEvent::MergeCommit { eval } => {
                self.registry.inc("merges");
                self.registry.inc("rounds_closed");
                if eval.is_some() {
                    self.registry.inc("evals");
                }
            }
            RunEvent::AsyncBurn { .. } => {
                self.registry.inc("burns");
                self.registry.inc("rounds_closed");
            }
            RunEvent::JobSpawn { duration, dropped_after, .. } => {
                self.registry.inc("selected");
                let secs = dropped_after.unwrap_or(*duration);
                self.registry.observe("task_secs", TASK_SECS_BUCKETS, secs);
                if dropped_after.is_some() {
                    self.registry.inc("dropouts");
                }
            }
            RunEvent::JobDelivery { fate, .. } => {
                if *fate == FATE_TRAINED {
                    self.registry.inc("trained");
                }
            }
            RunEvent::JobRoundEnd { eval_loss, .. } => {
                self.registry.inc("rounds_closed");
                if eval_loss.is_some() {
                    self.registry.inc("evals");
                }
            }
            RunEvent::RunStart { .. }
            | RunEvent::RoundStart { .. }
            | RunEvent::KernelPop { .. }
            | RunEvent::SweepLeftover { .. }
            | RunEvent::RunEnd
            | RunEvent::JobSetStart { .. }
            | RunEvent::JobStart { .. }
            | RunEvent::JobRoundStart { .. }
            | RunEvent::JobSweep { .. }
            | RunEvent::JobSetEnd => {}
        }
    }

    /// Which per-cause gauge the waste one event produced belongs to.
    fn waste_cause(&mut self, ev: &RunEvent) -> &'static str {
        match ev {
            RunEvent::TaskDropout { learner, .. } | RunEvent::AsyncDropout { learner, .. } => {
                if self.crash_flagged.remove(learner) {
                    "waste.crash"
                } else {
                    "waste.dropout"
                }
            }
            RunEvent::StragglerSpend { fate, .. } => {
                if *fate == FATE_DOOMED {
                    "waste.doomed"
                } else {
                    "waste.corrupt"
                }
            }
            RunEvent::FreshSpend { .. } => "waste.corrupt",
            RunEvent::AsyncDelivery { corrupt, .. } => {
                if *corrupt {
                    "waste.corrupt"
                } else {
                    "waste.stale_discard"
                }
            }
            RunEvent::StaleDelivery { .. } | RunEvent::MergeCommit { .. } => {
                "waste.stale_discard"
            }
            RunEvent::SweepLeftover { .. } => "waste.leftover",
            _ => "waste.other",
        }
    }

    /// Events consumed so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Run label from whichever header the stream saw (empty before any).
    pub fn label(&self) -> &str {
        match &self.multi {
            Some(m) => m.label(),
            None => self.reducer.label(),
        }
    }

    /// The stream saw a clean `RunEnd` (or `JobSetEnd` on multi-job logs).
    pub fn complete(&self) -> bool {
        match &self.multi {
            Some(m) => m.ended(),
            None => self.reducer.ended(),
        }
    }

    /// The first reduction error, if the stream turned out malformed.
    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    pub fn live(&self) -> LiveStats {
        match &self.multi {
            Some(m) => m.live(),
            None => self.reducer.live(),
        }
    }

    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    pub fn reducer(&self) -> &RunReducer {
        &self.reducer
    }

    /// Human-readable mode name from the header, once seen.
    pub fn mode_name(&self) -> Option<&'static str> {
        if self.multi.is_some() {
            return Some("multi-job");
        }
        self.reducer.header().map(|h| match h.mode {
            0 => "over-commit",
            1 => "deadline",
            _ => "async",
        })
    }

    /// The final result — exactly what `runlog::replay` would derive,
    /// because it *is* the shared reducer's result. Errors while the run
    /// is still in flight or the stream was malformed.
    pub fn result(&self) -> Result<ExperimentResult> {
        if let Some(e) = &self.error {
            bail!("telemetry stream is degraded: {e}");
        }
        if let Some(m) = &self.multi {
            if !m.ended() {
                bail!("telemetry stream: multi-job run still in flight");
            }
            return Ok(m.result().summary_result());
        }
        self.reducer.result()
    }

    /// The full per-job result, when the stream is a multi-job log. Partial
    /// (best-effort) before `JobSetEnd`, exactly like the reducer's.
    pub fn multi_result(&self) -> Option<MultiJobResult> {
        self.multi.as_ref().map(|m| m.result())
    }

    /// One machine-readable snapshot of everything the stream knows.
    /// `wall_secs` is the only wall-clock quantity anywhere near the
    /// result path, and it lives only here.
    pub fn snapshot(&self) -> Json {
        let live = self.live();
        let wall = self
            .started_wall
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        obj(vec![
            ("format", s("relay-telemetry-v1")),
            ("label", s(self.label())),
            (
                "mode",
                self.mode_name().map(s).unwrap_or(Json::Null),
            ),
            ("events", num(self.events as f64)),
            ("complete", Json::Bool(live.complete)),
            ("rounds_done", num(live.rounds_done as f64)),
            ("rounds_total", num(live.rounds_total as f64)),
            ("sim_time", num(live.sim_time)),
            ("wall_secs", num(wall)),
            ("spent_secs", num(live.spent)),
            ("aggregated_secs", num(live.aggregated)),
            ("wasted_secs", num(live.wasted)),
            ("in_flight_secs", num(live.in_flight_secs)),
            ("outstanding", num(live.outstanding as f64)),
            ("buffer_fill", num(live.buffer_fill as f64)),
            ("unique_participants", num(live.unique_participants as f64)),
            (
                "error",
                self.error.as_deref().map(s).unwrap_or(Json::Null),
            ),
            ("metrics", self.registry.to_json()),
        ])
    }
}

/// A cloneable, thread-safe handle over one [`TelemetryStream`] — the
/// in-process live hook. Hand [`observer`] to a `RunLogger` and read
/// snapshots from any other thread while the run executes.
///
/// [`observer`]: SharedStream::observer
#[derive(Clone)]
pub struct SharedStream(Arc<Mutex<TelemetryStream>>);

impl Default for SharedStream {
    fn default() -> Self {
        SharedStream::new()
    }
}

impl SharedStream {
    pub fn new() -> SharedStream {
        SharedStream(Arc::new(Mutex::new(TelemetryStream::new())))
    }

    /// Run `f` under the lock (poison-recovering: telemetry must never
    /// take a run down with it).
    pub fn with<T>(&self, f: impl FnOnce(&mut TelemetryStream) -> T) -> T {
        let mut guard = self.0.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        f(&mut guard)
    }

    pub fn snapshot(&self) -> Json {
        self.with(|stream| stream.snapshot())
    }

    pub fn complete(&self) -> bool {
        self.with(|stream| stream.complete())
    }

    /// An [`EventObserver`] feeding this stream, for
    /// `RunLogger::observing` / `with_observer`.
    pub fn observer(&self) -> Box<dyn EventObserver> {
        Box::new(Forwarder(self.clone()))
    }
}

struct Forwarder(SharedStream);

impl EventObserver for Forwarder {
    fn observe(&mut self, ev: &RunEvent) {
        self.0.with(|stream| stream.step(ev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runlog::{replay, FATE_TRAINED};

    fn sync_log() -> Vec<RunEvent> {
        vec![
            RunEvent::RunStart {
                label: "t".into(),
                perplexity: false,
                mode: 0,
                buffer_k: 0,
                max_staleness: None,
                rounds: 1,
                eval_every: 1,
                use_saa: true,
                staleness_threshold: Some(2),
            },
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Eligibility { count: 5 },
            RunEvent::Selected { learner: 1 },
            RunEvent::Selected { learner: 2 },
            RunEvent::FaultDecision { kind: 1, learner: 2, round: 0 },
            RunEvent::TaskDropout { learner: 2, spent: 4.0 },
            RunEvent::FreshSpend { learner: 1, duration: 10.0, corrupt: false },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 10.0, fresh: true },
            RunEvent::EvalDone { loss: 1.0, acc: 0.25 },
            RunEvent::RoundEnd { round_duration: 12.0 },
            RunEvent::SweepLeftover { secs: 0.0 },
            RunEvent::RunEnd,
        ]
    }

    #[test]
    fn stream_result_matches_batch_replay_exactly() {
        let log = sync_log();
        let mut stream = TelemetryStream::new();
        for ev in &log {
            stream.step(ev);
        }
        assert!(stream.complete());
        assert!(stream.error().is_none());
        let streamed = stream.result().expect("stream result");
        let replayed = replay(&log).expect("batch replay");
        assert_eq!(
            streamed.to_json().to_string(),
            replayed.to_json().to_string(),
            "shared reducer must make the stream and batch replay identical"
        );
    }

    #[test]
    fn waste_gauges_sum_to_reducer_total_and_name_causes() {
        let log = sync_log();
        let mut stream = TelemetryStream::new();
        for ev in &log {
            stream.step(ev);
        }
        let total: f64 = stream
            .registry()
            .gauges_with_prefix("waste.")
            .map(|(_, v)| v)
            .sum();
        let wasted = stream.live().wasted;
        assert!(
            (total - wasted).abs() <= 1e-9 * wasted.abs().max(1.0),
            "per-cause waste {total} must sum to the reducer's {wasted}"
        );
        // learner 2 crashed: its dropout waste lands in waste.crash
        assert_eq!(stream.registry().gauge("waste.crash"), 4.0);
        assert_eq!(stream.registry().counter("faults.crash"), 1);
        assert_eq!(stream.registry().counter("selected"), 2);
        assert_eq!(stream.registry().counter("dropouts"), 1);
    }

    #[test]
    fn malformed_stream_degrades_instead_of_panicking() {
        let mut stream = TelemetryStream::new();
        // log opens with a non-header event: reducer errors, stream keeps
        // counting
        stream.step(&RunEvent::RunEnd);
        stream.step(&RunEvent::RunEnd);
        assert_eq!(stream.events(), 2);
        assert!(stream.error().is_some());
        assert!(!stream.complete());
        assert!(stream.result().is_err());
        let snap = stream.snapshot().to_string();
        assert!(Json::parse(&snap).is_ok(), "{snap}");
        assert!(snap.contains("\"error\""));
    }

    #[test]
    fn staleness_histogram_sees_delivery_tau() {
        let log = vec![
            RunEvent::RunStart {
                label: "s".into(),
                perplexity: false,
                mode: 1,
                buffer_k: 0,
                max_staleness: None,
                rounds: 2,
                eval_every: 5,
                use_saa: true,
                staleness_threshold: Some(2),
            },
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Selected { learner: 1 },
            RunEvent::StragglerSpend { learner: 1, duration: 8.0, fate: FATE_TRAINED },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 8.0, fresh: false },
            RunEvent::RoundEnd { round_duration: 4.0 },
            RunEvent::RoundStart { round: 1, now: 4.0 },
            RunEvent::Selected { learner: 2 },
            RunEvent::FreshSpend { learner: 2, duration: 3.0, corrupt: false },
            RunEvent::Trained { learner: 2, mean_loss: 0.4, duration: 3.0, fresh: true },
            RunEvent::StaleDelivery { learner: 1, origin_round: 0, duration: 8.0 },
            RunEvent::RoundEnd { round_duration: 5.0 },
            RunEvent::SweepLeftover { secs: 0.0 },
            RunEvent::RunEnd,
        ];
        let mut stream = TelemetryStream::new();
        for ev in &log {
            stream.step(ev);
        }
        let hist = stream.registry().histogram("staleness").expect("staleness hist");
        assert_eq!(hist.count(), 1);
        assert_eq!(hist.sum(), 1.0, "delivered one round late");
    }

    fn multijob_log() -> Vec<RunEvent> {
        use crate::runlog::FATE_CORRUPT;
        vec![
            RunEvent::JobSetStart {
                label: "mj".into(),
                jobs: 2,
                policy: "fair".into(),
                rounds: 1,
                eval_every: 1,
            },
            RunEvent::JobStart {
                job: 0,
                selector: "random".into(),
                mode: "oc1.3".into(),
                target: 2,
                priority: 0,
            },
            RunEvent::JobStart {
                job: 1,
                selector: "oort".into(),
                mode: "dl40".into(),
                target: 1,
                priority: 0,
            },
            RunEvent::JobRoundStart { job: 0, round: 0, now: 0.0 },
            RunEvent::JobRoundStart { job: 1, round: 0, now: 0.0 },
            RunEvent::JobSpawn {
                job: 0,
                learner: 3,
                now: 0.0,
                duration: 10.0,
                dropped_after: None,
                corrupt: false,
            },
            RunEvent::JobSpawn {
                job: 0,
                learner: 4,
                now: 0.0,
                duration: 30.0,
                dropped_after: Some(12.5),
                corrupt: false,
            },
            RunEvent::JobSpawn {
                job: 1,
                learner: 5,
                now: 0.0,
                duration: 20.0,
                dropped_after: None,
                corrupt: true,
            },
            RunEvent::JobDelivery {
                job: 0,
                learner: 3,
                duration: 10.0,
                mean_loss: 0.5,
                fate: FATE_TRAINED,
            },
            RunEvent::JobDelivery {
                job: 1,
                learner: 5,
                duration: 20.0,
                mean_loss: 0.0,
                fate: FATE_CORRUPT,
            },
            RunEvent::JobRoundEnd {
                job: 0,
                round: 0,
                now: 10.0,
                round_duration: 10.0,
                fresh: 1,
                failed: false,
                train_loss: Some(0.5),
                eval_loss: Some(1.0),
                eval_acc: Some(0.25),
            },
            RunEvent::JobRoundEnd {
                job: 1,
                round: 0,
                now: 25.0,
                round_duration: 25.0,
                fresh: 0,
                failed: true,
                train_loss: None,
                eval_loss: Some(2.0),
                eval_acc: Some(0.25),
            },
            RunEvent::JobSweep { job: 0, secs: 0.0 },
            RunEvent::JobSweep { job: 1, secs: 0.0 },
            RunEvent::JobSetEnd,
        ]
    }

    #[test]
    fn multijob_stream_routes_to_the_multijob_reducer() {
        use crate::jobs::replay_multijob;
        let log = multijob_log();
        let mut stream = TelemetryStream::new();
        for ev in &log {
            stream.step(ev);
        }
        assert!(stream.complete());
        assert!(stream.error().is_none(), "{:?}", stream.error());
        assert_eq!(stream.mode_name(), Some("multi-job"));
        // summary result == what the standalone multi-job replay derives
        let streamed = stream.result().expect("stream result");
        let replayed = replay_multijob(&log).expect("multijob replay");
        assert_eq!(
            streamed.to_json().to_string(),
            replayed.summary_result().to_json().to_string()
        );
        let full = stream.multi_result().expect("multi result");
        assert_eq!(full.jobs.len(), 2);
        assert_eq!(full.fleet_spent_secs, 42.5);
        // fleet-level live view and the per-job gauges agree with the books
        let live = stream.live();
        assert!(live.complete);
        assert_eq!(live.spent, 42.5);
        assert_eq!(stream.registry().gauge("job0.spent"), 22.5);
        assert_eq!(stream.registry().gauge("job1.wasted"), 20.0);
        // event-kind counters: 3 claims, 1 trained delivery, 1 dropout
        assert_eq!(stream.registry().counter("selected"), 3);
        assert_eq!(stream.registry().counter("trained"), 1);
        assert_eq!(stream.registry().counter("dropouts"), 1);
        assert_eq!(stream.registry().counter("rounds_closed"), 2);
        assert_eq!(stream.registry().counter("evals"), 2);
        // snapshot renders valid JSON with the multi-job label and mode
        let snap = stream.snapshot().to_string();
        let parsed = Json::parse(&snap).unwrap();
        assert_eq!(parsed.get("label").and_then(|l| l.as_str()), Some("mj"));
        assert_eq!(parsed.get("mode").and_then(|m| m.as_str()), Some("multi-job"));
    }

    #[test]
    fn multijob_stream_degrades_on_divergent_logs() {
        let mut log = multijob_log();
        // claim job 0 merged two fresh updates when the stream shows one
        if let RunEvent::JobRoundEnd { fresh, .. } = &mut log[10] {
            *fresh = 2;
        } else {
            panic!("fixture drifted");
        }
        let mut stream = TelemetryStream::new();
        for ev in &log {
            stream.step(ev);
        }
        assert!(stream.error().is_some());
        assert!(!stream.complete());
        assert!(stream.result().is_err());
        assert!(Json::parse(&stream.snapshot().to_string()).is_ok());
    }

    #[test]
    fn shared_stream_forwards_through_observer() {
        let shared = SharedStream::new();
        let mut observer = shared.observer();
        for ev in &sync_log() {
            observer.observe(ev);
        }
        assert!(shared.complete());
        let result = shared.with(|s| s.result()).expect("shared result");
        let replayed = replay(&sync_log()).expect("replay");
        assert_eq!(result.to_json().to_string(), replayed.to_json().to_string());
    }
}
