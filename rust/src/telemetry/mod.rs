//! Live telemetry over the run log: streaming observability that never
//! touches the result path.
//!
//! The run log (`runlog/`) already witnesses every accounting-relevant
//! engine event. This module turns that stream into *live* metrics three
//! ways, strictly layered so observing a run can never change it:
//!
//! * [`metrics`] — a dependency-free registry of counters, gauges, and
//!   fixed-bucket histograms with deterministic JSON export;
//! * [`stream`] — [`TelemetryStream`]: an incremental consumer that feeds
//!   each event to the *same* [`runlog::replay::RunReducer`] the batch
//!   replay oracle runs, plus a metrics layer on top (staleness
//!   distribution, per-fault-kind waste attribution, round timings). Since
//!   the reducer is shared code — not a parallel reimplementation — the
//!   stream's final `ExperimentResult` is byte-identical to `relay replay`
//!   by construction, and the golden-matrix test pins it;
//! * [`watch`] — the `relay watch` surfaces: a polling loop over the
//!   [`runlog::tail::DirTailer`] with a plain-terminal dashboard, JSONL
//!   snapshot export for machines, and `--once` for CI;
//! * [`progress`] — the wall-clock progress/ETA meter `sweep/` and the
//!   watcher both report through.
//!
//! Wall-clock time appears **only** here (snapshot `wall_secs`, ETA
//! lines): `ExperimentResult` stays purely simulated-time so runs remain
//! byte-reproducible. The in-engine hook is an [`runlog::EventObserver`]
//! behind the same closure discipline as the `RunLogger` sink — unobserved
//! runs construct no events and stay byte-identical.
//!
//! [`runlog::replay::RunReducer`]: crate::runlog::replay::RunReducer
//! [`runlog::tail::DirTailer`]: crate::runlog::tail::DirTailer
//! [`runlog::EventObserver`]: crate::runlog::EventObserver
//! [`TelemetryStream`]: stream::TelemetryStream

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod metrics;
pub mod progress;
pub mod stream;
pub mod watch;

pub use metrics::{Histogram, MetricsRegistry};
pub use progress::ProgressMeter;
pub use stream::{SharedStream, TelemetryStream};
pub use watch::{watch_dir, WatchOpts};
