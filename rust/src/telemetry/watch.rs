//! `relay watch`: tail a run-log directory and surface live state.
//!
//! Three consumption modes over one loop:
//!
//! * **dashboard** (default) — re-render a plain-terminal summary each
//!   poll interval until the run completes;
//! * **`--jsonl`** — emit one machine-readable snapshot line whenever new
//!   events arrive (and a final one at completion);
//! * **`--once`** — poll a single time, render once, exit: the scripted /
//!   CI mode, whose exported result must byte-match `relay replay`.
//!
//! The watcher only ever *reads* segment files; the writer never knows it
//! exists.

use std::io::Write;
use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::runlog::tail::{DirTailer, TailStats};
use crate::util::json::Json;

use super::progress::ProgressMeter;
use super::stream::TelemetryStream;

/// Knobs for [`watch_dir`].
pub struct WatchOpts {
    /// Poll once and exit instead of following the log.
    pub once: bool,
    /// Emit JSONL snapshots instead of the dashboard.
    pub jsonl: bool,
    /// Sleep between polls when following.
    pub interval_ms: u64,
    /// Prefix each dashboard render with an ANSI clear (interactive
    /// terminals only; piped output stays appendable).
    pub clear_screen: bool,
    /// Stop after this many polls even if the run never completes
    /// (tests and bounded CI follows).
    pub max_polls: Option<u64>,
}

impl Default for WatchOpts {
    fn default() -> Self {
        WatchOpts {
            once: false,
            jsonl: false,
            interval_ms: 500,
            clear_screen: false,
            max_polls: None,
        }
    }
}

/// Tail `dir` until the run completes (or `once` / `max_polls` stops the
/// loop), writing dashboards or JSONL snapshots to `out`. Returns the
/// stream so callers can export the final result / snapshot.
pub fn watch_dir(dir: &Path, opts: &WatchOpts, out: &mut dyn Write) -> Result<TelemetryStream> {
    let mut tailer = DirTailer::open(dir);
    let mut stream = TelemetryStream::new();
    let mut meter: Option<ProgressMeter> = None;
    let mut polls: u64 = 0;
    loop {
        let events = tailer.poll().with_context(|| {
            format!("cannot tail run log under {}", dir.display())
        })?;
        for ev in &events {
            stream.step(ev);
        }
        // the round-progress clock starts when the header announces the
        // round count, not when the watcher was launched
        if meter.is_none() {
            let total = stream.live().rounds_total;
            if total > 0 {
                meter = Some(ProgressMeter::start("watch", total as usize));
            }
        }
        polls += 1;
        if opts.jsonl {
            // snapshot on every poll that changed something, plus the
            // first and last so consumers always see at least one line
            if !events.is_empty() || polls == 1 || stream.complete() {
                writeln!(out, "{}", stream.snapshot().to_string())?;
            }
        } else if !opts.once {
            render(&stream, tailer.stats(), meter.as_ref(), opts.clear_screen, out)?;
        }
        if opts.once || stream.complete() {
            break;
        }
        if let Some(max) = opts.max_polls {
            if polls >= max {
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(opts.interval_ms.max(1)));
    }
    if opts.once && !opts.jsonl {
        render(&stream, tailer.stats(), meter.as_ref(), false, out)?;
    }
    Ok(stream)
}

fn pct(part: f64, whole: f64) -> f64 {
    if whole > 0.0 {
        100.0 * part / whole
    } else {
        0.0
    }
}

/// One histogram summary line: count, mean, and coarse quantile edges.
fn hist_line(stream: &TelemetryStream, name: &str) -> Option<String> {
    let h = stream.registry().histogram(name)?;
    let q = |q: f64| match h.quantile_edge(q) {
        Some(edge) => format!("<={edge}"),
        None => "overflow".to_string(),
    };
    Some(format!(
        "  {name:<16} n={} mean={:.2} p50{} p90{} p99{}",
        h.count(),
        h.mean().unwrap_or(0.0),
        q(0.5),
        q(0.9),
        q(0.99),
    ))
}

/// Render the plain-text dashboard. Everything shown except `wall` is
/// simulated time derived from the log.
fn render(
    stream: &TelemetryStream,
    tail: &TailStats,
    meter: Option<&ProgressMeter>,
    clear: bool,
    out: &mut dyn Write,
) -> Result<()> {
    if clear {
        write!(out, "\x1b[2J\x1b[H")?;
    }
    let live = stream.live();
    let reg = stream.registry();
    let status = if stream.complete() {
        "complete"
    } else if stream.error().is_some() {
        "DEGRADED"
    } else if stream.events() == 0 {
        "waiting for events"
    } else {
        "running"
    };
    writeln!(
        out,
        "watch: {} [{}] — {status}",
        if stream.label().is_empty() {
            "(no header yet)"
        } else {
            stream.label()
        },
        stream.mode_name().unwrap_or("?"),
    )?;
    writeln!(
        out,
        "  rounds {}/{}  sim_time {:.1}s  events {}  segments {}",
        live.rounds_done,
        live.rounds_total,
        live.sim_time,
        stream.events(),
        tail.segments_finalized + 1,
    )?;
    writeln!(
        out,
        "  device-secs: spent {:.1} = aggregated {:.1} ({:.1}%) + wasted {:.1} ({:.1}%) + in-flight {:.1}",
        live.spent,
        live.aggregated,
        pct(live.aggregated, live.spent),
        live.wasted,
        pct(live.wasted, live.spent),
        live.in_flight_secs,
    )?;
    writeln!(
        out,
        "  participants {}  outstanding {}  buffer {}  eligible {:.0}",
        live.unique_participants,
        live.outstanding,
        live.buffer_fill,
        reg.gauge("eligible"),
    )?;
    let waste: Vec<String> = reg
        .gauges_with_prefix("waste.")
        .map(|(k, v)| format!("{}={v:.1}", k.trim_start_matches("waste.")))
        .collect();
    if !waste.is_empty() {
        writeln!(out, "  waste by cause: {}", waste.join(" "))?;
    }
    let faults: Vec<String> = ["flap", "crash", "delay", "corrupt", "duplicate"]
        .iter()
        .filter_map(|k| {
            let n = reg.counter(&format!("faults.{k}"));
            (n > 0).then(|| format!("{k}={n}"))
        })
        .collect();
    if !faults.is_empty() {
        writeln!(out, "  faults: {}", faults.join(" "))?;
    }
    for name in ["staleness", "task_secs", "round_secs", "round_selected"] {
        if let Some(line) = hist_line(stream, name) {
            writeln!(out, "{line}")?;
        }
    }
    if let Some(rec) = stream.reducer().records().last() {
        if let (Some(loss), Some(acc)) = (rec.test_loss, rec.test_accuracy) {
            writeln!(
                out,
                "  last eval (round {}): loss {loss:.4} acc {acc:.4}",
                rec.round
            )?;
        }
    }
    for note in &tail.skipped {
        writeln!(out, "  skipped: {note}")?;
    }
    if let Some(err) = stream.error() {
        writeln!(out, "  stream error: {err}")?;
    }
    if let Some(meter) = meter {
        if !stream.complete() && live.rounds_done > 0 {
            writeln!(out, "{}", meter.line_at(live.rounds_done, "rounds"))?;
        }
    }
    Ok(())
}

/// Parse one JSONL snapshot line back (round-trip helper for tests and
/// downstream tooling).
pub fn parse_snapshot(line: &str) -> Result<Json> {
    Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("bad snapshot line: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runlog::{DirSink, LogSink, RunEvent, RunLogger};

    fn write_log(dir: &Path, events: &[RunEvent]) {
        let sink = DirSink::create(dir).expect("create log dir");
        let mut logger = RunLogger::new(Box::new(sink) as Box<dyn LogSink>);
        for ev in events {
            let ev = ev.clone();
            logger.emit(move || ev);
        }
        logger.finish().expect("finish log");
    }

    fn tiny_log() -> Vec<RunEvent> {
        vec![
            RunEvent::RunStart {
                label: "w".into(),
                perplexity: false,
                mode: 0,
                buffer_k: 0,
                max_staleness: None,
                rounds: 1,
                eval_every: 1,
                use_saa: true,
                staleness_threshold: None,
            },
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Selected { learner: 1 },
            RunEvent::FreshSpend { learner: 1, duration: 2.0, corrupt: false },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 2.0, fresh: true },
            RunEvent::EvalDone { loss: 1.0, acc: 0.5 },
            RunEvent::RoundEnd { round_duration: 3.0 },
            RunEvent::SweepLeftover { secs: 0.0 },
            RunEvent::RunEnd,
        ]
    }

    #[test]
    fn once_mode_renders_and_returns_complete_stream() {
        let dir = std::env::temp_dir()
            .join(format!("relay-watch-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_log(&dir, &tiny_log());
        let mut out = Vec::new();
        let opts = WatchOpts { once: true, ..WatchOpts::default() };
        let stream = watch_dir(&dir, &opts, &mut out).expect("watch --once");
        assert!(stream.complete());
        let text = String::from_utf8(out).expect("utf8 dashboard");
        assert!(text.contains("complete"), "{text}");
        assert!(text.contains("device-secs"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn jsonl_mode_emits_parseable_snapshots() {
        let dir = std::env::temp_dir()
            .join(format!("relay-watch-jsonl-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        write_log(&dir, &tiny_log());
        let mut out = Vec::new();
        let opts = WatchOpts { jsonl: true, ..WatchOpts::default() };
        let stream = watch_dir(&dir, &opts, &mut out).expect("watch --jsonl");
        assert!(stream.complete());
        let text = String::from_utf8(out).expect("utf8 jsonl");
        let lines: Vec<&str> = text.lines().collect();
        assert!(!lines.is_empty());
        for line in &lines {
            let snap = parse_snapshot(line).expect("snapshot parses");
            assert_eq!(
                snap.get("format").and_then(|f| f.as_str()),
                Some("relay-telemetry-v1")
            );
        }
        let last = parse_snapshot(lines.last().expect("last line")).expect("last snapshot");
        assert_eq!(last.get("complete").and_then(|c| c.as_bool()), Some(true));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_reports_waiting_instead_of_erroring() {
        // `relay watch DIR` before the run has created DIR: no decode
        // garbage, no nonzero exit — a dashboard saying it is waiting.
        let dir = std::env::temp_dir()
            .join(format!("relay-watch-nodir-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut out = Vec::new();
        let opts = WatchOpts { once: true, ..WatchOpts::default() };
        let stream = watch_dir(&dir, &opts, &mut out).expect("watch missing dir");
        assert_eq!(stream.events(), 0);
        assert!(!stream.complete());
        assert!(stream.error().is_none());
        let text = String::from_utf8(out).expect("utf8 dashboard");
        assert!(text.contains("waiting for events"), "{text}");
    }

    #[test]
    fn first_segment_after_watcher_start_is_picked_up() {
        // the watcher starts against a directory that does not exist yet;
        // the run creates it and writes its first segment afterwards — the
        // follow loop must pick the log up and run to completion
        let dir = std::env::temp_dir()
            .join(format!("relay-watch-late-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let writer_dir = dir.clone();
        let writer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            write_log(&writer_dir, &tiny_log());
        });
        let mut out = Vec::new();
        let opts = WatchOpts {
            interval_ms: 5,
            max_polls: Some(2000),
            ..WatchOpts::default()
        };
        let stream = watch_dir(&dir, &opts, &mut out).expect("watch late log");
        writer.join().expect("writer thread");
        assert!(stream.complete(), "watcher must catch a log born after it");
        assert!(stream.error().is_none());
        assert!(stream.result().is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn multijob_log_watches_to_completion() {
        let dir = std::env::temp_dir()
            .join(format!("relay-watch-mj-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let events = vec![
            RunEvent::JobSetStart {
                label: "mj-watch".into(),
                jobs: 1,
                policy: "fair".into(),
                rounds: 1,
                eval_every: 1,
            },
            RunEvent::JobStart {
                job: 0,
                selector: "random".into(),
                mode: "oc1.3".into(),
                target: 1,
                priority: 0,
            },
            RunEvent::JobRoundStart { job: 0, round: 0, now: 0.0 },
            RunEvent::JobSpawn {
                job: 0,
                learner: 2,
                now: 0.0,
                duration: 5.0,
                dropped_after: None,
                corrupt: false,
            },
            RunEvent::JobDelivery {
                job: 0,
                learner: 2,
                duration: 5.0,
                mean_loss: 0.4,
                fate: crate::runlog::FATE_TRAINED,
            },
            RunEvent::JobRoundEnd {
                job: 0,
                round: 0,
                now: 5.0,
                round_duration: 5.0,
                fresh: 1,
                failed: false,
                train_loss: Some(0.4),
                eval_loss: Some(1.0),
                eval_acc: Some(0.5),
            },
            RunEvent::JobSweep { job: 0, secs: 0.0 },
            RunEvent::JobSetEnd,
        ];
        write_log(&dir, &events);
        let mut out = Vec::new();
        let opts = WatchOpts { once: true, ..WatchOpts::default() };
        let stream = watch_dir(&dir, &opts, &mut out).expect("watch multi-job");
        assert!(stream.complete());
        assert!(stream.error().is_none(), "{:?}", stream.error());
        let full = stream.multi_result().expect("multi result");
        assert_eq!(full.label, "mj-watch");
        assert_eq!(full.jobs.len(), 1);
        let text = String::from_utf8(out).expect("utf8 dashboard");
        assert!(text.contains("multi-job"), "{text}");
        assert!(text.contains("mj-watch"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn incomplete_log_stops_at_max_polls() {
        let dir = std::env::temp_dir()
            .join(format!("relay-watch-partial-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let events = tiny_log();
        write_log(&dir, &events[..4]);
        let mut out = Vec::new();
        let opts = WatchOpts {
            interval_ms: 1,
            max_polls: Some(3),
            ..WatchOpts::default()
        };
        let stream = watch_dir(&dir, &opts, &mut out).expect("bounded follow");
        assert!(!stream.complete());
        assert_eq!(stream.events(), 4);
        assert!(stream.result().is_err(), "mid-run result must error");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
