//! Dependency-free metrics registry: counters, gauges, and fixed-bucket
//! histograms with deterministic JSON export (BTreeMap ordering, so two
//! identical streams always serialize identically).

use std::collections::BTreeMap;

use crate::util::json::{arr, num, obj, s, Json};

/// Fixed-bucket histogram: `bounds` are ascending inclusive upper edges,
/// with one extra overflow bucket past the last bound. Buckets are chosen
/// at first observation and frozen — no rebinning, no allocation per
/// observe.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
        }
    }

    pub fn observe(&mut self, v: f64) {
        let i = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> Option<f64> {
        let n = self.count();
        (n > 0).then(|| self.sum / n as f64)
    }

    /// The smallest bucket upper edge covering at least `q` of the mass
    /// (`None` on an empty histogram; the overflow bucket reports `None`
    /// too since it has no finite edge).
    pub fn quantile_edge(&self, q: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, n) in self.counts.iter().enumerate() {
            seen += n;
            if seen >= target.max(1) {
                return self.bounds.get(i).copied();
            }
        }
        None
    }

    pub fn to_json(&self) -> Json {
        let mut buckets = Vec::with_capacity(self.counts.len());
        for (i, n) in self.counts.iter().enumerate() {
            let le = match self.bounds.get(i) {
                Some(b) => num(*b),
                None => s("+inf"),
            };
            buckets.push(obj(vec![("le", le), ("n", num(*n as f64))]));
        }
        obj(vec![
            ("count", num(self.count() as f64)),
            ("sum", num(self.sum)),
            ("buckets", arr(buckets)),
        ])
    }
}

/// Named counters (monotone u64), gauges (last-write or accumulated f64),
/// and histograms. Everything is created lazily on first touch so callers
/// never pre-register.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn add_gauge(&mut self, name: &str, dv: f64) {
        *self.gauges.entry(name.to_string()).or_insert(0.0) += dv;
    }

    /// Observe into a histogram, creating it with `bounds` on first touch.
    pub fn observe(&mut self, name: &str, bounds: &[f64], v: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Iterate gauges whose name starts with `prefix` (waste-by-cause
    /// rendering).
    pub fn gauges_with_prefix<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = (&'a str, f64)> + 'a {
        self.gauges
            .iter()
            .filter(move |(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), *v))
    }

    pub fn to_json(&self) -> Json {
        let counters = obj(self
            .counters
            .iter()
            .map(|(k, v)| (k.as_str(), num(*v as f64)))
            .collect());
        let gauges = obj(self
            .gauges
            .iter()
            .map(|(k, v)| (k.as_str(), num(*v)))
            .collect());
        let histograms = obj(self
            .histograms
            .iter()
            .map(|(k, h)| (k.as_str(), h.to_json()))
            .collect());
        obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(&[1.0, 2.0, 5.0]);
        for v in [0.5, 1.0, 1.5, 4.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 107.0);
        // 0.5 and 1.0 land in <=1, 1.5 in <=2, 4.0 in <=5, 100 overflows
        let json = h.to_json().to_string();
        assert!(json.contains("\"+inf\""), "{json}");
        assert_eq!(h.quantile_edge(0.5), Some(2.0));
        assert_eq!(h.quantile_edge(0.8), Some(5.0));
        assert_eq!(h.quantile_edge(1.0), None, "max sits in the overflow bucket");
    }

    #[test]
    fn registry_is_lazy_and_deterministic() {
        let mut reg = MetricsRegistry::new();
        reg.inc("b");
        reg.inc("a");
        reg.inc("a");
        reg.set_gauge("g", 2.5);
        reg.add_gauge("g", 0.5);
        reg.observe("h", &[1.0], 0.5);
        assert_eq!(reg.counter("a"), 2);
        assert_eq!(reg.counter("missing"), 0);
        assert_eq!(reg.gauge("g"), 3.0);
        let j = reg.to_json().to_string();
        // BTreeMap ordering: "a" serializes before "b"
        assert!(j.find("\"a\"").expect("a") < j.find("\"b\"").expect("b"));
        assert!(Json::parse(&j).is_ok(), "{j}");
    }
}
