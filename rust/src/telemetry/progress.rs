//! Wall-clock progress/ETA reporting — the one code path `sweep/` grids,
//! single runs, and the watcher dashboard all report through.
//!
//! Deliberately immutable and `Sync`: `sweep/` borrows one meter from the
//! stack into its scoped worker threads (alongside its completion
//! counter), so formatting needs only `&self`.

use std::time::Instant;

/// Formats `[label] k/total detail (Xs elapsed, eta Ys)` lines against a
/// fixed start instant.
pub struct ProgressMeter {
    label: String,
    total: usize,
    t0: Instant,
}

impl ProgressMeter {
    /// Start the clock now.
    pub fn start(label: &str, total: usize) -> ProgressMeter {
        ProgressMeter { label: label.to_string(), total, t0: Instant::now() }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Progress line for the `done`-th completion (1-based), with a
    /// linear-extrapolation ETA over the remaining items.
    pub fn line_at(&self, done: usize, detail: &str) -> String {
        let elapsed = self.elapsed_secs();
        let eta = if done == 0 {
            0.0
        } else {
            elapsed / done as f64 * self.total.saturating_sub(done) as f64
        };
        format!(
            "[{}] {done:>4}/{} {detail} ({elapsed:.1}s elapsed, eta {eta:.0}s)",
            self.label, self.total
        )
    }

    /// Failure/stall line: no ETA (extrapolating through a failure lies).
    pub fn stalled_at(&self, done: usize, detail: &str) -> String {
        let elapsed = self.elapsed_secs();
        format!(
            "[{}] {done:>4}/{} {detail} ({elapsed:.1}s elapsed)",
            self.label, self.total
        )
    }

    /// One-off banner under the same label, for headers like the grid
    /// shape announcement.
    pub fn banner(&self, detail: &str) -> String {
        format!("[{}] {detail}", self.label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_carry_label_counts_and_eta() {
        let meter = ProgressMeter::start("sweep", 8);
        let line = meter.line_at(2, "cell-a acc=0.5");
        assert!(line.starts_with("[sweep]"), "{line}");
        assert!(line.contains("2/8"), "{line}");
        assert!(line.contains("eta"), "{line}");
        let stalled = meter.stalled_at(3, "cell-b FAILED");
        assert!(stalled.contains("3/8"), "{stalled}");
        assert!(!stalled.contains("eta"), "{stalled}");
        assert_eq!(meter.banner("hello"), "[sweep] hello");
    }

    #[test]
    fn zero_done_has_zero_eta() {
        let meter = ProgressMeter::start("watch", 10);
        let line = meter.line_at(0, "warming up");
        assert!(line.contains("eta 0s"), "{line}");
    }
}
