//! The differential fuzz harness (`relay fuzz`): sample random
//! scenario+seed tuples from the whole config space (axes × fault mixes),
//! run each through a battery of engine invariants, and **shrink** any
//! failing tuple to a minimal config persisted into a replayable regression
//! corpus under `rust/tests/corpus/` (re-run by `tests/fuzz_corpus.rs` on
//! every push).
//!
//! Checks per sampled case:
//!
//! * **JSON validity** — the `ExperimentResult` serializes to parseable
//!   JSON with no non-finite values (the class of bug the seed's
//!   `train_loss: NaN` belonged to);
//! * **structural invariants** — one record per round/merge, monotone
//!   cumulative accounting, waste ≤ spent, `failed ⇔ nothing aggregated`,
//!   async concurrency within `[0, target]`, async-only fields null on
//!   sync records;
//! * **accounting identity** — `spent == aggregated + wasted` once the
//!   run's final sweep has retired all in-flight work (both engines track
//!   the aggregated bucket now, so the identity closes for sync *and*
//!   async cells, fault-injected or not);
//! * **worker invariance** — byte-identical output at `workers = 1` vs `8`;
//! * **differential** — for the round-synchronous modes, byte-identical
//!   output vs the frozen pre-refactor reference engine.
//!
//! Shrinking is greedy: a fixed list of simplifying transformations
//! (zero a fault rate, drop an axis to its simplest value, halve a size)
//! is applied repeatedly, keeping a transformation only when the failure
//! still reproduces, until no transformation makes the config smaller —
//! the persisted repro is locally minimal by construction.
//!
//! `--sabotage` plants a fake invariant ("no stale update is ever
//! aggregated") so the find → shrink → corpus pipeline can be exercised
//! and tested end-to-end without a real engine bug.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{AvailMode, ExpConfig, RoundMode};
use crate::coordinator::{
    run_experiment, run_experiment_logged, run_reference_experiment, Coordinator,
};
use crate::data::partition::PartitionScheme;
use crate::jobs::{replay_multijob, run_jobset, run_jobset_logged, MultiJobResult};
use crate::metrics::ExperimentResult;
use crate::runlog::{decode_segments, replay, MemSink};
use crate::runtime::{builtin_variant, Executor, NativeExecutor};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::faults::FaultConfig;

/// Relative tolerance for float accounting comparisons (sums of the same
/// terms in different orders).
const REL_EPS: f64 = 1e-6;

/// Fuzz-run knobs (CLI: `relay fuzz`).
pub struct FuzzOpts {
    /// Scenario+seed tuples to sample.
    pub iters: usize,
    /// Root seed of the tuple stream (each iter replays from `seed`+iter).
    pub seed: u64,
    /// Smaller populations/rounds for CI smoke runs.
    pub smoke: bool,
    /// Where shrunk repros are persisted.
    pub corpus_dir: PathBuf,
    /// Plant a fake invariant violation to demo the shrink pipeline.
    pub sabotage: bool,
    /// Stop after this many failures.
    pub max_failures: usize,
    /// Per-iteration progress lines.
    pub verbose: bool,
}

/// One found-and-shrunk failure.
pub struct FuzzFailure {
    pub iter: usize,
    pub failure: String,
    pub shrunk: ExpConfig,
    pub corpus_path: Option<PathBuf>,
}

/// The harvest of one fuzz run.
pub struct FuzzOutcome {
    /// Iterations actually executed (< `opts.iters` when the run stopped
    /// early at `max_failures`).
    pub iters: usize,
    pub failures: Vec<FuzzFailure>,
}

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new(builtin_variant("tiny")))
}

/// Draw one random scenario config from the full axis × fault space.
/// Sizes are kept tiny (`smoke` even tinier) so a case costs milliseconds.
pub fn sample_config(rng: &mut Rng, smoke: bool) -> ExpConfig {
    let selectors = ["random", "oort", "priority", "safa"];
    let partitions = ["iid", "fedscale", "label-balanced", "label-uniform", "label-zipf"];
    let (max_learners, max_rounds) = if smoke { (24, 4) } else { (64, 7) };
    let mut cfg = ExpConfig {
        variant: "tiny".into(),
        lr: 0.1,
        ..Default::default()
    };
    cfg.total_learners = rng.range(4, max_learners + 1);
    cfg.rounds = rng.range(2, max_rounds + 1);
    cfg.target_participants = rng.range(1, (cfg.total_learners / 2).max(2));
    cfg.mean_samples = rng.range(4, 10);
    cfg.test_per_class = 2;
    cfg.eval_every = rng.range(2, 4);
    cfg.cooldown_rounds = rng.below(3);
    cfg.min_round_duration = if rng.bool(0.7) { 0.0 } else { 30.0 };
    cfg.selector = selectors[rng.below(selectors.len())].into();
    cfg.partition =
        PartitionScheme::parse(partitions[rng.below(partitions.len())]).expect("known scheme");
    cfg.avail = if rng.bool(0.5) { AvailMode::AllAvail } else { AvailMode::DynAvail };
    cfg.use_saa = rng.bool(0.6);
    cfg.staleness_threshold = if rng.bool(0.5) { Some(rng.below(5)) } else { None };
    cfg.apt = rng.bool(0.3);
    cfg.safa_target_ratio = 0.1 + 0.2 * rng.f64();
    cfg.mode = match rng.below(3) {
        0 => RoundMode::OverCommit { factor: 1.0 + rng.f64() },
        1 => RoundMode::Deadline { deadline: 1.0 + 60.0 * rng.f64() },
        _ => RoundMode::Async {
            buffer_k: rng.range(1, 6),
            max_staleness: if rng.bool(0.5) { Some(rng.below(6)) } else { None },
        },
    };
    // SAFA+O's two-pass oracle protocol, on the sync modes that define it —
    // without this the plan-transfer path would sit outside the fuzzed space
    cfg.oracle = cfg.selector == "safa"
        && !matches!(cfg.mode, RoundMode::Async { .. })
        && rng.bool(0.2);
    cfg.seed = rng.next_u64() % 100_000;
    // multi-job axis: a quarter of the cases run N concurrent jobs over one
    // shared fleet through the jobset engine (which rejects oracle/apt)
    if rng.bool(0.25) {
        let jobs = rng.range(2, 5);
        cfg.jobs = jobs;
        cfg.oracle = false;
        cfg.apt = false;
        cfg.job_policy = if rng.bool(0.5) { "fair" } else { "priority" }.into();
        if rng.bool(0.6) {
            cfg.job_priorities = (0..jobs).map(|_| rng.below(10) as u64).collect();
        }
        if rng.bool(0.5) {
            let sels = ["random", "oort", "priority", "safa"];
            cfg.job_selectors =
                (0..jobs).map(|_| sels[rng.below(sels.len())].to_string()).collect();
        }
        if rng.bool(0.5) {
            let specs = ["oc", "oc1.5", "dl40", "async2", "async3"];
            cfg.job_modes =
                (0..jobs).map(|_| specs[rng.below(specs.len())].to_string()).collect();
        }
        if rng.bool(0.5) {
            let cap = cfg.total_learners.min(8);
            cfg.job_targets = (0..jobs).map(|_| rng.range(1, cap + 1)).collect();
        }
    }
    if rng.bool(0.65) {
        let mut f = FaultConfig { fault_seed: rng.next_u64() % 100_000, ..Default::default() };
        if rng.bool(0.4) {
            f.flap = 0.5 * rng.f64();
        }
        if rng.bool(0.4) {
            f.crash = 0.5 * rng.f64();
        }
        if rng.bool(0.4) {
            f.delay = 0.5 * rng.f64();
            f.delay_secs = 30.0 + 300.0 * rng.f64();
        }
        if rng.bool(0.4) {
            f.corrupt = 0.5 * rng.f64();
        }
        if rng.bool(0.4) {
            f.duplicate = 0.5 * rng.f64();
        }
        cfg.faults = f;
    }
    cfg.label = format!("fuzz-{:08x}", rng.next_u64() & 0xFFFF_FFFF);
    cfg
}

/// Run one config at the given sweep- and training-worker counts;
/// `(result, terminal buckets)`. Oracle configs route through the two-pass
/// protocol (no totals).
fn run_engine(
    cfg: &ExpConfig,
    workers: usize,
    train_workers: usize,
) -> Result<(ExperimentResult, Option<(f64, f64, f64)>), String> {
    let mut c = cfg.clone();
    c.workers = workers;
    c.train_workers = train_workers;
    if c.oracle {
        let r = run_experiment(c, exec()).map_err(|e| format!("engine run failed: {e:#}"))?;
        Ok((r, None))
    } else {
        let mut coord = Coordinator::new(c, exec())
            .map_err(|e| format!("engine construct failed: {e:#}"))?;
        let r = coord.run().map_err(|e| format!("engine run failed: {e:#}"))?;
        let totals = coord.accounting_totals();
        Ok((r, Some(totals)))
    }
}

/// Structural invariants over one result log.
fn check_result(cfg: &ExpConfig, r: &ExperimentResult) -> Result<(), String> {
    if r.rounds.len() != cfg.rounds {
        return Err(format!(
            "round count {} != cfg.rounds {}",
            r.rounds.len(),
            cfg.rounds
        ));
    }
    let is_async = matches!(cfg.mode, RoundMode::Async { .. });
    let mut prev_res = 0.0f64;
    let mut prev_waste = 0.0f64;
    let mut prev_time = 0.0f64;
    for rec in &r.rounds {
        let i = rec.round;
        let tol = REL_EPS * rec.cum_resource_secs.max(1.0);
        if rec.cum_resource_secs < prev_res - tol {
            return Err(format!("round {i}: cum_resource_secs decreased"));
        }
        if rec.cum_waste_secs < prev_waste - tol {
            return Err(format!("round {i}: cum_waste_secs decreased"));
        }
        if rec.sim_time < prev_time - 1e-9 {
            return Err(format!("round {i}: sim_time went backwards"));
        }
        if rec.cum_waste_secs > rec.cum_resource_secs + tol {
            return Err(format!(
                "round {i}: wasted {} > spent {}",
                rec.cum_waste_secs, rec.cum_resource_secs
            ));
        }
        if rec.failed != (rec.fresh_updates + rec.stale_updates == 0) {
            return Err(format!(
                "round {i}: failed={} but fresh+stale={}",
                rec.failed,
                rec.fresh_updates + rec.stale_updates
            ));
        }
        if let Some(l) = rec.train_loss {
            if !l.is_finite() {
                return Err(format!("round {i}: non-finite train_loss"));
            }
        }
        if let Some(a) = rec.test_accuracy {
            if !(0.0..=1.0).contains(&a) {
                return Err(format!("round {i}: accuracy {a} outside [0,1]"));
            }
        }
        if is_async {
            let Some(conc) = rec.mean_concurrency else {
                return Err(format!("round {i}: async record missing mean_concurrency"));
            };
            if !(-1e-9..=cfg.target_participants as f64 + 1e-9).contains(&conc) {
                return Err(format!("round {i}: concurrency {conc} outside [0, target]"));
            }
            if rec.in_flight_secs.unwrap_or(0.0) < -tol {
                return Err(format!("round {i}: negative in-flight seconds"));
            }
            if rec.kernel_events.is_none() {
                return Err(format!("round {i}: async record missing kernel_events"));
            }
        } else if rec.mean_concurrency.is_some()
            || rec.cum_aggregated_secs.is_some()
            || rec.in_flight_secs.is_some()
            || rec.kernel_events.is_some()
        {
            return Err(format!("round {i}: async-only field set on a sync record"));
        }
        prev_res = rec.cum_resource_secs;
        prev_waste = rec.cum_waste_secs;
        prev_time = rec.sim_time;
    }
    if is_async {
        if let Some(last) = r.rounds.last() {
            // record-level closure (not the totals the engine hands us
            // directly): the final record's own buckets must account for
            // every spent second — this fires if the end-of-run sweep is
            // ever lost, even though run_async also zeroes in_flight_secs
            let agg = last.cum_aggregated_secs.unwrap_or(0.0);
            let inflight = last.in_flight_secs.unwrap_or(0.0);
            let closed = agg + last.cum_waste_secs + inflight;
            if (last.cum_resource_secs - closed).abs()
                > REL_EPS * last.cum_resource_secs.max(1.0)
            {
                return Err(format!(
                    "final record identity broken: spent {} != aggregated {agg} + wasted {} \
                     + in-flight {inflight}",
                    last.cum_resource_secs, last.cum_waste_secs
                ));
            }
        }
    }
    Ok(())
}

/// Run one multi-job config at the given worker counts (and, optionally, a
/// coordinator shard override).
fn run_multijob(
    cfg: &ExpConfig,
    workers: usize,
    train_workers: usize,
    coord_shards: Option<usize>,
) -> Result<MultiJobResult, String> {
    let mut c = cfg.clone();
    c.workers = workers;
    c.train_workers = train_workers;
    if let Some(k) = coord_shards {
        c.coord_shards = k;
    }
    run_jobset(c, exec()).map_err(|e| format!("jobset run failed: {e:#}"))
}

/// The multi-job invariant battery: JSON validity, per-job accounting
/// identity after the terminal sweep, fleet totals = sum over jobs,
/// workers / train-workers / coord-shards byte-invariance, and the
/// logged-run → decode → `replay_multijob` byte-identity loop.
fn run_multijob_checks(cfg: &ExpConfig) -> Result<(), String> {
    let r1 = run_multijob(cfg, 1, 1, None)?;
    let j1 = r1.to_json().to_string();
    Json::parse(&j1).map_err(|e| format!("multi-job output is not valid JSON: {e}"))?;
    if j1.contains("NaN") || j1.contains(":inf") || j1.contains(":-inf") {
        return Err("non-finite value leaked into multi-job JSON".into());
    }
    if r1.jobs.len() != cfg.jobs {
        return Err(format!("{} job summaries != cfg.jobs {}", r1.jobs.len(), cfg.jobs));
    }
    let tol = |x: f64| REL_EPS * x.abs().max(1.0);
    let mut fleet_spent = 0.0f64;
    for (j, job) in r1.jobs.iter().enumerate() {
        if job.in_flight_secs.abs() > tol(job.spent_secs) {
            return Err(format!(
                "job {j}: {} in-flight seconds survived the terminal sweep",
                job.in_flight_secs
            ));
        }
        let closed = job.aggregated_secs + job.wasted_secs + job.in_flight_secs;
        if (job.spent_secs - closed).abs() > tol(job.spent_secs) {
            return Err(format!(
                "job {j} identity broken: spent {} != aggregated {} + wasted {} \
                 + in-flight {}",
                job.spent_secs, job.aggregated_secs, job.wasted_secs, job.in_flight_secs
            ));
        }
        fleet_spent += job.spent_secs;
    }
    if (r1.fleet_spent_secs - fleet_spent).abs() > tol(fleet_spent) {
        return Err(format!(
            "fleet spent {} != sum of per-job spent {fleet_spent}",
            r1.fleet_spent_secs
        ));
    }
    let r8 = run_multijob(cfg, 8, 8, None)?;
    if r8.to_json().to_string() != j1 {
        return Err("multi-job workers-1-vs-8 outputs diverged".into());
    }
    for k in [2usize, 7] {
        let rk = run_multijob(cfg, 4, 1, Some(k))?;
        if rk.to_json().to_string() != j1 {
            return Err(format!("multi-job coord-shards {k} output diverged"));
        }
    }
    let sink = MemSink::default();
    let mut lc = cfg.clone();
    lc.workers = 1;
    lc.train_workers = 1;
    let logged = run_jobset_logged(lc, exec(), Box::new(sink.clone()))
        .map_err(|e| format!("logged jobset run failed: {e:#}"))?;
    if logged.to_json().to_string() != j1 {
        return Err("enabling the run log perturbed the multi-job bytes".into());
    }
    let (events, stats) = decode_segments(&sink.segments());
    if !stats.clean {
        return Err(format!(
            "multi-job run log did not decode cleanly: {}",
            stats.note.unwrap_or_default()
        ));
    }
    let replayed =
        replay_multijob(&events).map_err(|e| format!("multi-job replay failed: {e:#}"))?;
    if replayed.to_json().to_string() != j1 {
        return Err("multi-job replay diverged from the engine output".into());
    }
    Ok(())
}

fn run_checks(cfg: &ExpConfig) -> Result<(), String> {
    cfg.validate().map_err(|e| format!("validate: {e:#}"))?;
    if cfg.jobs > 1 {
        return run_multijob_checks(cfg);
    }
    let (r1, totals) = run_engine(cfg, 1, 1)?;
    let j1 = r1.to_json().to_string();
    Json::parse(&j1).map_err(|e| format!("output is not valid JSON: {e}"))?;
    if j1.contains("NaN") || j1.contains(":inf") || j1.contains(":-inf") {
        return Err("non-finite value leaked into output JSON".into());
    }
    check_result(cfg, &r1)?;
    if let Some((spent, agg, wasted)) = totals {
        if (spent - (agg + wasted)).abs() > REL_EPS * spent.max(1.0) {
            return Err(format!(
                "accounting identity broken: spent {spent} != aggregated {agg} + wasted {wasted}"
            ));
        }
    }
    let (r8, _) = run_engine(cfg, 8, 1)?;
    if r8.to_json().to_string() != j1 {
        return Err("workers-1-vs-8 outputs diverged (byte-determinism broken)".into());
    }
    // train-worker axis: fanning local SGD across the training pool must
    // never perturb the bytes, at any width, including the combined case
    // where both pools are wide.
    for (w, tw) in [(1usize, 2usize), (1, 8), (8, 8)] {
        let (rt, _) = run_engine(cfg, w, tw)?;
        if rt.to_json().to_string() != j1 {
            return Err(format!(
                "train-workers-1-vs-{tw} (workers {w}) outputs diverged \
                 (training pool broke byte-determinism)"
            ));
        }
    }
    // coordinator-shard axis: partitioning the registry + availability
    // index into K id-range shards (advanced in parallel, merged
    // shard-major) must never perturb the bytes at any K — the
    // K-invariance contract behind `--coord-shards`.
    for k in [2usize, 7] {
        let mut c = cfg.clone();
        c.coord_shards = k;
        let (rk, _) = run_engine(&c, 4, 1)?;
        if rk.to_json().to_string() != j1 {
            return Err(format!(
                "coord-shards {k} output diverged (shard partition/merge broke \
                 byte-determinism)"
            ));
        }
    }
    // engine-vs-replay differential: a logged run must stay byte-identical
    // to the unlogged run (logging only observes), its log must decode
    // cleanly, and the replay oracle must re-derive the exact same JSON
    // from the events alone. Unlike the frozen sync reference below, this
    // oracle also covers the async regime.
    let sink = MemSink::default();
    let mut lc = cfg.clone();
    lc.workers = 1;
    lc.train_workers = 1;
    let logged = run_experiment_logged(lc, exec(), Box::new(sink.clone()))
        .map_err(|e| format!("logged run failed: {e:#}"))?;
    if logged.to_json().to_string() != j1 {
        return Err("enabling the run log perturbed the result bytes".into());
    }
    let (events, stats) = decode_segments(&sink.segments());
    if !stats.clean {
        return Err(format!("run log did not decode cleanly: {}", stats.note.unwrap_or_default()));
    }
    let replayed = replay(&events).map_err(|e| format!("run log replay failed: {e:#}"))?;
    if replayed.to_json().to_string() != j1 {
        return Err("replay oracle diverged from the engine output".into());
    }
    if !matches!(cfg.mode, RoundMode::Async { .. }) {
        let mut c = cfg.clone();
        c.workers = 1;
        let rr = run_reference_experiment(c, exec())
            .map_err(|e| format!("reference run failed: {e:#}"))?;
        if rr.to_json().to_string() != j1 {
            return Err("kernel engine diverged from the frozen reference".into());
        }
    }
    Ok(())
}

/// The real invariant battery: `None` = passed, `Some(why)` = failed.
pub fn check_case(cfg: &ExpConfig) -> Option<String> {
    run_checks(cfg).err()
}

/// The planted fake invariant ("no stale update is ever aggregated") used
/// to demo and test the find → shrink → corpus pipeline.
pub fn sabotage_check(cfg: &ExpConfig) -> Option<String> {
    if cfg.jobs > 1 {
        // the planted invariant is defined over the single-job engine's
        // per-round stale counts; multi-job samples just pass
        return None;
    }
    let (r, _) = match run_engine(cfg, 1, 1) {
        Ok(v) => v,
        Err(e) => return Some(e),
    };
    let stale: usize = r.rounds.iter().map(|x| x.stale_updates).sum();
    if stale > 0 {
        Some(format!(
            "[sabotage] planted invariant violated: {stale} stale updates were aggregated"
        ))
    } else {
        None
    }
}

/// The simplifying transformations the shrinker tries, most-drastic first.
/// Each is idempotent and moves one knob toward its simplest value, so the
/// greedy loop terminates at a locally-minimal config.
pub fn shrink_transforms() -> Vec<Box<dyn Fn(&ExpConfig) -> ExpConfig>> {
    fn with(f: impl Fn(&mut ExpConfig) + 'static) -> Box<dyn Fn(&ExpConfig) -> ExpConfig> {
        Box::new(move |c| {
            let mut c = c.clone();
            f(&mut c);
            c
        })
    }
    vec![
        with(|c| c.faults = FaultConfig::default()),
        with(|c| c.faults.flap = 0.0),
        with(|c| c.faults.crash = 0.0),
        with(|c| c.faults.delay = 0.0),
        with(|c| c.faults.corrupt = 0.0),
        with(|c| c.faults.duplicate = 0.0),
        with(|c| c.avail = AvailMode::AllAvail),
        with(|c| c.partition = PartitionScheme::UniformIid),
        with(|c| c.selector = "random".into()),
        with(|c| c.apt = false),
        with(|c| c.oracle = false),
        with(|c| c.coord_shards = 0),
        with(|c| {
            c.jobs = 1;
            c.job_policy = "fair".into();
            c.job_priorities.clear();
            c.job_selectors.clear();
            c.job_modes.clear();
            c.job_targets.clear();
        }),
        with(|c| {
            if c.jobs > 2 {
                c.jobs -= 1;
                c.job_priorities.truncate(c.jobs);
                c.job_selectors.truncate(c.jobs);
                c.job_modes.truncate(c.jobs);
                c.job_targets.truncate(c.jobs);
            }
        }),
        with(|c| {
            c.job_selectors.clear();
            c.job_modes.clear();
            c.job_targets.clear();
        }),
        with(|c| {
            c.use_saa = false;
            c.staleness_threshold = None;
        }),
        with(|c| c.staleness_threshold = None),
        with(|c| c.mode = RoundMode::OverCommit { factor: 1.3 }),
        with(|c| c.total_learners = (c.total_learners / 2).max(2)),
        with(|c| c.total_learners = c.total_learners.saturating_sub(1).max(2)),
        with(|c| c.rounds = (c.rounds / 2).max(1)),
        with(|c| c.rounds = c.rounds.saturating_sub(1).max(1)),
        with(|c| c.target_participants = (c.target_participants / 2).max(1)),
        with(|c| c.mean_samples = 4),
        with(|c| c.cooldown_rounds = 0),
        with(|c| c.min_round_duration = 0.0),
        with(|c| c.test_per_class = 2),
        with(|c| c.eval_every = c.rounds.max(1)),
    ]
}

/// Greedy shrink: keep applying simplifying transformations while the
/// failure still reproduces; stop at a config no transformation can reduce.
pub fn shrink(
    cfg: &ExpConfig,
    fails: &mut dyn FnMut(&ExpConfig) -> Option<String>,
) -> ExpConfig {
    let transforms = shrink_transforms();
    let mut cur = cfg.clone();
    loop {
        let mut progressed = false;
        for t in &transforms {
            let cand = t(&cur);
            if cand.to_json().to_string() == cur.to_json().to_string() {
                continue; // no-op at this config
            }
            if cand.validate().is_err() {
                continue;
            }
            if fails(&cand).is_some() {
                cur = cand;
                progressed = true;
            }
        }
        if !progressed {
            return cur;
        }
    }
}

/// Persist one shrunk repro; the file name is a stable hash of the config,
/// so re-finding the same minimum overwrites rather than duplicates.
pub fn write_corpus_entry(dir: &Path, cfg: &ExpConfig, failure: &str) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let cfg_json = cfg.to_json();
    let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
    for b in cfg_json.to_string().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    let path = dir.join(format!("shrunk-{h:016x}.json"));
    let entry = obj(vec![
        ("format", Json::Str("relay-fuzz-corpus-v1".into())),
        ("failure", Json::Str(failure.into())),
        ("config", cfg_json),
    ]);
    std::fs::write(&path, entry.to_string())?;
    Ok(path)
}

/// Load every corpus entry under `dir` (sorted by path for determinism).
pub fn corpus_entries(dir: &Path) -> Result<Vec<(PathBuf, ExpConfig, String)>> {
    let mut out = Vec::new();
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(out), // no corpus yet
    };
    let mut paths: Vec<PathBuf> = rd
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    paths.sort();
    for p in paths {
        let text = std::fs::read_to_string(&p)?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", p.display()))?;
        let cfg_json = j
            .get("config")
            .ok_or_else(|| anyhow!("{}: missing 'config'", p.display()))?;
        let cfg = ExpConfig::from_json(cfg_json)
            .map_err(|e| anyhow!("{}: {e:#}", p.display()))?;
        let failure = j.get("failure").and_then(|f| f.as_str()).unwrap_or("").to_string();
        out.push((p, cfg, failure));
    }
    Ok(out)
}

/// The fuzz driver: sample, check, shrink, persist.
pub fn run_fuzz(opts: &FuzzOpts) -> Result<FuzzOutcome> {
    let root = Rng::new(opts.seed);
    let mut failures = Vec::new();
    let mut executed = 0usize;
    // sabotage repros are demos of the pipeline, not regressions — keep
    // them out of the committed corpus (the README promises as much)
    let corpus_dir = if opts.sabotage {
        std::env::temp_dir().join(format!("relay-fuzz-sabotage-{}", std::process::id()))
    } else {
        opts.corpus_dir.clone()
    };
    for iter in 0..opts.iters {
        executed = iter + 1;
        let mut rng = root.stream(iter as u64);
        let cfg = sample_config(&mut rng, opts.smoke);
        let mut fails = |c: &ExpConfig| {
            if opts.sabotage {
                sabotage_check(c)
            } else {
                check_case(c)
            }
        };
        let Some(failure) = fails(&cfg) else {
            if opts.verbose {
                eprintln!("[fuzz] iter {iter}: ok ({})", cfg.label);
            }
            continue;
        };
        eprintln!("[fuzz] iter {iter}: FAILED: {failure}");
        let shrunk = shrink(&cfg, &mut fails);
        let final_failure = fails(&shrunk).unwrap_or(failure);
        eprintln!(
            "[fuzz]   shrunk: {} learners x {} rounds, selector={}, mode={}, faults=[{}]",
            shrunk.total_learners,
            shrunk.rounds,
            shrunk.selector,
            shrunk.mode.label(),
            shrunk.faults.label()
        );
        let corpus_path = match write_corpus_entry(&corpus_dir, &shrunk, &final_failure) {
            Ok(p) => {
                eprintln!("[fuzz]   repro persisted: {}", p.display());
                Some(p)
            }
            Err(e) => {
                eprintln!("[fuzz]   corpus write failed: {e:#}");
                None
            }
        };
        failures.push(FuzzFailure { iter, failure: final_failure, shrunk, corpus_path });
        if failures.len() >= opts.max_failures {
            break;
        }
    }
    Ok(FuzzOutcome { iters: executed, failures })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampled_configs_always_validate() {
        let root = Rng::new(0xF022);
        for case in 0..200 {
            let mut rng = root.stream(case);
            let cfg = sample_config(&mut rng, case % 2 == 0);
            cfg.validate().unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        }
    }

    #[test]
    fn corpus_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join(format!("relay-fuzz-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = sample_config(&mut Rng::new(7), true);
        cfg.label = "roundtrip".into();
        let path = write_corpus_entry(&dir, &cfg, "test failure").unwrap();
        assert!(path.exists());
        let entries = corpus_entries(&dir).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].1.to_json().to_string(), cfg.to_json().to_string());
        assert_eq!(entries[0].2, "test failure");
        // same config re-persisted lands on the same file (no duplicates)
        let path2 = write_corpus_entry(&dir, &cfg, "test failure").unwrap();
        assert_eq!(path, path2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_corpus_dir_is_empty_not_an_error() {
        let entries =
            corpus_entries(Path::new("/nonexistent/relay-corpus-xyz")).unwrap();
        assert!(entries.is_empty());
    }

    #[test]
    fn shrink_reaches_a_local_minimum() {
        // a config-shape-only predicate (no engine runs): "fails" whenever
        // SAA is on — the shrinker must zero everything else and keep SAA
        let mut cfg = sample_config(&mut Rng::new(42), true);
        cfg.use_saa = true;
        cfg.faults.crash = 0.4;
        let mut fails =
            |c: &ExpConfig| if c.use_saa { Some("saa on".to_string()) } else { None };
        let shrunk = shrink(&cfg, &mut fails);
        assert!(shrunk.use_saa, "the failing knob must survive shrinking");
        assert_eq!(shrunk.total_learners, 2);
        assert_eq!(shrunk.rounds, 1);
        assert_eq!(shrunk.target_participants, 1);
        assert!(!shrunk.faults.is_active(), "irrelevant faults must be zeroed");
        assert_eq!(shrunk.selector, "random");
        assert_eq!(shrunk.avail, AvailMode::AllAvail);
        // local minimality: every transformation is either a no-op here,
        // invalid, or makes the failure disappear
        for t in shrink_transforms() {
            let cand = t(&shrunk);
            if cand.to_json().to_string() != shrunk.to_json().to_string()
                && cand.validate().is_ok()
            {
                assert!(fails(&cand).is_none(), "shrunk config is not locally minimal");
            }
        }
    }
}
