//! Deterministic fault injection: seed-derived perturbations of the round
//! life-cycle, threaded through both round engines (and the frozen
//! reference, so the differential suite pins the fault paths too).
//!
//! Every decision is a **pure function** of `(fault_seed, fault kind,
//! learner, round)` — no RNG stream is consumed — so faults fire at the
//! same points regardless of worker count, engine, or event interleaving,
//! and an all-zero [`FaultConfig`] (the default) is bit-for-bit the
//! pre-fault behavior. The modeled faults, each accounted exactly like the
//! failure mode it perturbs (nothing leaks out of the
//! `spent == aggregated + wasted + in-flight` identity):
//!
//! * **flap** — a selected learner vanishes between selection and
//!   configuration (Bonawitz et al.'s phase-2 drop-offs): the task never
//!   starts, the slot is lost, no device time is spent;
//! * **crash** — a learner that would have completed dies mid-task at a
//!   seed-derived fraction of its duration: partial spend, all wasted,
//!   accounted like a trace dropout;
//! * **delay** — a finished upload is held in transit for extra seconds:
//!   the update arrives late and may die to the round window or the
//!   staleness bound;
//! * **corrupt** — the update arrives mangled and server-side validation
//!   rejects it: full spend, all wasted, the model never sees the delta;
//! * **duplicate** — an upload is received twice and the copy is deduped:
//!   no accounting impact, but the rejection path is exercised and counted.

use anyhow::{anyhow, Result};

use crate::util::json::{num, obj, Json};
use crate::util::rng::splitmix64;

// Fault-kind salts for the decision hash (distinct per decision stream).
const KIND_FLAP: u64 = 1;
const KIND_CRASH: u64 = 2;
const KIND_CRASH_FRAC: u64 = 3;
const KIND_DELAY: u64 = 4;
const KIND_DELAY_AMT: u64 = 5;
const KIND_CORRUPT: u64 = 6;
const KIND_DUPLICATE: u64 = 7;

/// Fault classes, as recorded in the run log's `FaultDecision` events
/// (stable wire codes — the replay oracle matches on them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Flap,
    Crash,
    Delay,
    Corrupt,
    Duplicate,
}

impl FaultKind {
    pub fn code(self) -> u8 {
        match self {
            FaultKind::Flap => 0,
            FaultKind::Crash => 1,
            FaultKind::Delay => 2,
            FaultKind::Corrupt => 3,
            FaultKind::Duplicate => 4,
        }
    }

    pub fn from_code(code: u8) -> Option<FaultKind> {
        match code {
            0 => Some(FaultKind::Flap),
            1 => Some(FaultKind::Crash),
            2 => Some(FaultKind::Delay),
            3 => Some(FaultKind::Corrupt),
            4 => Some(FaultKind::Duplicate),
            _ => None,
        }
    }
}

/// Fault-injection knobs (all probabilities per selected-learner-per-round;
/// the default is all-off). Carried by `ExpConfig` and serialized with it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// P(a selected learner never starts: check-in flap before
    /// configuration).
    pub flap: f64,
    /// P(a learner that would have completed crashes mid-task).
    pub crash: f64,
    /// P(a finished upload is delayed in transit).
    pub delay: f64,
    /// Mean extra upload delay in seconds when `delay` fires (the actual
    /// delay is seed-derived in `[0.5, 1.5] * delay_secs`).
    pub delay_secs: f64,
    /// P(an update arrives corrupted and is rejected by validation).
    pub corrupt: f64,
    /// P(an accepted delivery is received twice; the copy is deduped).
    pub duplicate: f64,
    /// Seed of the fault stream, independent of the experiment seed so the
    /// same fault pattern can be replayed across scenario axes.
    pub fault_seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            flap: 0.0,
            crash: 0.0,
            delay: 0.0,
            delay_secs: 120.0,
            corrupt: 0.0,
            duplicate: 0.0,
            fault_seed: 0,
        }
    }
}

impl FaultConfig {
    /// Whether any fault class can ever fire.
    pub fn is_active(&self) -> bool {
        self.flap > 0.0
            || self.crash > 0.0
            || self.delay > 0.0
            || self.corrupt > 0.0
            || self.duplicate > 0.0
    }

    /// Uniform-[0,1) decision value for one `(kind, learner, round)` cell:
    /// two chained splitmix64 rounds over the xor-folded coordinates.
    fn u01(&self, kind: u64, learner: usize, round: usize) -> f64 {
        let mut s = self.fault_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ kind.wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ (learner as u64).wrapping_mul(0xA24B_AED4_963E_E407)
            ^ (round as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
        let a = splitmix64(&mut s);
        let b = splitmix64(&mut s);
        ((a ^ b) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Check-in flap: the selected learner never starts its task.
    pub fn flaps(&self, learner: usize, round: usize) -> bool {
        self.flap > 0.0 && self.u01(KIND_FLAP, learner, round) < self.flap
    }

    /// Mid-task crash: `Some(fraction)` of the task duration completed
    /// before the crash (in `[0.05, 0.95]`, never a zero-length task).
    pub fn crashes(&self, learner: usize, round: usize) -> Option<f64> {
        if self.crash > 0.0 && self.u01(KIND_CRASH, learner, round) < self.crash {
            Some(0.05 + 0.9 * self.u01(KIND_CRASH_FRAC, learner, round))
        } else {
            None
        }
    }

    /// In-transit upload delay: `Some(extra seconds)` when it fires.
    pub fn delays(&self, learner: usize, round: usize) -> Option<f64> {
        if self.delay > 0.0 && self.u01(KIND_DELAY, learner, round) < self.delay {
            Some(self.delay_secs * (0.5 + self.u01(KIND_DELAY_AMT, learner, round)))
        } else {
            None
        }
    }

    /// Corrupted update: rejected by server validation on delivery.
    pub fn corrupts(&self, learner: usize, round: usize) -> bool {
        self.corrupt > 0.0 && self.u01(KIND_CORRUPT, learner, round) < self.corrupt
    }

    /// Duplicate delivery: the server receives (and dedupes) a second copy.
    pub fn duplicates(&self, learner: usize, round: usize) -> bool {
        self.duplicate > 0.0 && self.u01(KIND_DUPLICATE, learner, round) < self.duplicate
    }

    pub fn validate(&self) -> Result<()> {
        for (name, rate) in [
            ("flap", self.flap),
            ("crash", self.crash),
            ("delay", self.delay),
            ("corrupt", self.corrupt),
            ("duplicate", self.duplicate),
        ] {
            if !(0.0..=1.0).contains(&rate) || !rate.is_finite() {
                return Err(anyhow!("fault rate '{name}' must be in [0,1], got {rate}"));
            }
        }
        if !self.delay_secs.is_finite() || self.delay_secs < 0.0 {
            return Err(anyhow!(
                "fault delay_secs must be finite and >= 0, got {}",
                self.delay_secs
            ));
        }
        if self.fault_seed > (1u64 << 53) {
            // the seed round-trips through a JSON f64; beyond 2^53 that
            // silently corrupts it and replayed corpus entries would fire
            // different faults than the run that persisted them
            return Err(anyhow!(
                "fault_seed must fit in 53 bits for exact JSON round-trips, got {}",
                self.fault_seed
            ));
        }
        Ok(())
    }

    /// Compact axis label for sweep cells / reports, e.g.
    /// `flap0.1+crash0.25`. Empty when inactive.
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.flap > 0.0 {
            parts.push(format!("flap{}", self.flap));
        }
        if self.crash > 0.0 {
            parts.push(format!("crash{}", self.crash));
        }
        if self.delay > 0.0 {
            parts.push(format!("delay{}", self.delay));
        }
        if self.corrupt > 0.0 {
            parts.push(format!("corrupt{}", self.corrupt));
        }
        if self.duplicate > 0.0 {
            parts.push(format!("dup{}", self.duplicate));
        }
        parts.join("+")
    }

    /// Parse a CLI spec like `flap=0.1,crash=0.2,delay=0.3,delay-secs=300,
    /// corrupt=0.05,dup=0.1,seed=7`.
    pub fn parse_spec(spec: &str) -> Result<FaultConfig> {
        let mut f = FaultConfig::default();
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("--faults entries are key=value, got '{part}'"))?;
            match k {
                "flap" => f.flap = v.parse()?,
                "crash" => f.crash = v.parse()?,
                "delay" => f.delay = v.parse()?,
                "delay-secs" | "delay_secs" => f.delay_secs = v.parse()?,
                "corrupt" => f.corrupt = v.parse()?,
                "dup" | "duplicate" => f.duplicate = v.parse()?,
                "seed" => f.fault_seed = v.parse()?,
                other => return Err(anyhow!("unknown fault knob '{other}'")),
            }
        }
        f.validate()?;
        Ok(f)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("flap", num(self.flap)),
            ("crash", num(self.crash)),
            ("delay", num(self.delay)),
            ("delay_secs", num(self.delay_secs)),
            ("corrupt", num(self.corrupt)),
            ("duplicate", num(self.duplicate)),
            ("fault_seed", num(self.fault_seed as f64)),
        ])
    }

    /// Lenient load: missing keys fall back to the defaults, so configs
    /// written before the fault layer existed keep loading unchanged.
    pub fn from_json(j: &Json) -> FaultConfig {
        let d = FaultConfig::default();
        let gf = |k: &str, dflt: f64| j.get(k).and_then(|v| v.as_f64()).unwrap_or(dflt);
        FaultConfig {
            flap: gf("flap", d.flap),
            crash: gf("crash", d.crash),
            delay: gf("delay", d.delay),
            delay_secs: gf("delay_secs", d.delay_secs),
            corrupt: gf("corrupt", d.corrupt),
            duplicate: gf("duplicate", d.duplicate),
            fault_seed: gf("fault_seed", d.fault_seed as f64) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_inactive_and_never_fires() {
        let f = FaultConfig::default();
        assert!(!f.is_active());
        for learner in 0..50 {
            for round in 0..20 {
                assert!(!f.flaps(learner, round));
                assert!(f.crashes(learner, round).is_none());
                assert!(f.delays(learner, round).is_none());
                assert!(!f.corrupts(learner, round));
                assert!(!f.duplicates(learner, round));
            }
        }
        assert_eq!(f.label(), "");
        f.validate().unwrap();
    }

    #[test]
    fn decisions_are_deterministic_and_rate_calibrated() {
        let f = FaultConfig {
            flap: 0.3,
            crash: 0.5,
            corrupt: 0.1,
            fault_seed: 42,
            ..Default::default()
        };
        let g = f; // same knobs => same decisions
        let mut flaps = 0usize;
        let mut crashes = 0usize;
        let mut corrupts = 0usize;
        let n = 20_000usize;
        for i in 0..n {
            let (learner, round) = (i % 500, i / 500);
            assert_eq!(f.flaps(learner, round), g.flaps(learner, round));
            assert_eq!(f.crashes(learner, round), g.crashes(learner, round));
            flaps += usize::from(f.flaps(learner, round));
            if let Some(frac) = f.crashes(learner, round) {
                crashes += 1;
                assert!((0.05..=0.95).contains(&frac));
            }
            corrupts += usize::from(f.corrupts(learner, round));
        }
        let rate = |c: usize| c as f64 / n as f64;
        assert!((rate(flaps) - 0.3).abs() < 0.02, "flap rate {}", rate(flaps));
        assert!((rate(crashes) - 0.5).abs() < 0.02, "crash rate {}", rate(crashes));
        assert!((rate(corrupts) - 0.1).abs() < 0.02, "corrupt rate {}", rate(corrupts));
    }

    #[test]
    fn different_seeds_decide_differently() {
        let a = FaultConfig { crash: 0.5, fault_seed: 1, ..Default::default() };
        let b = FaultConfig { crash: 0.5, fault_seed: 2, ..Default::default() };
        let diff = (0..2000)
            .filter(|&i| a.crashes(i, 0).is_some() != b.crashes(i, 0).is_some())
            .count();
        assert!(diff > 200, "seeds should decorrelate decisions, diff={diff}");
    }

    #[test]
    fn delay_scales_with_delay_secs() {
        let f = FaultConfig {
            delay: 1.0,
            delay_secs: 100.0,
            fault_seed: 3,
            ..Default::default()
        };
        for i in 0..500 {
            let d = f.delays(i, 1).expect("delay=1.0 always fires");
            assert!((50.0..=150.0).contains(&d), "delay {d} outside [0.5,1.5]*100");
        }
    }

    #[test]
    fn spec_parses_and_labels() {
        let f = FaultConfig::parse_spec(
            "flap=0.1,crash=0.25,delay=0.5,delay-secs=300,corrupt=0.05,dup=0.2,seed=9",
        )
        .unwrap();
        assert_eq!(f.flap, 0.1);
        assert_eq!(f.crash, 0.25);
        assert_eq!(f.delay_secs, 300.0);
        assert_eq!(f.duplicate, 0.2);
        assert_eq!(f.fault_seed, 9);
        assert_eq!(f.label(), "flap0.1+crash0.25+delay0.5+corrupt0.05+dup0.2");
        assert!(FaultConfig::parse_spec("bogus=1").is_err());
        assert!(FaultConfig::parse_spec("flap=1.5").is_err());
        assert!(FaultConfig::parse_spec("flap").is_err());
    }

    #[test]
    fn json_roundtrip_and_lenient_defaults() {
        let f = FaultConfig {
            flap: 0.125,
            crash: 0.5,
            delay: 0.25,
            delay_secs: 64.0,
            corrupt: 0.0625,
            duplicate: 0.75,
            fault_seed: 123,
        };
        let j = Json::parse(&f.to_json().to_string()).unwrap();
        assert_eq!(FaultConfig::from_json(&j), f);
        // configs without a faults object load as all-off
        let empty = Json::parse("{}").unwrap();
        assert_eq!(FaultConfig::from_json(&empty), FaultConfig::default());
    }
}
