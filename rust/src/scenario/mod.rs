//! The scenario engine: **named scenario presets** composing the
//! experiment axes (partition × availability × selector × round mode ×
//! population scale × fault mix) into registered, CLI-addressable cells,
//! plus the deterministic [`faults`] layer and the differential [`fuzz`]
//! harness that searches the whole config space for engine bugs.
//!
//! ```text
//!   presets (this module) ──► ExpConfig ──► engines (sync / async / frozen)
//!        ▲                        ▲
//!   relay run --scenario     faults::FaultConfig (seed-derived flap /
//!   relay scenario           crash / delay / corrupt / duplicate)
//!
//!   fuzz::run_fuzz ──► random scenario+seed tuples ──► invariant checks
//!        │                (engine-vs-reference, workers-1-vs-N,
//!        │                 accounting identity, JSON validity)
//!        └──► shrink ──► minimal repro ──► tests/corpus/*.json (replayed
//!                                          by tests/fuzz_corpus.rs)
//! ```
//!
//! The ROADMAP north star asks for "as many scenarios as you can imagine";
//! before this subsystem every cell was a hand-written config and the only
//! adversity was trace-driven availability. Presets make adversity
//! reproducible and addressable; the fuzzer manufactures the cells nobody
//! thought to write.

pub mod faults;
pub mod fuzz;

use crate::config::{AvailMode, ExpConfig, RoundMode};
use crate::data::partition::{LabelSkew, PartitionScheme};
use faults::FaultConfig;

/// One registered scenario: a named, fully-specified experiment cell.
pub struct ScenarioPreset {
    pub name: &'static str,
    pub summary: &'static str,
    pub cfg: ExpConfig,
}

/// Shared base: the CLI-runnable tiny variant sized so every preset runs in
/// seconds on the native backend (override `--learners/--rounds` to scale).
fn base() -> ExpConfig {
    ExpConfig {
        variant: "tiny".into(),
        total_learners: 60,
        rounds: 15,
        target_participants: 8,
        mean_samples: 16,
        test_per_class: 8,
        eval_every: 5,
        lr: 0.1,
        min_round_duration: 0.0,
        ..Default::default()
    }
}

/// Every registered scenario, in a stable order.
pub fn all() -> Vec<ScenarioPreset> {
    let mut out = Vec::new();

    // -- control cells -----------------------------------------------------
    let mut c = base();
    c.avail = AvailMode::AllAvail;
    out.push(ScenarioPreset {
        name: "baseline-oc",
        summary: "control: random selection, OC rounds, everyone available",
        cfg: c.with_label("baseline-oc"),
    });

    let mut c = base().relay();
    c.mode = RoundMode::Deadline { deadline: 60.0 };
    c.partition = PartitionScheme::LabelLimited { labels: 0, skew: LabelSkew::Zipf };
    out.push(ScenarioPreset {
        name: "paper-relay-dl",
        summary: "the paper's full RELAY stack (IPS+SAA+APT) on skewed data",
        cfg: c.with_label("paper-relay-dl"),
    });

    // -- adversity cells ---------------------------------------------------
    let mut c = base();
    c.selector = "oort".into();
    c.use_saa = true;
    c.staleness_threshold = Some(3);
    c.faults = FaultConfig { flap: 0.15, crash: 0.1, fault_seed: 1, ..Default::default() };
    out.push(ScenarioPreset {
        name: "flaky-fleet",
        summary: "trace churn plus check-in flaps and mid-task crashes",
        cfg: c.with_label("flaky-fleet"),
    });

    let mut c = base();
    c.selector = "safa".into();
    c.mode = RoundMode::Deadline { deadline: 30.0 };
    c.use_saa = true;
    c.staleness_threshold = Some(2);
    c.faults = FaultConfig { crash: 0.3, corrupt: 0.1, fault_seed: 2, ..Default::default() };
    out.push(ScenarioPreset {
        name: "crash-storm",
        summary: "SAFA under heavy mid-task crashes and corrupted updates",
        cfg: c.with_label("crash-storm"),
    });

    let mut c = base();
    c.selector = "priority".into();
    c.use_saa = true;
    c.mode = RoundMode::Async { buffer_k: 4, max_staleness: Some(2) };
    c.faults = FaultConfig {
        delay: 0.35,
        delay_secs: 400.0,
        fault_seed: 3,
        ..Default::default()
    };
    out.push(ScenarioPreset {
        name: "stale-storm",
        summary: "buffered-async with long transit delays vs a tight staleness bound",
        cfg: c.with_label("stale-storm"),
    });

    let mut c = base();
    c.selector = "oort".into();
    c.use_saa = true;
    c.staleness_threshold = Some(4);
    c.avail = AvailMode::AllAvail;
    c.faults = FaultConfig {
        corrupt: 0.25,
        duplicate: 0.2,
        fault_seed: 4,
        ..Default::default()
    };
    out.push(ScenarioPreset {
        name: "byzantine-lite",
        summary: "corrupted and duplicate deliveries exercising server-side rejection",
        cfg: c.with_label("byzantine-lite"),
    });

    let mut c = base().relay();
    c.mode = RoundMode::Deadline { deadline: 45.0 };
    c.min_round_duration = 30.0;
    c.faults = FaultConfig { flap: 0.2, fault_seed: 5, ..Default::default() };
    out.push(ScenarioPreset {
        name: "graveyard-shift",
        summary: "IPS chasing low-availability learners through heavy flapping",
        cfg: c.with_label("graveyard-shift"),
    });

    // -- data-shape cells --------------------------------------------------
    let mut c = base();
    c.selector = "oort".into();
    c.partition = PartitionScheme::FedScale;
    out.push(ScenarioPreset {
        name: "fedscale-longtail",
        summary: "long-tail FedScale-style shard sizes under utility selection",
        cfg: c.with_label("fedscale-longtail"),
    });

    // -- scale cell --------------------------------------------------------
    let mut c = base();
    c.total_learners = 50_000;
    c.rounds = 5;
    c.target_participants = 50;
    c.mode = RoundMode::Async { buffer_k: 10, max_staleness: None };
    c.mean_samples = 4;
    c.test_per_class = 2;
    c.eval_every = 1_000_000;
    out.push(ScenarioPreset {
        name: "mega-async",
        summary: "50k-learner lazy DynAvail buffered-async cell (scale smoke)",
        cfg: c.with_label("mega-async"),
    });

    // -- multi-job cells ---------------------------------------------------
    let mut c = base();
    c.total_learners = 80;
    c.rounds = 6;
    c.jobs = 4;
    c.job_policy = "fair".into();
    c.job_selectors =
        ["random", "oort", "priority", "random"].iter().map(|s| s.to_string()).collect();
    c.job_modes = ["oc1.3", "dl40", "async3", "oc"].iter().map(|s| s.to_string()).collect();
    c.job_targets = vec![6, 5, 4, 3];
    c.faults = FaultConfig { crash: 0.1, corrupt: 0.05, fault_seed: 6, ..Default::default() };
    out.push(ScenarioPreset {
        name: "job-storm",
        summary: "four mixed-mode jobs arbitrating one churning fleet under faults",
        cfg: c.with_label("job-storm"),
    });

    let mut c = base();
    c.total_learners = 12;
    c.rounds = 5;
    c.target_participants = 8;
    c.jobs = 3;
    c.job_policy = "priority".into();
    c.job_priorities = vec![9, 5, 1];
    c.job_targets = vec![8, 8, 8];
    c.avail = AvailMode::AllAvail;
    out.push(ScenarioPreset {
        name: "starved-low-priority",
        summary: "strict-priority jobs oversubscribing a pool too small for every target",
        cfg: c.with_label("starved-low-priority"),
    });

    // -- fuzz anchor -------------------------------------------------------
    let mut c = base();
    c.total_learners = 16;
    c.rounds = 4;
    c.target_participants = 3;
    c.mean_samples = 8;
    c.test_per_class = 2;
    out.push(ScenarioPreset {
        name: "tiny-smoke",
        summary: "minimal everything; the fuzz harness's smoke-scale anchor",
        cfg: c.with_label("tiny-smoke"),
    });

    out
}

/// Look up a registered scenario by name.
pub fn by_name(name: &str) -> Option<ScenarioPreset> {
    all().into_iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_validates_and_names_are_unique() {
        let presets = all();
        assert!(presets.len() >= 8, "expected a real scenario library");
        let mut names = std::collections::HashSet::new();
        for p in &presets {
            p.cfg.validate().unwrap_or_else(|e| panic!("{}: {e:#}", p.name));
            assert_eq!(p.cfg.label, p.name, "{}: label must equal the name", p.name);
            assert!(names.insert(p.name), "duplicate scenario name {}", p.name);
            assert!(!p.summary.is_empty());
        }
    }

    #[test]
    fn by_name_resolves_and_rejects() {
        assert_eq!(by_name("flaky-fleet").unwrap().name, "flaky-fleet");
        assert!(by_name("flaky-fleet").unwrap().cfg.faults.is_active());
        assert!(by_name("baseline-oc").unwrap().cfg.faults.label().is_empty());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn adversity_presets_cover_every_fault_class() {
        let presets = all();
        let covered = |pick: fn(&FaultConfig) -> f64| {
            presets.iter().any(|p| pick(&p.cfg.faults) > 0.0)
        };
        assert!(covered(|f| f.flap));
        assert!(covered(|f| f.crash));
        assert!(covered(|f| f.delay));
        assert!(covered(|f| f.corrupt));
        assert!(covered(|f| f.duplicate));
    }

    #[test]
    fn multijob_presets_are_registered_with_contending_targets() {
        let storm = by_name("job-storm").unwrap().cfg;
        assert_eq!(storm.jobs, 4);
        assert_eq!(storm.job_modes.len(), 4);
        assert!(storm.faults.is_active());
        let starved = by_name("starved-low-priority").unwrap().cfg;
        assert_eq!(starved.job_policy, "priority");
        let total: usize = starved.job_targets.iter().sum();
        assert!(
            total > starved.total_learners,
            "the starvation preset must oversubscribe the fleet"
        );
    }

    #[test]
    fn small_presets_run_end_to_end() {
        use crate::coordinator::run_experiment;
        use crate::runtime::{builtin_variant, NativeExecutor};
        use std::sync::Arc;
        // the cheap presets actually execute (scale cells are covered by
        // `relay bench` and the 20k/50k integration tests)
        for name in ["tiny-smoke", "flaky-fleet"] {
            let mut cfg = by_name(name).unwrap().cfg;
            cfg.total_learners = cfg.total_learners.min(24);
            cfg.rounds = cfg.rounds.min(4);
            cfg.mean_samples = cfg.mean_samples.min(8);
            cfg.test_per_class = cfg.test_per_class.min(2);
            let exec: Arc<dyn crate::runtime::Executor> =
                Arc::new(NativeExecutor::new(builtin_variant("tiny")));
            let r = run_experiment(cfg, exec).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(!r.rounds.is_empty(), "{name}");
        }
    }
}
