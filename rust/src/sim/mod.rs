//! Event-driven simulation core: the virtual clock, the availability view
//! (AllAvail vs DynAvail over a trace), the discrete-event kernel
//! ([`kernel::EventKernel`] — a unified heap of check-ins, task
//! completions, stale deliveries and evals with deterministic tie-breaking),
//! and the legacy pending-delivery queue ([`DeliveryQueue`], now a thin
//! wrapper over the kernel) used for post-deadline (stale) update arrivals.
//!
//! The paper's testbed time-multiplexes simulated learners on GPUs; here
//! *training math is real* (AOT HLO through PJRT) while *time* is simulated:
//! completion times come from device profiles, availability from traces.

// The replay oracle re-derives results from the kernel's event stream, so
// a panic here is a replay divergence waiting to happen: fallible paths
// must return errors, not unwrap.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod kernel;

pub use kernel::{EventClass, EventKernel, Scheduled};

use crate::trace::{LazyTraceSet, TraceSet};

/// Virtual wall-clock (seconds since experiment start).
#[derive(Clone, Copy, Debug, Default)]
pub struct Clock {
    pub now: f64,
}

impl Clock {
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "time cannot go backwards");
        self.now += dt;
    }
}

/// Availability dynamics (paper §3.3: AllAvail vs DynAvail).
pub enum Availability {
    /// Every learner is always available.
    All,
    /// Availability follows a fully-materialized charging trace.
    Dynamic(TraceSet),
    /// Availability follows a lazily-generated charging trace: a learner's
    /// week is generated at first touch, so 100k+-learner populations
    /// construct without any up-front trace work (bit-identical replay to
    /// `Dynamic` for the same seed).
    Lazy(LazyTraceSet),
}

impl Availability {
    pub fn parse(s: &str, trace: impl FnOnce() -> TraceSet) -> Option<Availability> {
        match s {
            "all" => Some(Availability::All),
            "dyn" => Some(Availability::Dynamic(trace())),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Availability::All => "AllAvail",
            Availability::Dynamic(_) | Availability::Lazy(_) => "DynAvail",
        }
    }

    pub fn available(&self, learner: usize, t: f64) -> bool {
        match self {
            Availability::All => true,
            Availability::Dynamic(tr) => tr.available(learner, t),
            Availability::Lazy(tr) => tr.available(learner, t),
        }
    }

    /// Available for the whole interval [t, t+dur]?
    pub fn available_through(&self, learner: usize, t: f64, dur: f64) -> bool {
        match self {
            Availability::All => true,
            Availability::Dynamic(tr) => tr.available_through(learner, t, dur),
            Availability::Lazy(tr) => tr.available_through(learner, t, dur),
        }
    }

    /// Sampled 0/1 availability series for one learner (the forecaster
    /// bootstrap input); `None` under AllAvail.
    pub fn sample_series(&self, learner: usize, step: f64) -> Option<Vec<f64>> {
        match self {
            Availability::All => None,
            Availability::Dynamic(tr) => Some(tr.sample_series(learner, step)),
            Availability::Lazy(tr) => Some(tr.sample_series(learner, step)),
        }
    }

    /// The eager trace, when this availability holds one (`Lazy` exposes
    /// its sessions through the query methods instead).
    pub fn trace(&self) -> Option<&TraceSet> {
        match self {
            Availability::Dynamic(tr) => Some(tr),
            _ => None,
        }
    }
}

/// A scheduled future delivery (straggler upload finishing after its round),
/// as returned by [`DeliveryQueue::due`]. This used to be the heap entry
/// itself, with a `partial_cmp(..).unwrap_or(Equal)` comparator that
/// silently corrupted heap order for non-finite times; ordering now lives
/// entirely in [`EventKernel`] (total-order comparator + non-finite times
/// rejected at insertion), and `Pending` is just the plain return value.
#[derive(Clone, Debug)]
pub struct Pending<T> {
    pub deliver_at: f64,
    pub item: T,
}

/// Min-heap of future deliveries — the pre-kernel API, now a thin wrapper
/// over [`EventKernel`] (class [`EventClass::Delivery`]), so it inherits the
/// kernel's deterministic FIFO tie-breaking and non-finite-time rejection.
pub struct DeliveryQueue<T> {
    kernel: EventKernel<T>,
}

impl<T> Default for DeliveryQueue<T> {
    fn default() -> Self {
        DeliveryQueue { kernel: EventKernel::default() }
    }
}

impl<T> DeliveryQueue<T> {
    /// Schedule a delivery. Panics on non-finite `deliver_at` (a NaN would
    /// silently corrupt heap order — see `Pending::cmp` above).
    pub fn push(&mut self, deliver_at: f64, item: T) {
        self.kernel.schedule(deliver_at, EventClass::Delivery, item);
    }

    /// Pop every item due at or before `t`, in delivery order (FIFO among
    /// equal `deliver_at`).
    pub fn due(&mut self, t: f64) -> Vec<Pending<T>> {
        self.kernel
            .pop_due(t)
            .into_iter()
            .map(|e| Pending { deliver_at: e.at, item: e.payload })
            .collect()
    }

    /// Iterate `(deliver_at, item)` still pending (e.g. APT's straggler
    /// probe), in unspecified (but deterministic) order.
    pub fn iter(&self) -> impl Iterator<Item = (f64, &T)> {
        self.kernel.iter().map(|e| (e.at, &e.payload))
    }

    pub fn len(&self) -> usize {
        self.kernel.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernel.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceConfig;

    #[test]
    fn clock_advances() {
        let mut c = Clock::default();
        c.advance(5.0);
        c.advance(2.5);
        assert_eq!(c.now, 7.5);
    }

    #[test]
    #[should_panic(expected = "time cannot go backwards")]
    #[cfg(debug_assertions)]
    fn clock_rejects_negative() {
        Clock::default().advance(-1.0);
    }

    #[test]
    fn all_avail_always_true() {
        let a = Availability::All;
        assert!(a.available(0, 0.0));
        assert!(a.available_through(99, 1e6, 1e6));
        assert_eq!(a.label(), "AllAvail");
    }

    #[test]
    fn dynamic_follows_trace() {
        let tr = TraceSet::generate(5, 1, TraceConfig::default());
        let (s, e) = tr.sessions[0][0];
        let a = Availability::Dynamic(tr);
        assert!(a.available(0, (s + e) / 2.0));
        assert_eq!(a.label(), "DynAvail");
    }

    #[test]
    fn lazy_availability_matches_eager() {
        let tr = TraceSet::generate(6, 9, TraceConfig::default());
        let lz = crate::trace::LazyTraceSet::new(6, 9, TraceConfig::default());
        let eager = Availability::Dynamic(tr);
        let lazy = Availability::Lazy(lz);
        assert_eq!(lazy.label(), "DynAvail");
        for l in 0..6 {
            for t in [0.0, 5_000.0, 200_000.0, 700_000.0] {
                assert_eq!(eager.available(l, t), lazy.available(l, t), "l={l} t={t}");
                assert_eq!(
                    eager.available_through(l, t, 900.0),
                    lazy.available_through(l, t, 900.0)
                );
            }
            assert_eq!(eager.sample_series(l, 1800.0), lazy.sample_series(l, 1800.0));
        }
        assert!(lazy.trace().is_none() && eager.trace().is_some());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn delivery_queue_rejects_nan_times() {
        // Regression: a NaN deliver_at used to enter the heap and compare
        // Equal to everything, silently corrupting delivery order.
        let mut q = DeliveryQueue::default();
        q.push(f64::NAN, "x");
    }

    #[test]
    fn delivery_queue_breaks_ties_fifo() {
        let mut q = DeliveryQueue::default();
        q.push(2.0, "first");
        q.push(2.0, "second");
        q.push(2.0, "third");
        let items: Vec<&str> = q.due(2.0).into_iter().map(|p| p.item).collect();
        assert_eq!(items, vec!["first", "second", "third"]);
    }

    #[test]
    fn delivery_queue_orders_by_time() {
        let mut q = DeliveryQueue::default();
        q.push(10.0, "c");
        q.push(1.0, "a");
        q.push(5.0, "b");
        assert_eq!(q.len(), 3);
        let due = q.due(6.0);
        let items: Vec<&str> = due.iter().map(|p| p.item).collect();
        assert_eq!(items, vec!["a", "b"]);
        assert_eq!(q.len(), 1);
        assert!(q.due(9.0).is_empty());
        assert_eq!(q.due(10.0)[0].item, "c");
        assert!(q.is_empty());
    }
}
