//! Event-driven simulation core: the virtual clock, the availability view
//! (AllAvail vs DynAvail over a trace), and a pending-delivery queue used
//! for post-deadline (stale) update arrivals.
//!
//! The paper's testbed time-multiplexes simulated learners on GPUs; here
//! *training math is real* (AOT HLO through PJRT) while *time* is simulated:
//! completion times come from device profiles, availability from traces.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::trace::{LazyTraceSet, TraceSet};

/// Virtual wall-clock (seconds since experiment start).
#[derive(Clone, Copy, Debug, Default)]
pub struct Clock {
    pub now: f64,
}

impl Clock {
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "time cannot go backwards");
        self.now += dt;
    }
}

/// Availability dynamics (paper §3.3: AllAvail vs DynAvail).
pub enum Availability {
    /// Every learner is always available.
    All,
    /// Availability follows a fully-materialized charging trace.
    Dynamic(TraceSet),
    /// Availability follows a lazily-generated charging trace: a learner's
    /// week is generated at first touch, so 100k+-learner populations
    /// construct without any up-front trace work (bit-identical replay to
    /// `Dynamic` for the same seed).
    Lazy(LazyTraceSet),
}

impl Availability {
    pub fn parse(s: &str, trace: impl FnOnce() -> TraceSet) -> Option<Availability> {
        match s {
            "all" => Some(Availability::All),
            "dyn" => Some(Availability::Dynamic(trace())),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Availability::All => "AllAvail",
            Availability::Dynamic(_) | Availability::Lazy(_) => "DynAvail",
        }
    }

    pub fn available(&self, learner: usize, t: f64) -> bool {
        match self {
            Availability::All => true,
            Availability::Dynamic(tr) => tr.available(learner, t),
            Availability::Lazy(tr) => tr.available(learner, t),
        }
    }

    /// Available for the whole interval [t, t+dur]?
    pub fn available_through(&self, learner: usize, t: f64, dur: f64) -> bool {
        match self {
            Availability::All => true,
            Availability::Dynamic(tr) => tr.available_through(learner, t, dur),
            Availability::Lazy(tr) => tr.available_through(learner, t, dur),
        }
    }

    /// Sampled 0/1 availability series for one learner (the forecaster
    /// bootstrap input); `None` under AllAvail.
    pub fn sample_series(&self, learner: usize, step: f64) -> Option<Vec<f64>> {
        match self {
            Availability::All => None,
            Availability::Dynamic(tr) => Some(tr.sample_series(learner, step)),
            Availability::Lazy(tr) => Some(tr.sample_series(learner, step)),
        }
    }

    /// The eager trace, when this availability holds one (`Lazy` exposes
    /// its sessions through the query methods instead).
    pub fn trace(&self) -> Option<&TraceSet> {
        match self {
            Availability::Dynamic(tr) => Some(tr),
            _ => None,
        }
    }
}

/// A scheduled future delivery (straggler upload finishing after its round).
#[derive(Clone, Debug)]
pub struct Pending<T> {
    pub deliver_at: f64,
    pub item: T,
}

impl<T> PartialEq for Pending<T> {
    fn eq(&self, other: &Self) -> bool {
        self.deliver_at == other.deliver_at
    }
}
impl<T> Eq for Pending<T> {}
impl<T> PartialOrd for Pending<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Pending<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on deliver_at
        other
            .deliver_at
            .partial_cmp(&self.deliver_at)
            .unwrap_or(Ordering::Equal)
    }
}

/// Min-heap of future deliveries.
pub struct DeliveryQueue<T> {
    heap: BinaryHeap<Pending<T>>,
}

impl<T> Default for DeliveryQueue<T> {
    fn default() -> Self {
        DeliveryQueue { heap: BinaryHeap::new() }
    }
}

impl<T> DeliveryQueue<T> {
    pub fn push(&mut self, deliver_at: f64, item: T) {
        self.heap.push(Pending { deliver_at, item });
    }

    /// Pop every item due at or before `t`, in delivery order.
    pub fn due(&mut self, t: f64) -> Vec<Pending<T>> {
        let mut out = Vec::new();
        while let Some(top) = self.heap.peek() {
            if top.deliver_at <= t {
                out.push(self.heap.pop().unwrap());
            } else {
                break;
            }
        }
        out
    }

    /// Iterate items still pending (e.g. APT's straggler probe).
    pub fn iter(&self) -> impl Iterator<Item = &Pending<T>> {
        self.heap.iter()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceConfig;

    #[test]
    fn clock_advances() {
        let mut c = Clock::default();
        c.advance(5.0);
        c.advance(2.5);
        assert_eq!(c.now, 7.5);
    }

    #[test]
    #[should_panic(expected = "time cannot go backwards")]
    #[cfg(debug_assertions)]
    fn clock_rejects_negative() {
        Clock::default().advance(-1.0);
    }

    #[test]
    fn all_avail_always_true() {
        let a = Availability::All;
        assert!(a.available(0, 0.0));
        assert!(a.available_through(99, 1e6, 1e6));
        assert_eq!(a.label(), "AllAvail");
    }

    #[test]
    fn dynamic_follows_trace() {
        let tr = TraceSet::generate(5, 1, TraceConfig::default());
        let (s, e) = tr.sessions[0][0];
        let a = Availability::Dynamic(tr);
        assert!(a.available(0, (s + e) / 2.0));
        assert_eq!(a.label(), "DynAvail");
    }

    #[test]
    fn lazy_availability_matches_eager() {
        let tr = TraceSet::generate(6, 9, TraceConfig::default());
        let lz = crate::trace::LazyTraceSet::new(6, 9, TraceConfig::default());
        let eager = Availability::Dynamic(tr);
        let lazy = Availability::Lazy(lz);
        assert_eq!(lazy.label(), "DynAvail");
        for l in 0..6 {
            for t in [0.0, 5_000.0, 200_000.0, 700_000.0] {
                assert_eq!(eager.available(l, t), lazy.available(l, t), "l={l} t={t}");
                assert_eq!(
                    eager.available_through(l, t, 900.0),
                    lazy.available_through(l, t, 900.0)
                );
            }
            assert_eq!(eager.sample_series(l, 1800.0), lazy.sample_series(l, 1800.0));
        }
        assert!(lazy.trace().is_none() && eager.trace().is_some());
    }

    #[test]
    fn delivery_queue_orders_by_time() {
        let mut q = DeliveryQueue::default();
        q.push(10.0, "c");
        q.push(1.0, "a");
        q.push(5.0, "b");
        assert_eq!(q.len(), 3);
        let due = q.due(6.0);
        let items: Vec<&str> = due.iter().map(|p| p.item).collect();
        assert_eq!(items, vec!["a", "b"]);
        assert_eq!(q.len(), 1);
        assert!(q.due(9.0).is_empty());
        assert_eq!(q.due(10.0)[0].item, "c");
        assert!(q.is_empty());
    }
}
