//! The discrete-event kernel: one unified min-heap of timestamped events
//! (check-ins, task completions/departures, stale deliveries, evaluations)
//! with fully deterministic ordering, generalizing the original
//! stale-delivery-only [`crate::sim::DeliveryQueue`].
//!
//! Ordering is the triple `(at, class, seq)`:
//!
//! * `at` — event time, compared with `f64::total_cmp` (never `partial_cmp`,
//!   whose `None` on NaN silently corrupted heap order in the pre-kernel
//!   queue). Non-finite times are rejected at insertion, so a NaN produced
//!   by upstream timing math fails loudly instead of reordering the heap.
//! * `class` — [`EventClass`] priority among same-time events (deliveries
//!   before departures before evals before check-ins), so simultaneous
//!   events of different kinds resolve the same way on every run.
//! * `seq` — monotonically increasing insertion index: same-time same-class
//!   events pop in FIFO order regardless of how insertions interleave
//!   (tests/substrate_props.rs locks this in).
//!
//! The kernel also carries the virtual clock: `pop_next` advances `now` to
//! the popped event's time, which is how the asynchronous (buffered) round
//! regime advances time; round-synchronous drivers instead use `pop_due` +
//! `advance_to` to sweep a whole round window at once.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Priority class breaking ties among events scheduled at the same instant.
/// Lower-numbered classes pop first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventClass {
    /// An update arriving at the server (fresh/stale delivery, async task
    /// completion).
    Delivery = 0,
    /// A learner leaving mid-task (dropout) without delivering.
    Departure = 1,
    /// A scheduled evaluation.
    Eval = 2,
    /// A (re-)selection opportunity: the async regime's check-in retry.
    CheckIn = 3,
    /// An availability transition (a learner's charging session starting or
    /// ending). Used by `population::AvailabilityIndex`, which runs these on
    /// its own `EventKernel` instance — one pending transition per learner —
    /// so they never interleave with (or reorder) engine events.
    Availability = 4,
}

impl EventClass {
    /// Stable wire code for the run log.
    pub fn code(self) -> u8 {
        self as u8
    }

    pub fn from_code(code: u8) -> Option<EventClass> {
        match code {
            0 => Some(EventClass::Delivery),
            1 => Some(EventClass::Departure),
            2 => Some(EventClass::Eval),
            3 => Some(EventClass::CheckIn),
            4 => Some(EventClass::Availability),
            _ => None,
        }
    }
}

/// One scheduled event, as returned by [`EventKernel::pop_next`]/`pop_due`.
#[derive(Clone, Debug)]
pub struct Scheduled<P> {
    /// Absolute event time (seconds since experiment start). Always finite.
    pub at: f64,
    pub class: EventClass,
    /// Insertion index: FIFO order among `(at, class)` ties.
    pub seq: u64,
    pub payload: P,
}

impl<P> PartialEq for Scheduled<P> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl<P> Eq for Scheduled<P> {}
impl<P> PartialOrd for Scheduled<P> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<P> Ord for Scheduled<P> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest
        // (at, class, seq) triple on top.
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.class.cmp(&self.class))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Unified event heap + virtual clock. See the module docs for ordering.
pub struct EventKernel<P> {
    heap: BinaryHeap<Scheduled<P>>,
    next_seq: u64,
    now: f64,
}

impl<P> Default for EventKernel<P> {
    fn default() -> Self {
        EventKernel { heap: BinaryHeap::new(), next_seq: 0, now: 0.0 }
    }
}

impl<P> EventKernel<P> {
    /// Current virtual time (seconds since experiment start).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute time `at`.
    ///
    /// Panics on non-finite `at` (a NaN/inf would corrupt heap order — the
    /// hazard the pre-kernel `Pending::cmp` silently swallowed) and on
    /// scheduling into the past.
    pub fn schedule(&mut self, at: f64, class: EventClass, payload: P) {
        assert!(at.is_finite(), "event kernel: non-finite event time {at}");
        assert!(
            at >= self.now,
            "event kernel: event at {at} is before now {}",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { at, class, seq, payload });
    }

    /// Pop the earliest event and advance the clock to its time.
    pub fn pop_next(&mut self) -> Option<Scheduled<P>> {
        let ev = self.heap.pop()?;
        self.now = ev.at; // >= now: enforced at schedule time
        Some(ev)
    }

    /// Pop every event due at or before `t`, in deterministic
    /// `(at, class, seq)` order, without touching the clock (the
    /// round-synchronous drivers sweep a whole round window at once).
    pub fn pop_due(&mut self, t: f64) -> Vec<Scheduled<P>> {
        let mut out = Vec::new();
        loop {
            match self.heap.peek() {
                Some(top) if top.at <= t => {
                    if let Some(ev) = self.heap.pop() {
                        out.push(ev);
                    }
                }
                _ => break,
            }
        }
        out
    }

    /// Advance the clock without popping (round-synchronous drivers).
    ///
    /// Panics on `t < now` in all build profiles: a backwards clock would
    /// let `schedule`/`pop_due` boundary semantics diverge between the
    /// engine and the replay oracle, which both assume monotone time.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t >= self.now, "time cannot go backwards");
        if t > self.now {
            self.now = t;
        }
    }

    /// Time of the earliest pending event, if any.
    pub fn peek_at(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.at)
    }

    /// Iterate pending events in unspecified (but deterministic) order —
    /// for order-insensitive probes like APT's straggler scan.
    pub fn iter(&self) -> impl Iterator<Item = &Scheduled<P>> {
        self.heap.iter()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_and_advances_clock() {
        let mut k = EventKernel::default();
        k.schedule(10.0, EventClass::Delivery, "c");
        k.schedule(1.0, EventClass::Delivery, "a");
        k.schedule(5.0, EventClass::Delivery, "b");
        assert_eq!(k.len(), 3);
        assert_eq!(k.peek_at(), Some(1.0));
        let first = k.pop_next().unwrap();
        assert_eq!((first.at, first.payload), (1.0, "a"));
        assert_eq!(k.now(), 1.0);
        let rest: Vec<&str> = k.pop_due(10.0).into_iter().map(|e| e.payload).collect();
        assert_eq!(rest, vec!["b", "c"]);
        assert!(k.is_empty());
    }

    #[test]
    fn same_time_orders_by_class_then_fifo() {
        let mut k = EventKernel::default();
        k.schedule(3.0, EventClass::CheckIn, 0);
        k.schedule(3.0, EventClass::Delivery, 1);
        k.schedule(3.0, EventClass::Delivery, 2);
        k.schedule(3.0, EventClass::Departure, 3);
        k.schedule(3.0, EventClass::Eval, 4);
        let order: Vec<i32> = k.pop_due(3.0).into_iter().map(|e| e.payload).collect();
        // deliveries (FIFO) -> departure -> eval -> check-in
        assert_eq!(order, vec![1, 2, 3, 4, 0]);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_nan_times() {
        let mut k = EventKernel::default();
        k.schedule(f64::NAN, EventClass::Delivery, ());
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn rejects_infinite_times() {
        let mut k = EventKernel::default();
        k.schedule(f64::INFINITY, EventClass::Delivery, ());
    }

    #[test]
    #[should_panic(expected = "is before now")]
    fn rejects_scheduling_into_the_past() {
        let mut k = EventKernel::default();
        k.schedule(5.0, EventClass::Delivery, ());
        k.pop_next();
        k.schedule(1.0, EventClass::Delivery, ());
    }

    #[test]
    fn schedule_at_drain_boundary_delivers_exactly_once() {
        // Regression: an event scheduled exactly at the drain time `t`
        // *after* a partial drain of that instant must still be delivered
        // by the next sweep — once — and never re-delivered.
        let mut k = EventKernel::default();
        k.schedule(5.0, EventClass::Delivery, 1);
        let first = k.pop_due(5.0);
        assert_eq!(first.len(), 1);
        k.schedule(5.0, EventClass::Delivery, 2);
        let second = k.pop_due(5.0);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].payload, 2);
        assert!(k.pop_due(5.0).is_empty(), "no re-delivery");
    }

    #[test]
    fn advance_then_schedule_at_now_pops() {
        // Scheduling exactly at `now` is legal (schedule uses `>=`) and the
        // event must pop immediately, leaving the clock where it was.
        let mut k = EventKernel::default();
        k.advance_to(3.0);
        k.schedule(3.0, EventClass::CheckIn, 7);
        let ev = k.pop_next().unwrap();
        assert_eq!((ev.at, ev.payload), (3.0, 7));
        assert_eq!(k.now(), 3.0);
    }

    #[test]
    #[should_panic(expected = "time cannot go backwards")]
    fn advance_backwards_panics() {
        let mut k: EventKernel<()> = EventKernel::default();
        k.advance_to(2.0);
        k.advance_to(1.0);
    }

    #[test]
    fn pop_due_leaves_clock_and_later_events() {
        let mut k = EventKernel::default();
        k.schedule(1.0, EventClass::Delivery, 1);
        k.schedule(2.0, EventClass::Delivery, 2);
        let due = k.pop_due(1.5);
        assert_eq!(due.len(), 1);
        assert_eq!(k.now(), 0.0);
        k.advance_to(2.0);
        assert_eq!(k.now(), 2.0);
        assert_eq!(k.pop_next().unwrap().payload, 2);
    }
}
