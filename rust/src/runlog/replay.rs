//! Replay: re-derive a full `ExperimentResult` from a run log alone.
//!
//! This is deliberately a *second, independent* implementation of the
//! engines' bookkeeping — a pair of event reducers (one round-synchronous,
//! one buffered-async) that rebuild every round record, accounting total,
//! and fault counter from the logged event stream, sharing no code with
//! `coordinator/`. The fuzzer compares the replayed result byte-for-byte
//! against the engine's JSON, which turns every logged run into its own
//! oracle — including the async regime, which the frozen sync reference
//! cannot cross-check.
//!
//! Replay is strict: an event arriving in a state the engines could never
//! produce (a delivery with nothing in flight, a merge without a full
//! buffer, an eval on a non-eval round) is an error, not a best-effort
//! guess — those are exactly the divergences the oracle exists to catch.
//! All f64 arithmetic mirrors the engines' operation order exactly, so the
//! derived JSON matches bit-for-bit, not just within epsilon.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::metrics::{ExperimentResult, RoundRecord};
use crate::scenario::faults::FaultKind;

use super::{RunEvent, FATE_CORRUPT, FATE_DOOMED, FATE_TRAINED};

/// Relative tolerance for the order-insensitive cross-checks (the sync
/// leftover sweep sums the heap in unspecified order, so only an
/// epsilon-level check is meaningful there; everything else is bit-exact).
const REL_EPS: f64 = 1e-6;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_EPS * a.abs().max(b.abs()).max(1.0)
}

/// Everything the reducers need from the `RunStart` header.
struct Header {
    buffer_k: usize,
    max_staleness: Option<u64>,
    rounds: u64,
    eval_every: u64,
    use_saa: bool,
    staleness_threshold: Option<u64>,
}

/// Rebuild the full experiment result from a decoded event stream.
pub fn replay(events: &[RunEvent]) -> Result<ExperimentResult> {
    let first = events.first().ok_or_else(|| anyhow!("replay: empty run log"))?;
    let RunEvent::RunStart {
        label,
        perplexity,
        mode,
        buffer_k,
        max_staleness,
        rounds,
        eval_every,
        use_saa,
        staleness_threshold,
    } = first
    else {
        bail!("replay: log must open with RunStart, got {first:?}");
    };
    if *eval_every == 0 {
        bail!("replay: eval_every must be >= 1");
    }
    let hdr = Header {
        buffer_k: *buffer_k as usize,
        max_staleness: *max_staleness,
        rounds: *rounds,
        eval_every: *eval_every,
        use_saa: *use_saa,
        staleness_threshold: *staleness_threshold,
    };
    let records = match mode {
        0 | 1 => replay_sync(&hdr, &events[1..])?,
        2 => replay_async(&hdr, &events[1..])?,
        m => bail!("replay: unknown mode code {m}"),
    };
    Ok(ExperimentResult {
        label: label.clone(),
        rounds: records,
        perplexity_metric: *perplexity,
    })
}

// ----------------------------------------------------- sync (OC/DL) ------

/// In-progress round state for the synchronous reducer.
#[derive(Default)]
struct SyncRound {
    round: u64,
    now: f64,
    selected: usize,
    dropouts: usize,
    discarded: usize,
    faults: usize,
    fresh: usize,
    stale: usize,
    loss_sum: f64,
    loss_n: usize,
    eval: Option<(f64, f64)>,
}

fn open_round<'a>(cur: &'a mut Option<SyncRound>, i: usize) -> Result<&'a mut SyncRound> {
    cur.as_mut()
        .ok_or_else(|| anyhow!("replay: event {i} arrived outside any round"))
}

fn replay_sync(hdr: &Header, events: &[RunEvent]) -> Result<Vec<RoundRecord>> {
    let mut recs: Vec<RoundRecord> = Vec::new();
    let mut cur: Option<SyncRound> = None;
    let mut spent = 0.0f64;
    let mut wasted = 0.0f64;
    let mut aggregated = 0.0f64;
    let mut unique: HashSet<u64> = HashSet::new();
    // stale updates in flight: (learner, origin round) -> device-seconds
    let mut outstanding: HashMap<(u64, u64), f64> = HashMap::new();
    let mut swept = false;
    let mut ended = false;
    for (i, ev) in events.iter().enumerate() {
        if ended {
            bail!("replay: event {i} after RunEnd: {ev:?}");
        }
        match ev {
            RunEvent::RoundStart { round, now } => {
                if cur.is_some() {
                    bail!("replay: RoundStart at event {i} inside an open round");
                }
                if *round != recs.len() as u64 {
                    bail!(
                        "replay: RoundStart for round {round} at event {i}, expected {}",
                        recs.len()
                    );
                }
                cur = Some(SyncRound { round: *round, now: *now, ..Default::default() });
            }
            RunEvent::Eligibility { .. } => {}
            RunEvent::Selected { .. } => {
                open_round(&mut cur, i)?.selected += 1;
            }
            RunEvent::FaultDecision { kind, .. } => {
                let c = open_round(&mut cur, i)?;
                c.faults += 1;
                // a flap is the one fault the sync engine also counts as a
                // dropout (the task never starts, so no TaskDropout event
                // will follow)
                if FaultKind::from_code(*kind) == Some(FaultKind::Flap) {
                    c.dropouts += 1;
                }
            }
            RunEvent::TaskDropout { learner, spent: sp } => {
                let c = open_round(&mut cur, i)?;
                spent += sp;
                unique.insert(*learner);
                wasted += sp;
                c.dropouts += 1;
            }
            RunEvent::StragglerSpend { learner, duration, fate } => {
                let c = open_round(&mut cur, i)?;
                spent += duration;
                unique.insert(*learner);
                match *fate {
                    FATE_TRAINED => {}
                    FATE_CORRUPT | FATE_DOOMED => {
                        wasted += duration;
                        c.discarded += 1;
                    }
                    f => bail!("replay: unknown straggler fate {f} at event {i}"),
                }
            }
            RunEvent::FreshSpend { learner, duration, corrupt } => {
                let c = open_round(&mut cur, i)?;
                spent += duration;
                unique.insert(*learner);
                if *corrupt {
                    wasted += duration;
                    c.discarded += 1;
                }
            }
            RunEvent::Trained { learner, mean_loss, duration, fresh } => {
                let c = open_round(&mut cur, i)?;
                c.loss_sum += mean_loss;
                c.loss_n += 1;
                if *fresh {
                    aggregated += duration;
                    c.fresh += 1;
                } else if outstanding.insert((*learner, c.round), *duration).is_some() {
                    bail!(
                        "replay: learner {learner} already has an update in \
                         flight from round {} (event {i})",
                        c.round
                    );
                }
            }
            RunEvent::StaleDelivery { learner, origin_round, duration } => {
                let c = open_round(&mut cur, i)?;
                let dur = outstanding.remove(&(*learner, *origin_round)).ok_or_else(|| {
                    anyhow!(
                        "replay: stale delivery at event {i} for learner {learner} \
                         round {origin_round} with nothing in flight"
                    )
                })?;
                if dur.to_bits() != duration.to_bits() {
                    bail!(
                        "replay: stale delivery duration {duration} disagrees with \
                         the spawned {dur} (event {i})"
                    );
                }
                if *origin_round > c.round {
                    bail!("replay: stale delivery from the future at event {i}");
                }
                let tau = c.round - origin_round;
                let within =
                    hdr.staleness_threshold.map(|th| tau <= th).unwrap_or(true);
                if hdr.use_saa && within {
                    aggregated += duration;
                    c.stale += 1;
                } else {
                    wasted += duration;
                    c.discarded += 1;
                }
            }
            RunEvent::EvalDone { loss, acc } => {
                let c = open_round(&mut cur, i)?;
                if c.eval.is_some() {
                    bail!("replay: second EvalDone in round {} (event {i})", c.round);
                }
                c.eval = Some((*loss, *acc));
            }
            RunEvent::RoundEnd { round_duration } => {
                let c = cur
                    .take()
                    .ok_or_else(|| anyhow!("replay: RoundEnd at event {i} with no round"))?;
                let expected_eval = c.selected > 0
                    && ((c.round + 1) % hdr.eval_every == 0 || c.round + 1 == hdr.rounds);
                if expected_eval != c.eval.is_some() {
                    bail!(
                        "replay: round {} eval mismatch (expected {expected_eval}, \
                         logged {})",
                        c.round,
                        c.eval.is_some()
                    );
                }
                recs.push(RoundRecord {
                    round: c.round as usize,
                    sim_time: c.now + round_duration,
                    round_duration: *round_duration,
                    selected: c.selected,
                    fresh_updates: c.fresh,
                    stale_updates: c.stale,
                    dropouts: c.dropouts,
                    discarded: c.discarded,
                    faults: c.faults,
                    cum_resource_secs: spent,
                    cum_waste_secs: wasted,
                    unique_participants: unique.len(),
                    failed: c.fresh == 0 && c.stale == 0,
                    train_loss: (c.loss_n > 0).then(|| c.loss_sum / c.loss_n as f64),
                    test_accuracy: c.eval.map(|(_, a)| a),
                    test_loss: c.eval.map(|(l, _)| l),
                    ..Default::default()
                });
            }
            RunEvent::SweepLeftover { secs } => {
                if cur.is_some() {
                    bail!("replay: SweepLeftover at event {i} inside an open round");
                }
                if swept {
                    bail!("replay: second SweepLeftover at event {i}");
                }
                // the engine sums its heap in unspecified order, so only an
                // epsilon cross-check is possible; the *logged* value is
                // what feeds the byte-exact waste total
                let pending: f64 = outstanding.values().sum();
                if !close(*secs, pending) {
                    bail!(
                        "replay: leftover sweep {secs} disagrees with the {pending} \
                         still outstanding (event {i})"
                    );
                }
                wasted += secs;
                if let Some(last) = recs.last_mut() {
                    last.cum_waste_secs = wasted;
                }
                outstanding.clear();
                swept = true;
            }
            RunEvent::RunEnd => {
                if cur.is_some() {
                    bail!("replay: RunEnd at event {i} inside an open round");
                }
                if !swept {
                    bail!("replay: RunEnd at event {i} without a leftover sweep");
                }
                if recs.len() as u64 != hdr.rounds {
                    bail!(
                        "replay: log ended after {} rounds, header promised {}",
                        recs.len(),
                        hdr.rounds
                    );
                }
                if !close(spent, aggregated + wasted) {
                    bail!(
                        "replay: accounting identity broken: spent {spent} != \
                         aggregated {aggregated} + wasted {wasted}"
                    );
                }
                ended = true;
            }
            other => bail!("replay: async-only event {other:?} in a sync log (event {i})"),
        }
    }
    if !ended {
        bail!("replay: log ends without RunEnd ({} events)", events.len());
    }
    Ok(recs)
}

// ------------------------------------------------- async (buffered) ------

fn replay_async(hdr: &Header, events: &[RunEvent]) -> Result<Vec<RoundRecord>> {
    let mut recs: Vec<RoundRecord> = Vec::new();
    let mut version: u64 = 0;
    let mut in_flight: usize = 0;
    let mut in_flight_secs = 0.0f64;
    // buffered unmerged updates: (origin version, device-seconds, mean loss)
    let mut buffer: Vec<(u64, f64, f64)> = Vec::new();
    // per-merge-interval counters
    let mut selected = 0usize;
    let mut dropouts = 0usize;
    let mut discarded = 0usize;
    let mut faults = 0usize;
    let mut events_n = 0usize;
    let mut interval_start = 0.0f64;
    let mut conc_area = 0.0f64;
    let mut conc_last_t = 0.0f64;
    let mut expect_merge = false;
    // run-wide accounting
    let mut spent = 0.0f64;
    let mut wasted = 0.0f64;
    let mut aggregated = 0.0f64;
    let mut unique: HashSet<u64> = HashSet::new();
    let mut swept = false;
    let mut ended = false;
    for (i, ev) in events.iter().enumerate() {
        if ended {
            bail!("replay: event {i} after RunEnd: {ev:?}");
        }
        if expect_merge && !matches!(ev, RunEvent::MergeCommit { .. }) {
            bail!(
                "replay: buffer reached K but event {i} is {ev:?}, not a MergeCommit"
            );
        }
        match ev {
            RunEvent::KernelPop { at, class: _ } => {
                events_n += 1;
                conc_area += in_flight as f64 * (at - conc_last_t);
                conc_last_t = *at;
            }
            RunEvent::Eligibility { .. } => {}
            RunEvent::FaultDecision { kind, .. } => {
                faults += 1;
                // the async engine counts a flapped learner as selected and
                // dropped at decision time (no task ever spawns for it)
                if FaultKind::from_code(*kind) == Some(FaultKind::Flap) {
                    selected += 1;
                    dropouts += 1;
                }
            }
            RunEvent::AsyncSpawn { learner, duration, dropped_after } => {
                let secs = dropped_after.unwrap_or(*duration);
                spent += secs;
                unique.insert(*learner);
                in_flight_secs += secs;
                in_flight += 1;
                selected += 1;
            }
            RunEvent::AsyncDropout { learner: _, spent: sp } => {
                in_flight = in_flight
                    .checked_sub(1)
                    .ok_or_else(|| anyhow!("replay: dropout at event {i} with nothing in flight"))?;
                in_flight_secs -= sp;
                dropouts += 1;
                wasted += sp;
            }
            RunEvent::AsyncDelivery {
                learner: _,
                origin_version,
                duration,
                mean_loss,
                corrupt,
            } => {
                in_flight = in_flight.checked_sub(1).ok_or_else(|| {
                    anyhow!("replay: delivery at event {i} with nothing in flight")
                })?;
                if *corrupt {
                    wasted += duration;
                    in_flight_secs -= duration;
                    discarded += 1;
                } else {
                    if *origin_version > version {
                        bail!("replay: delivery from future version at event {i}");
                    }
                    let tau = version - origin_version;
                    let within = hdr.max_staleness.map(|m| tau <= m).unwrap_or(true);
                    if within {
                        buffer.push((*origin_version, *duration, *mean_loss));
                        if buffer.len() >= hdr.buffer_k {
                            expect_merge = true;
                        }
                    } else {
                        wasted += duration;
                        in_flight_secs -= duration;
                        discarded += 1;
                    }
                }
            }
            RunEvent::MergeCommit { eval } => {
                if !expect_merge {
                    bail!("replay: MergeCommit at event {i} without a full buffer");
                }
                expect_merge = false;
                let end = conc_last_t;
                let entries = std::mem::take(&mut buffer);
                // the engine re-checks staleness against the *current*
                // version at merge time (versions may have advanced since
                // an update was buffered... they cannot here, since merges
                // fire the moment the buffer fills, but the engine guards
                // it and so does replay)
                let mut kept: Vec<(u64, f64, f64)> = Vec::new();
                for (origin, duration, mean_loss) in entries {
                    let tau = version - origin;
                    let within = hdr.max_staleness.map(|m| tau <= m).unwrap_or(true);
                    if within {
                        kept.push((origin, duration, mean_loss));
                    } else {
                        wasted += duration;
                        in_flight_secs -= duration;
                        discarded += 1;
                    }
                }
                let fresh = kept.iter().filter(|(o, _, _)| *o == version).count();
                let stale = kept.len() - fresh;
                let failed = kept.is_empty();
                let train_loss = (!kept.is_empty())
                    .then(|| kept.iter().map(|(_, _, l)| *l).sum::<f64>() / kept.len() as f64);
                for (_, duration, _) in &kept {
                    aggregated += duration;
                    in_flight_secs -= duration;
                }
                let interval = end - interval_start;
                let mean_conc =
                    if interval > 0.0 { conc_area / interval } else { in_flight as f64 };
                let mut rec = RoundRecord {
                    round: version as usize,
                    sim_time: end,
                    round_duration: interval,
                    selected,
                    fresh_updates: fresh,
                    stale_updates: stale,
                    dropouts,
                    discarded,
                    faults,
                    cum_resource_secs: spent,
                    cum_waste_secs: wasted,
                    unique_participants: unique.len(),
                    failed,
                    train_loss,
                    mean_concurrency: Some(mean_conc),
                    cum_aggregated_secs: Some(aggregated),
                    in_flight_secs: Some(in_flight_secs),
                    kernel_events: Some(events_n),
                    ..Default::default()
                };
                version += 1;
                let expected_eval =
                    version % hdr.eval_every == 0 || version == hdr.rounds;
                if expected_eval != eval.is_some() {
                    bail!(
                        "replay: version {version} eval mismatch (expected \
                         {expected_eval}, logged {})",
                        eval.is_some()
                    );
                }
                if let Some((loss, acc)) = eval {
                    rec.test_loss = Some(*loss);
                    rec.test_accuracy = Some(*acc);
                }
                recs.push(rec);
                selected = 0;
                dropouts = 0;
                discarded = 0;
                faults = 0;
                events_n = 0;
                interval_start = end;
                conc_area = 0.0;
                conc_last_t = end;
            }
            RunEvent::AsyncBurn { end } => {
                // a starved interval: nothing in flight, so the engine jumps
                // the clock without integrating concurrency area
                conc_last_t = *end;
                let interval = end - interval_start;
                let mean_conc =
                    if interval > 0.0 { conc_area / interval } else { in_flight as f64 };
                recs.push(RoundRecord {
                    round: version as usize,
                    sim_time: *end,
                    round_duration: interval,
                    selected,
                    dropouts,
                    discarded,
                    faults,
                    cum_resource_secs: spent,
                    cum_waste_secs: wasted,
                    unique_participants: unique.len(),
                    failed: true,
                    mean_concurrency: Some(mean_conc),
                    cum_aggregated_secs: Some(aggregated),
                    in_flight_secs: Some(in_flight_secs),
                    kernel_events: Some(events_n),
                    ..Default::default()
                });
                version += 1;
                selected = 0;
                dropouts = 0;
                discarded = 0;
                faults = 0;
                events_n = 0;
                interval_start = *end;
                conc_area = 0.0;
            }
            RunEvent::SweepLeftover { secs } => {
                if swept {
                    bail!("replay: second SweepLeftover at event {i}");
                }
                if version != hdr.rounds {
                    bail!(
                        "replay: leftover sweep at version {version}, expected {}",
                        hdr.rounds
                    );
                }
                // replay mirrors the engine's in-flight arithmetic op for
                // op, so this one is bit-exact — any difference is a real
                // divergence
                if secs.to_bits() != in_flight_secs.to_bits() {
                    bail!(
                        "replay: leftover sweep {secs} != replayed in-flight \
                         {in_flight_secs} (event {i})"
                    );
                }
                wasted += secs;
                if let Some(last) = recs.last_mut() {
                    last.cum_waste_secs = wasted;
                    last.in_flight_secs = Some(0.0);
                }
                swept = true;
            }
            RunEvent::RunEnd => {
                if !swept {
                    bail!("replay: RunEnd at event {i} without a leftover sweep");
                }
                if recs.len() as u64 != hdr.rounds {
                    bail!(
                        "replay: log ended after {} versions, header promised {}",
                        recs.len(),
                        hdr.rounds
                    );
                }
                if !close(spent, aggregated + wasted) {
                    bail!(
                        "replay: accounting identity broken: spent {spent} != \
                         aggregated {aggregated} + wasted {wasted}"
                    );
                }
                ended = true;
            }
            other => bail!("replay: sync-only event {other:?} in an async log (event {i})"),
        }
    }
    if !ended {
        bail!("replay: log ends without RunEnd ({} events)", events.len());
    }
    Ok(recs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sync_header() -> RunEvent {
        RunEvent::RunStart {
            label: "sync".into(),
            perplexity: false,
            mode: 0,
            buffer_k: 0,
            max_staleness: None,
            rounds: 1,
            eval_every: 1,
            use_saa: true,
            staleness_threshold: Some(2),
        }
    }

    #[test]
    fn sync_round_rebuilds_records_and_accounting() {
        let log = vec![
            sync_header(),
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Eligibility { count: 5 },
            RunEvent::Selected { learner: 1 },
            RunEvent::Selected { learner: 2 },
            RunEvent::FreshSpend { learner: 1, duration: 10.0, corrupt: false },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 10.0, fresh: true },
            RunEvent::StragglerSpend { learner: 2, duration: 20.0, fate: FATE_TRAINED },
            RunEvent::Trained { learner: 2, mean_loss: 0.7, duration: 20.0, fresh: false },
            RunEvent::EvalDone { loss: 1.0, acc: 0.25 },
            RunEvent::RoundEnd { round_duration: 12.0 },
            RunEvent::SweepLeftover { secs: 20.0 },
            RunEvent::RunEnd,
        ];
        let result = replay(&log).unwrap();
        assert_eq!(result.label, "sync");
        assert_eq!(result.rounds.len(), 1);
        let r = &result.rounds[0];
        assert_eq!(r.selected, 2);
        assert_eq!(r.fresh_updates, 1);
        assert_eq!(r.stale_updates, 0);
        assert_eq!(r.sim_time, 12.0);
        assert_eq!(r.cum_resource_secs, 30.0);
        assert_eq!(r.cum_waste_secs, 20.0, "leftover sweep lands on the last round");
        assert_eq!(r.unique_participants, 2);
        assert_eq!(r.train_loss, Some(0.6));
        assert_eq!(r.test_accuracy, Some(0.25));
        assert!(!r.failed);
    }

    #[test]
    fn sync_stale_delivery_aggregates_within_threshold() {
        let log = vec![
            RunEvent::RunStart {
                label: "sync".into(),
                perplexity: false,
                mode: 1,
                buffer_k: 0,
                max_staleness: None,
                rounds: 2,
                eval_every: 5,
                use_saa: true,
                staleness_threshold: Some(2),
            },
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Selected { learner: 1 },
            RunEvent::StragglerSpend { learner: 1, duration: 8.0, fate: FATE_TRAINED },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 8.0, fresh: false },
            RunEvent::RoundEnd { round_duration: 4.0 },
            RunEvent::RoundStart { round: 1, now: 4.0 },
            RunEvent::Selected { learner: 2 },
            RunEvent::FreshSpend { learner: 2, duration: 3.0, corrupt: false },
            RunEvent::Trained { learner: 2, mean_loss: 0.4, duration: 3.0, fresh: true },
            RunEvent::StaleDelivery { learner: 1, origin_round: 0, duration: 8.0 },
            RunEvent::EvalDone { loss: 2.0, acc: 0.5 },
            RunEvent::RoundEnd { round_duration: 5.0 },
            RunEvent::SweepLeftover { secs: 0.0 },
            RunEvent::RunEnd,
        ];
        let result = replay(&log).unwrap();
        assert!(result.rounds[0].failed, "round 0 merged nothing fresh");
        let r1 = &result.rounds[1];
        assert_eq!(r1.stale_updates, 1);
        assert_eq!(r1.sim_time, 9.0);
        assert_eq!(r1.cum_resource_secs, 11.0);
        assert_eq!(r1.cum_waste_secs, 0.0);
    }

    #[test]
    fn async_merge_rebuilds_concurrency_and_buffers() {
        let log = vec![
            RunEvent::RunStart {
                label: "async".into(),
                perplexity: false,
                mode: 2,
                buffer_k: 1,
                max_staleness: None,
                rounds: 1,
                eval_every: 1,
                use_saa: false,
                staleness_threshold: None,
            },
            RunEvent::KernelPop { at: 0.0, class: 3 },
            RunEvent::AsyncSpawn { learner: 1, duration: 10.0, dropped_after: None },
            RunEvent::KernelPop { at: 10.0, class: 0 },
            RunEvent::AsyncDelivery {
                learner: 1,
                origin_version: 0,
                duration: 10.0,
                mean_loss: 0.5,
                corrupt: false,
            },
            RunEvent::MergeCommit { eval: Some((1.0, 0.25)) },
            RunEvent::SweepLeftover { secs: 0.0 },
            RunEvent::RunEnd,
        ];
        let result = replay(&log).unwrap();
        assert_eq!(result.rounds.len(), 1);
        let r = &result.rounds[0];
        assert_eq!(r.selected, 1);
        assert_eq!(r.fresh_updates, 1);
        assert_eq!(r.sim_time, 10.0);
        assert_eq!(r.mean_concurrency, Some(1.0));
        assert_eq!(r.kernel_events, Some(2));
        assert_eq!(r.cum_aggregated_secs, Some(10.0));
        assert_eq!(r.in_flight_secs, Some(0.0));
        assert_eq!(r.test_accuracy, Some(0.25));
    }

    #[test]
    fn rejects_logs_without_header_or_end() {
        assert!(replay(&[]).is_err());
        assert!(replay(&[RunEvent::RunEnd]).is_err());
        let unterminated = vec![sync_header(), RunEvent::RoundStart { round: 0, now: 0.0 }];
        assert!(replay(&unterminated).is_err());
    }

    #[test]
    fn rejects_delivery_with_nothing_in_flight() {
        let log = vec![
            RunEvent::RunStart {
                label: "async".into(),
                perplexity: false,
                mode: 2,
                buffer_k: 2,
                max_staleness: None,
                rounds: 1,
                eval_every: 1,
                use_saa: false,
                staleness_threshold: None,
            },
            RunEvent::AsyncDelivery {
                learner: 1,
                origin_version: 0,
                duration: 10.0,
                mean_loss: 0.5,
                corrupt: false,
            },
        ];
        let err = replay(&log).unwrap_err().to_string();
        assert!(err.contains("nothing in flight"), "{err}");
    }

    #[test]
    fn rejects_merge_without_full_buffer() {
        let log = vec![
            RunEvent::RunStart {
                label: "async".into(),
                perplexity: false,
                mode: 2,
                buffer_k: 3,
                max_staleness: None,
                rounds: 1,
                eval_every: 1,
                use_saa: false,
                staleness_threshold: None,
            },
            RunEvent::MergeCommit { eval: None },
        ];
        let err = replay(&log).unwrap_err().to_string();
        assert!(err.contains("without a full buffer"), "{err}");
    }
}
