//! Replay: re-derive a full `ExperimentResult` from a run log alone.
//!
//! This is deliberately a *second, independent* implementation of the
//! engines' bookkeeping — a pair of event reducers (one round-synchronous,
//! one buffered-async) that rebuild every round record, accounting total,
//! and fault counter from the logged event stream, sharing no code with
//! `coordinator/`. The fuzzer compares the replayed result byte-for-byte
//! against the engine's JSON, which turns every logged run into its own
//! oracle — including the async regime, which the frozen sync reference
//! cannot cross-check.
//!
//! The reducers are *incremental*: [`RunReducer`] consumes one event at a
//! time, so the same code drives both the batch [`replay`] oracle and the
//! live telemetry watcher (`telemetry/`) tailing a log mid-run. Whatever
//! the watcher's final snapshot derives is therefore the replay result by
//! construction, not by a parallel reimplementation.
//!
//! Replay is strict: an event arriving in a state the engines could never
//! produce (a delivery with nothing in flight, a merge without a full
//! buffer, an eval on a non-eval round) is an error, not a best-effort
//! guess — those are exactly the divergences the oracle exists to catch.
//! All f64 arithmetic mirrors the engines' operation order exactly, so the
//! derived JSON matches bit-for-bit, not just within epsilon.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::metrics::{ExperimentResult, RoundRecord};
use crate::scenario::faults::FaultKind;

use super::{RunEvent, FATE_CORRUPT, FATE_DOOMED, FATE_TRAINED};

/// Relative tolerance for the order-insensitive cross-checks (the sync
/// leftover sweep sums the heap in unspecified order, so only an
/// epsilon-level check is meaningful there; everything else is bit-exact).
const REL_EPS: f64 = 1e-6;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_EPS * a.abs().max(b.abs()).max(1.0)
}

/// Everything the reducers need from the `RunStart` header.
#[derive(Clone, Debug)]
pub struct Header {
    pub mode: u8,
    pub buffer_k: usize,
    pub max_staleness: Option<u64>,
    pub rounds: u64,
    pub eval_every: u64,
    pub use_saa: bool,
    pub staleness_threshold: Option<u64>,
}

/// Rebuild the full experiment result from a decoded event stream.
pub fn replay(events: &[RunEvent]) -> Result<ExperimentResult> {
    let mut reducer = RunReducer::new();
    for ev in events {
        reducer.step(ev)?;
    }
    reducer.result()
}

/// A point-in-time view of the reducer for live dashboards. Everything here
/// is derived from logged (simulated) quantities — no wall clock.
#[derive(Clone, Debug, Default)]
pub struct LiveStats {
    /// Completed round/version records so far.
    pub rounds_done: usize,
    /// Rounds the header promised.
    pub rounds_total: u64,
    /// Device-seconds spent / aggregated / wasted so far.
    pub spent: f64,
    pub aggregated: f64,
    pub wasted: f64,
    /// Device-seconds currently tied up in undelivered updates (sync: the
    /// outstanding stale heap; async: tasks in flight).
    pub in_flight_secs: f64,
    /// Undelivered update count (sync stale heap / async in-flight tasks).
    pub outstanding: usize,
    /// Async: updates buffered toward the next merge.
    pub buffer_fill: usize,
    pub unique_participants: usize,
    /// Latest simulated clock the reducer has witnessed.
    pub sim_time: f64,
    /// The open round (sync) or current version (async), if any.
    pub current_round: Option<u64>,
    /// `RunEnd` has been consumed.
    pub complete: bool,
}

enum State {
    /// Waiting for the `RunStart` header.
    Start,
    Sync { hdr: Header, st: SyncState },
    Async { hdr: Header, st: AsyncState },
}

/// Incremental event reducer: feed events one at a time with [`step`], pull
/// the finished result with [`result`] once `RunEnd` arrived. [`replay`] is
/// exactly `step` over the whole log — the telemetry watcher shares this
/// type, which is what makes its final snapshot provably replay-identical.
///
/// [`step`]: RunReducer::step
/// [`result`]: RunReducer::result
pub struct RunReducer {
    label: String,
    perplexity: bool,
    state: State,
    /// Events consumed so far (diagnostics only).
    seen: usize,
}

impl Default for RunReducer {
    fn default() -> Self {
        RunReducer::new()
    }
}

impl RunReducer {
    pub fn new() -> RunReducer {
        RunReducer {
            label: String::new(),
            perplexity: false,
            state: State::Start,
            seen: 0,
        }
    }

    /// Consume one event. The first error poisons nothing — the caller
    /// decides whether to stop — but reducer state after an error is
    /// unspecified, so live consumers should stop reducing.
    pub fn step(&mut self, ev: &RunEvent) -> Result<()> {
        let i = self.seen;
        self.seen += 1;
        match &mut self.state {
            State::Start => {
                let RunEvent::RunStart {
                    label,
                    perplexity,
                    mode,
                    buffer_k,
                    max_staleness,
                    rounds,
                    eval_every,
                    use_saa,
                    staleness_threshold,
                } = ev
                else {
                    if matches!(ev, RunEvent::JobSetStart { .. }) {
                        bail!(
                            "replay: this is a multi-job log (JobSetStart header) — \
                             use the multi-job reducer (jobs::replay_multijob)"
                        );
                    }
                    bail!("replay: log must open with RunStart, got {ev:?}");
                };
                if *eval_every == 0 {
                    bail!("replay: eval_every must be >= 1");
                }
                let hdr = Header {
                    mode: *mode,
                    buffer_k: *buffer_k as usize,
                    max_staleness: *max_staleness,
                    rounds: *rounds,
                    eval_every: *eval_every,
                    use_saa: *use_saa,
                    staleness_threshold: *staleness_threshold,
                };
                self.label = label.clone();
                self.perplexity = *perplexity;
                self.state = match mode {
                    0 | 1 => State::Sync { hdr, st: SyncState::default() },
                    2 => State::Async { hdr, st: AsyncState::default() },
                    m => bail!("replay: unknown mode code {m}"),
                };
                Ok(())
            }
            State::Sync { hdr, st } => st.step(hdr, ev, i),
            State::Async { hdr, st } => st.step(hdr, ev, i),
        }
    }

    /// Header fields, once `RunStart` has been consumed.
    pub fn header(&self) -> Option<&Header> {
        match &self.state {
            State::Start => None,
            State::Sync { hdr, .. } | State::Async { hdr, .. } => Some(hdr),
        }
    }

    /// Run label from the header (empty before `RunStart`).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// `RunEnd` has been consumed cleanly.
    pub fn ended(&self) -> bool {
        match &self.state {
            State::Start => false,
            State::Sync { st, .. } => st.ended,
            State::Async { st, .. } => st.ended,
        }
    }

    /// Completed round records so far (grows as the stream is consumed).
    pub fn records(&self) -> &[RoundRecord] {
        match &self.state {
            State::Start => &[],
            State::Sync { st, .. } => &st.recs,
            State::Async { st, .. } => &st.recs,
        }
    }

    /// Total device-seconds wasted so far (O(1); telemetry uses the delta
    /// across a step to attribute waste to its cause).
    pub fn wasted(&self) -> f64 {
        match &self.state {
            State::Start => 0.0,
            State::Sync { st, .. } => st.wasted,
            State::Async { st, .. } => st.wasted,
        }
    }

    /// The open round (sync) or current version (async).
    pub fn current_round(&self) -> Option<u64> {
        match &self.state {
            State::Start => None,
            State::Sync { st, .. } => st.cur.as_ref().map(|c| c.round),
            State::Async { st, .. } => Some(st.version),
        }
    }

    /// Point-in-time view for dashboards.
    pub fn live(&self) -> LiveStats {
        match &self.state {
            State::Start => LiveStats::default(),
            State::Sync { hdr, st } => LiveStats {
                rounds_done: st.recs.len(),
                rounds_total: hdr.rounds,
                spent: st.spent,
                aggregated: st.aggregated,
                wasted: st.wasted,
                in_flight_secs: st.outstanding_secs,
                outstanding: st.outstanding.len(),
                buffer_fill: 0,
                unique_participants: st.unique.len(),
                sim_time: st
                    .cur
                    .as_ref()
                    .map(|c| c.now)
                    .or_else(|| st.recs.last().map(|r| r.sim_time))
                    .unwrap_or(0.0),
                current_round: st.cur.as_ref().map(|c| c.round),
                complete: st.ended,
            },
            State::Async { hdr, st } => LiveStats {
                rounds_done: st.recs.len(),
                rounds_total: hdr.rounds,
                spent: st.spent,
                aggregated: st.aggregated,
                wasted: st.wasted,
                in_flight_secs: st.in_flight_secs,
                outstanding: st.in_flight,
                buffer_fill: st.buffer.len(),
                unique_participants: st.unique.len(),
                sim_time: st.conc_last_t,
                current_round: Some(st.version),
                complete: st.ended,
            },
        }
    }

    /// The finished result. Errors until `RunEnd` has been consumed.
    pub fn result(&self) -> Result<ExperimentResult> {
        match &self.state {
            State::Start => bail!("replay: empty run log"),
            State::Sync { st, .. } => {
                if !st.ended {
                    bail!("replay: log ends without RunEnd ({} events)", self.seen);
                }
                Ok(ExperimentResult {
                    label: self.label.clone(),
                    rounds: st.recs.clone(),
                    perplexity_metric: self.perplexity,
                })
            }
            State::Async { st, .. } => {
                if !st.ended {
                    bail!("replay: log ends without RunEnd ({} events)", self.seen);
                }
                Ok(ExperimentResult {
                    label: self.label.clone(),
                    rounds: st.recs.clone(),
                    perplexity_metric: self.perplexity,
                })
            }
        }
    }
}

// ----------------------------------------------------- sync (OC/DL) ------

/// In-progress round state for the synchronous reducer.
#[derive(Default)]
struct SyncRound {
    round: u64,
    now: f64,
    selected: usize,
    dropouts: usize,
    discarded: usize,
    faults: usize,
    fresh: usize,
    stale: usize,
    loss_sum: f64,
    loss_n: usize,
    eval: Option<(f64, f64)>,
}

fn open_round<'a>(cur: &'a mut Option<SyncRound>, i: usize) -> Result<&'a mut SyncRound> {
    cur.as_mut()
        .ok_or_else(|| anyhow!("replay: event {i} arrived outside any round"))
}

#[derive(Default)]
struct SyncState {
    recs: Vec<RoundRecord>,
    cur: Option<SyncRound>,
    spent: f64,
    wasted: f64,
    aggregated: f64,
    unique: HashSet<u64>,
    /// stale updates in flight: (learner, origin round) -> device-seconds
    outstanding: HashMap<(u64, u64), f64>,
    /// Running sum over `outstanding` for live dashboards only — never
    /// feeds a record (the engine's own leftover value does, bit-exactly).
    outstanding_secs: f64,
    swept: bool,
    ended: bool,
}

impl SyncState {
    fn step(&mut self, hdr: &Header, ev: &RunEvent, i: usize) -> Result<()> {
        if self.ended {
            bail!("replay: event {i} after RunEnd: {ev:?}");
        }
        match ev {
            RunEvent::RoundStart { round, now } => {
                if self.cur.is_some() {
                    bail!("replay: RoundStart at event {i} inside an open round");
                }
                if *round != self.recs.len() as u64 {
                    bail!(
                        "replay: RoundStart for round {round} at event {i}, expected {}",
                        self.recs.len()
                    );
                }
                self.cur = Some(SyncRound { round: *round, now: *now, ..Default::default() });
            }
            RunEvent::Eligibility { .. } => {}
            RunEvent::Selected { .. } => {
                open_round(&mut self.cur, i)?.selected += 1;
            }
            RunEvent::FaultDecision { kind, .. } => {
                let c = open_round(&mut self.cur, i)?;
                c.faults += 1;
                // a flap is the one fault the sync engine also counts as a
                // dropout (the task never starts, so no TaskDropout event
                // will follow)
                if FaultKind::from_code(*kind) == Some(FaultKind::Flap) {
                    c.dropouts += 1;
                }
            }
            RunEvent::TaskDropout { learner, spent: sp } => {
                let c = open_round(&mut self.cur, i)?;
                self.spent += sp;
                self.unique.insert(*learner);
                self.wasted += sp;
                c.dropouts += 1;
            }
            RunEvent::StragglerSpend { learner, duration, fate } => {
                let c = open_round(&mut self.cur, i)?;
                self.spent += duration;
                self.unique.insert(*learner);
                match *fate {
                    FATE_TRAINED => {}
                    FATE_CORRUPT | FATE_DOOMED => {
                        self.wasted += duration;
                        c.discarded += 1;
                    }
                    f => bail!("replay: unknown straggler fate {f} at event {i}"),
                }
            }
            RunEvent::FreshSpend { learner, duration, corrupt } => {
                let c = open_round(&mut self.cur, i)?;
                self.spent += duration;
                self.unique.insert(*learner);
                if *corrupt {
                    self.wasted += duration;
                    c.discarded += 1;
                }
            }
            RunEvent::Trained { learner, mean_loss, duration, fresh } => {
                let c = open_round(&mut self.cur, i)?;
                c.loss_sum += mean_loss;
                c.loss_n += 1;
                if *fresh {
                    self.aggregated += duration;
                    c.fresh += 1;
                } else {
                    let round = c.round;
                    if self.outstanding.insert((*learner, round), *duration).is_some() {
                        bail!(
                            "replay: learner {learner} already has an update in \
                             flight from round {round} (event {i})"
                        );
                    }
                    self.outstanding_secs += duration;
                }
            }
            RunEvent::StaleDelivery { learner, origin_round, duration } => {
                let c = open_round(&mut self.cur, i)?;
                let dur =
                    self.outstanding.remove(&(*learner, *origin_round)).ok_or_else(|| {
                        anyhow!(
                            "replay: stale delivery at event {i} for learner {learner} \
                             round {origin_round} with nothing in flight"
                        )
                    })?;
                self.outstanding_secs -= dur;
                if dur.to_bits() != duration.to_bits() {
                    bail!(
                        "replay: stale delivery duration {duration} disagrees with \
                         the spawned {dur} (event {i})"
                    );
                }
                if *origin_round > c.round {
                    bail!("replay: stale delivery from the future at event {i}");
                }
                let tau = c.round - origin_round;
                let within = hdr.staleness_threshold.map(|th| tau <= th).unwrap_or(true);
                if hdr.use_saa && within {
                    self.aggregated += duration;
                    c.stale += 1;
                } else {
                    self.wasted += duration;
                    c.discarded += 1;
                }
            }
            RunEvent::EvalDone { loss, acc } => {
                let c = open_round(&mut self.cur, i)?;
                if c.eval.is_some() {
                    bail!("replay: second EvalDone in round {} (event {i})", c.round);
                }
                c.eval = Some((*loss, *acc));
            }
            RunEvent::RoundEnd { round_duration } => {
                let c = self
                    .cur
                    .take()
                    .ok_or_else(|| anyhow!("replay: RoundEnd at event {i} with no round"))?;
                let expected_eval = c.selected > 0
                    && ((c.round + 1) % hdr.eval_every == 0 || c.round + 1 == hdr.rounds);
                if expected_eval != c.eval.is_some() {
                    bail!(
                        "replay: round {} eval mismatch (expected {expected_eval}, \
                         logged {})",
                        c.round,
                        c.eval.is_some()
                    );
                }
                self.recs.push(RoundRecord {
                    round: c.round as usize,
                    sim_time: c.now + round_duration,
                    round_duration: *round_duration,
                    selected: c.selected,
                    fresh_updates: c.fresh,
                    stale_updates: c.stale,
                    dropouts: c.dropouts,
                    discarded: c.discarded,
                    faults: c.faults,
                    cum_resource_secs: self.spent,
                    cum_waste_secs: self.wasted,
                    unique_participants: self.unique.len(),
                    failed: c.fresh == 0 && c.stale == 0,
                    train_loss: (c.loss_n > 0).then(|| c.loss_sum / c.loss_n as f64),
                    test_accuracy: c.eval.map(|(_, a)| a),
                    test_loss: c.eval.map(|(l, _)| l),
                    ..Default::default()
                });
            }
            RunEvent::SweepLeftover { secs } => {
                if self.cur.is_some() {
                    bail!("replay: SweepLeftover at event {i} inside an open round");
                }
                if self.swept {
                    bail!("replay: second SweepLeftover at event {i}");
                }
                // the engine sums its heap in unspecified order, so only an
                // epsilon cross-check is possible; the *logged* value is
                // what feeds the byte-exact waste total
                let pending: f64 = self.outstanding.values().sum();
                if !close(*secs, pending) {
                    bail!(
                        "replay: leftover sweep {secs} disagrees with the {pending} \
                         still outstanding (event {i})"
                    );
                }
                self.wasted += secs;
                if let Some(last) = self.recs.last_mut() {
                    last.cum_waste_secs = self.wasted;
                }
                self.outstanding.clear();
                self.outstanding_secs = 0.0;
                self.swept = true;
            }
            RunEvent::RunEnd => {
                if self.cur.is_some() {
                    bail!("replay: RunEnd at event {i} inside an open round");
                }
                if !self.swept {
                    bail!("replay: RunEnd at event {i} without a leftover sweep");
                }
                if self.recs.len() as u64 != hdr.rounds {
                    bail!(
                        "replay: log ended after {} rounds, header promised {}",
                        self.recs.len(),
                        hdr.rounds
                    );
                }
                if !close(self.spent, self.aggregated + self.wasted) {
                    bail!(
                        "replay: accounting identity broken: spent {} != \
                         aggregated {} + wasted {}",
                        self.spent,
                        self.aggregated,
                        self.wasted
                    );
                }
                self.ended = true;
            }
            other => bail!("replay: async-only event {other:?} in a sync log (event {i})"),
        }
        Ok(())
    }
}

// ------------------------------------------------- async (buffered) ------

#[derive(Default)]
struct AsyncState {
    recs: Vec<RoundRecord>,
    version: u64,
    in_flight: usize,
    in_flight_secs: f64,
    /// buffered unmerged updates: (origin version, device-seconds, mean loss)
    buffer: Vec<(u64, f64, f64)>,
    // per-merge-interval counters
    selected: usize,
    dropouts: usize,
    discarded: usize,
    faults: usize,
    events_n: usize,
    interval_start: f64,
    conc_area: f64,
    conc_last_t: f64,
    expect_merge: bool,
    // run-wide accounting
    spent: f64,
    wasted: f64,
    aggregated: f64,
    unique: HashSet<u64>,
    swept: bool,
    ended: bool,
}

impl AsyncState {
    fn step(&mut self, hdr: &Header, ev: &RunEvent, i: usize) -> Result<()> {
        if self.ended {
            bail!("replay: event {i} after RunEnd: {ev:?}");
        }
        if self.expect_merge && !matches!(ev, RunEvent::MergeCommit { .. }) {
            bail!("replay: buffer reached K but event {i} is {ev:?}, not a MergeCommit");
        }
        match ev {
            RunEvent::KernelPop { at, class: _ } => {
                self.events_n += 1;
                self.conc_area += self.in_flight as f64 * (at - self.conc_last_t);
                self.conc_last_t = *at;
            }
            RunEvent::Eligibility { .. } => {}
            RunEvent::FaultDecision { kind, .. } => {
                self.faults += 1;
                // the async engine counts a flapped learner as selected and
                // dropped at decision time (no task ever spawns for it)
                if FaultKind::from_code(*kind) == Some(FaultKind::Flap) {
                    self.selected += 1;
                    self.dropouts += 1;
                }
            }
            RunEvent::AsyncSpawn { learner, duration, dropped_after } => {
                let secs = dropped_after.unwrap_or(*duration);
                self.spent += secs;
                self.unique.insert(*learner);
                self.in_flight_secs += secs;
                self.in_flight += 1;
                self.selected += 1;
            }
            RunEvent::AsyncDropout { learner: _, spent: sp } => {
                self.in_flight = self.in_flight.checked_sub(1).ok_or_else(|| {
                    anyhow!("replay: dropout at event {i} with nothing in flight")
                })?;
                self.in_flight_secs -= sp;
                self.dropouts += 1;
                self.wasted += sp;
            }
            RunEvent::AsyncDelivery {
                learner: _,
                origin_version,
                duration,
                mean_loss,
                corrupt,
            } => {
                self.in_flight = self.in_flight.checked_sub(1).ok_or_else(|| {
                    anyhow!("replay: delivery at event {i} with nothing in flight")
                })?;
                if *corrupt {
                    self.wasted += duration;
                    self.in_flight_secs -= duration;
                    self.discarded += 1;
                } else {
                    if *origin_version > self.version {
                        bail!("replay: delivery from future version at event {i}");
                    }
                    let tau = self.version - origin_version;
                    let within = hdr.max_staleness.map(|m| tau <= m).unwrap_or(true);
                    if within {
                        self.buffer.push((*origin_version, *duration, *mean_loss));
                        if self.buffer.len() >= hdr.buffer_k {
                            self.expect_merge = true;
                        }
                    } else {
                        self.wasted += duration;
                        self.in_flight_secs -= duration;
                        self.discarded += 1;
                    }
                }
            }
            RunEvent::MergeCommit { eval } => {
                if !self.expect_merge {
                    bail!("replay: MergeCommit at event {i} without a full buffer");
                }
                self.expect_merge = false;
                let end = self.conc_last_t;
                let entries = std::mem::take(&mut self.buffer);
                // the engine re-checks staleness against the *current*
                // version at merge time (versions may have advanced since
                // an update was buffered... they cannot here, since merges
                // fire the moment the buffer fills, but the engine guards
                // it and so does replay)
                let mut kept: Vec<(u64, f64, f64)> = Vec::new();
                for (origin, duration, mean_loss) in entries {
                    let tau = self.version - origin;
                    let within = hdr.max_staleness.map(|m| tau <= m).unwrap_or(true);
                    if within {
                        kept.push((origin, duration, mean_loss));
                    } else {
                        self.wasted += duration;
                        self.in_flight_secs -= duration;
                        self.discarded += 1;
                    }
                }
                let fresh = kept.iter().filter(|(o, _, _)| *o == self.version).count();
                let stale = kept.len() - fresh;
                let failed = kept.is_empty();
                let train_loss = (!kept.is_empty())
                    .then(|| kept.iter().map(|(_, _, l)| *l).sum::<f64>() / kept.len() as f64);
                for (_, duration, _) in &kept {
                    self.aggregated += duration;
                    self.in_flight_secs -= duration;
                }
                let interval = end - self.interval_start;
                let mean_conc = if interval > 0.0 {
                    self.conc_area / interval
                } else {
                    self.in_flight as f64
                };
                let mut rec = RoundRecord {
                    round: self.version as usize,
                    sim_time: end,
                    round_duration: interval,
                    selected: self.selected,
                    fresh_updates: fresh,
                    stale_updates: stale,
                    dropouts: self.dropouts,
                    discarded: self.discarded,
                    faults: self.faults,
                    cum_resource_secs: self.spent,
                    cum_waste_secs: self.wasted,
                    unique_participants: self.unique.len(),
                    failed,
                    train_loss,
                    mean_concurrency: Some(mean_conc),
                    cum_aggregated_secs: Some(self.aggregated),
                    in_flight_secs: Some(self.in_flight_secs),
                    kernel_events: Some(self.events_n),
                    ..Default::default()
                };
                self.version += 1;
                let expected_eval =
                    self.version % hdr.eval_every == 0 || self.version == hdr.rounds;
                if expected_eval != eval.is_some() {
                    bail!(
                        "replay: version {} eval mismatch (expected \
                         {expected_eval}, logged {})",
                        self.version,
                        eval.is_some()
                    );
                }
                if let Some((loss, acc)) = eval {
                    rec.test_loss = Some(*loss);
                    rec.test_accuracy = Some(*acc);
                }
                self.recs.push(rec);
                self.selected = 0;
                self.dropouts = 0;
                self.discarded = 0;
                self.faults = 0;
                self.events_n = 0;
                self.interval_start = end;
                self.conc_area = 0.0;
                self.conc_last_t = end;
            }
            RunEvent::AsyncBurn { end } => {
                // a starved interval: nothing in flight, so the engine jumps
                // the clock without integrating concurrency area
                self.conc_last_t = *end;
                let interval = end - self.interval_start;
                let mean_conc = if interval > 0.0 {
                    self.conc_area / interval
                } else {
                    self.in_flight as f64
                };
                self.recs.push(RoundRecord {
                    round: self.version as usize,
                    sim_time: *end,
                    round_duration: interval,
                    selected: self.selected,
                    dropouts: self.dropouts,
                    discarded: self.discarded,
                    faults: self.faults,
                    cum_resource_secs: self.spent,
                    cum_waste_secs: self.wasted,
                    unique_participants: self.unique.len(),
                    failed: true,
                    mean_concurrency: Some(mean_conc),
                    cum_aggregated_secs: Some(self.aggregated),
                    in_flight_secs: Some(self.in_flight_secs),
                    kernel_events: Some(self.events_n),
                    ..Default::default()
                });
                self.version += 1;
                self.selected = 0;
                self.dropouts = 0;
                self.discarded = 0;
                self.faults = 0;
                self.events_n = 0;
                self.interval_start = *end;
                self.conc_area = 0.0;
            }
            RunEvent::SweepLeftover { secs } => {
                if self.swept {
                    bail!("replay: second SweepLeftover at event {i}");
                }
                if self.version != hdr.rounds {
                    bail!(
                        "replay: leftover sweep at version {}, expected {}",
                        self.version,
                        hdr.rounds
                    );
                }
                // replay mirrors the engine's in-flight arithmetic op for
                // op, so this one is bit-exact — any difference is a real
                // divergence
                if secs.to_bits() != self.in_flight_secs.to_bits() {
                    bail!(
                        "replay: leftover sweep {secs} != replayed in-flight \
                         {} (event {i})",
                        self.in_flight_secs
                    );
                }
                self.wasted += secs;
                if let Some(last) = self.recs.last_mut() {
                    last.cum_waste_secs = self.wasted;
                    last.in_flight_secs = Some(0.0);
                }
                self.swept = true;
            }
            RunEvent::RunEnd => {
                if !self.swept {
                    bail!("replay: RunEnd at event {i} without a leftover sweep");
                }
                if self.recs.len() as u64 != hdr.rounds {
                    bail!(
                        "replay: log ended after {} versions, header promised {}",
                        self.recs.len(),
                        hdr.rounds
                    );
                }
                if !close(self.spent, self.aggregated + self.wasted) {
                    bail!(
                        "replay: accounting identity broken: spent {} != \
                         aggregated {} + wasted {}",
                        self.spent,
                        self.aggregated,
                        self.wasted
                    );
                }
                self.ended = true;
            }
            other => bail!("replay: sync-only event {other:?} in an async log (event {i})"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sync_header() -> RunEvent {
        RunEvent::RunStart {
            label: "sync".into(),
            perplexity: false,
            mode: 0,
            buffer_k: 0,
            max_staleness: None,
            rounds: 1,
            eval_every: 1,
            use_saa: true,
            staleness_threshold: Some(2),
        }
    }

    #[test]
    fn sync_round_rebuilds_records_and_accounting() {
        let log = vec![
            sync_header(),
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Eligibility { count: 5 },
            RunEvent::Selected { learner: 1 },
            RunEvent::Selected { learner: 2 },
            RunEvent::FreshSpend { learner: 1, duration: 10.0, corrupt: false },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 10.0, fresh: true },
            RunEvent::StragglerSpend { learner: 2, duration: 20.0, fate: FATE_TRAINED },
            RunEvent::Trained { learner: 2, mean_loss: 0.7, duration: 20.0, fresh: false },
            RunEvent::EvalDone { loss: 1.0, acc: 0.25 },
            RunEvent::RoundEnd { round_duration: 12.0 },
            RunEvent::SweepLeftover { secs: 20.0 },
            RunEvent::RunEnd,
        ];
        let result = replay(&log).unwrap();
        assert_eq!(result.label, "sync");
        assert_eq!(result.rounds.len(), 1);
        let r = &result.rounds[0];
        assert_eq!(r.selected, 2);
        assert_eq!(r.fresh_updates, 1);
        assert_eq!(r.stale_updates, 0);
        assert_eq!(r.sim_time, 12.0);
        assert_eq!(r.cum_resource_secs, 30.0);
        assert_eq!(r.cum_waste_secs, 20.0, "leftover sweep lands on the last round");
        assert_eq!(r.unique_participants, 2);
        assert_eq!(r.train_loss, Some(0.6));
        assert_eq!(r.test_accuracy, Some(0.25));
        assert!(!r.failed);
    }

    #[test]
    fn sync_stale_delivery_aggregates_within_threshold() {
        let log = vec![
            RunEvent::RunStart {
                label: "sync".into(),
                perplexity: false,
                mode: 1,
                buffer_k: 0,
                max_staleness: None,
                rounds: 2,
                eval_every: 5,
                use_saa: true,
                staleness_threshold: Some(2),
            },
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Selected { learner: 1 },
            RunEvent::StragglerSpend { learner: 1, duration: 8.0, fate: FATE_TRAINED },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 8.0, fresh: false },
            RunEvent::RoundEnd { round_duration: 4.0 },
            RunEvent::RoundStart { round: 1, now: 4.0 },
            RunEvent::Selected { learner: 2 },
            RunEvent::FreshSpend { learner: 2, duration: 3.0, corrupt: false },
            RunEvent::Trained { learner: 2, mean_loss: 0.4, duration: 3.0, fresh: true },
            RunEvent::StaleDelivery { learner: 1, origin_round: 0, duration: 8.0 },
            RunEvent::EvalDone { loss: 2.0, acc: 0.5 },
            RunEvent::RoundEnd { round_duration: 5.0 },
            RunEvent::SweepLeftover { secs: 0.0 },
            RunEvent::RunEnd,
        ];
        let result = replay(&log).unwrap();
        assert!(result.rounds[0].failed, "round 0 merged nothing fresh");
        let r1 = &result.rounds[1];
        assert_eq!(r1.stale_updates, 1);
        assert_eq!(r1.sim_time, 9.0);
        assert_eq!(r1.cum_resource_secs, 11.0);
        assert_eq!(r1.cum_waste_secs, 0.0);
    }

    #[test]
    fn async_merge_rebuilds_concurrency_and_buffers() {
        let log = vec![
            RunEvent::RunStart {
                label: "async".into(),
                perplexity: false,
                mode: 2,
                buffer_k: 1,
                max_staleness: None,
                rounds: 1,
                eval_every: 1,
                use_saa: false,
                staleness_threshold: None,
            },
            RunEvent::KernelPop { at: 0.0, class: 3 },
            RunEvent::AsyncSpawn { learner: 1, duration: 10.0, dropped_after: None },
            RunEvent::KernelPop { at: 10.0, class: 0 },
            RunEvent::AsyncDelivery {
                learner: 1,
                origin_version: 0,
                duration: 10.0,
                mean_loss: 0.5,
                corrupt: false,
            },
            RunEvent::MergeCommit { eval: Some((1.0, 0.25)) },
            RunEvent::SweepLeftover { secs: 0.0 },
            RunEvent::RunEnd,
        ];
        let result = replay(&log).unwrap();
        assert_eq!(result.rounds.len(), 1);
        let r = &result.rounds[0];
        assert_eq!(r.selected, 1);
        assert_eq!(r.fresh_updates, 1);
        assert_eq!(r.sim_time, 10.0);
        assert_eq!(r.mean_concurrency, Some(1.0));
        assert_eq!(r.kernel_events, Some(2));
        assert_eq!(r.cum_aggregated_secs, Some(10.0));
        assert_eq!(r.in_flight_secs, Some(0.0));
        assert_eq!(r.test_accuracy, Some(0.25));
    }

    #[test]
    fn points_multijob_logs_at_the_multijob_reducer() {
        let log = vec![RunEvent::JobSetStart {
            label: "m".into(),
            jobs: 2,
            policy: "fair".into(),
            rounds: 1,
            eval_every: 1,
        }];
        let err = replay(&log).unwrap_err().to_string();
        assert!(err.contains("multi-job"), "{err}");
        assert!(err.contains("replay_multijob"), "{err}");
    }

    #[test]
    fn rejects_logs_without_header_or_end() {
        assert!(replay(&[]).is_err());
        assert!(replay(&[RunEvent::RunEnd]).is_err());
        let unterminated = vec![sync_header(), RunEvent::RoundStart { round: 0, now: 0.0 }];
        assert!(replay(&unterminated).is_err());
    }

    #[test]
    fn rejects_delivery_with_nothing_in_flight() {
        let log = vec![
            RunEvent::RunStart {
                label: "async".into(),
                perplexity: false,
                mode: 2,
                buffer_k: 2,
                max_staleness: None,
                rounds: 1,
                eval_every: 1,
                use_saa: false,
                staleness_threshold: None,
            },
            RunEvent::AsyncDelivery {
                learner: 1,
                origin_version: 0,
                duration: 10.0,
                mean_loss: 0.5,
                corrupt: false,
            },
        ];
        let err = replay(&log).unwrap_err().to_string();
        assert!(err.contains("nothing in flight"), "{err}");
    }

    #[test]
    fn rejects_merge_without_full_buffer() {
        let log = vec![
            RunEvent::RunStart {
                label: "async".into(),
                perplexity: false,
                mode: 2,
                buffer_k: 3,
                max_staleness: None,
                rounds: 1,
                eval_every: 1,
                use_saa: false,
                staleness_threshold: None,
            },
            RunEvent::MergeCommit { eval: None },
        ];
        let err = replay(&log).unwrap_err().to_string();
        assert!(err.contains("without a full buffer"), "{err}");
    }

    #[test]
    fn incremental_reducer_exposes_live_state_mid_stream() {
        let log = vec![
            sync_header(),
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Selected { learner: 1 },
            RunEvent::FreshSpend { learner: 1, duration: 10.0, corrupt: false },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 10.0, fresh: true },
        ];
        let mut red = RunReducer::new();
        for ev in &log {
            red.step(ev).unwrap();
        }
        assert!(!red.ended());
        assert!(red.result().is_err(), "result before RunEnd must error");
        let live = red.live();
        assert_eq!(live.current_round, Some(0));
        assert_eq!(live.spent, 10.0);
        assert_eq!(live.aggregated, 10.0);
        assert_eq!(live.unique_participants, 1);
        assert_eq!(live.rounds_total, 1);
        assert!(!live.complete);
    }

    #[test]
    fn sync_outstanding_secs_tracks_the_stale_heap() {
        let mut red = RunReducer::new();
        for ev in [
            RunEvent::RunStart {
                label: "s".into(),
                perplexity: false,
                mode: 1,
                buffer_k: 0,
                max_staleness: None,
                rounds: 2,
                eval_every: 5,
                use_saa: true,
                staleness_threshold: Some(2),
            },
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Selected { learner: 1 },
            RunEvent::StragglerSpend { learner: 1, duration: 8.0, fate: FATE_TRAINED },
            RunEvent::Trained { learner: 1, mean_loss: 0.5, duration: 8.0, fresh: false },
        ] {
            red.step(&ev).unwrap();
        }
        let live = red.live();
        assert_eq!(live.outstanding, 1);
        assert_eq!(live.in_flight_secs, 8.0);
    }
}
