//! Event-sourced run log: a compact, crash-safe append log of every kernel
//! event the engines process (check-ins, completions, stale deliveries,
//! merges, fault decisions, eligibility counts), plus the [`replay`] engine
//! that re-derives a full `ExperimentResult` from the log alone.
//!
//! Design constraints, in order:
//!
//! * **zero-cost when disabled** — every engine emit site goes through
//!   [`RunLogger::emit`] with a closure, so a disabled logger never
//!   constructs an event and the golden/equivalence suites stay
//!   byte-identical with logging off;
//! * **crash-safe** — frames are individually length-prefixed and CRC'd,
//!   and segments rotate every [`SEGMENT_EVENTS`] events, so a torn tail
//!   loses at most the last partial frame and decoding always returns a
//!   clean prefix (never panics on garbage);
//! * **bit-exact** — `f64` payloads travel as raw IEEE bits, so a replay
//!   re-derives byte-identical JSON, not merely approximately-equal totals.
//!
//! Wire format: each segment is `MAGIC` (8 bytes) followed by frames of
//! `varint(payload_len) ++ payload ++ crc32_le(payload)`. Payloads are a
//! one-byte event tag followed by LEB128 varints (`u64`), raw-bit `f64`s,
//! single-byte bools, and presence-byte-prefixed options.

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod replay;
pub mod tail;

use std::fs;
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

pub use replay::replay;
pub use tail::{DirTailer, TailStats};

/// Segment header magic (format version 1).
pub const MAGIC: &[u8; 8] = b"RLOG0001";

/// Events per segment before the logger rotates to a fresh one.
pub const SEGMENT_EVENTS: u64 = 8192;

// Straggler fates (`RunEvent::StragglerSpend::fate`).
/// The straggler's update was scheduled for stale delivery.
pub const FATE_TRAINED: u8 = 0;
/// The straggler's update was corrupted and discarded on the spot.
pub const FATE_CORRUPT: u8 = 1;
/// SAA pre-screening judged the update too stale to ever aggregate.
pub const FATE_DOOMED: u8 = 2;

/// One logged engine event. Variants mirror the engines' accounting call
/// sites one-to-one — the replay reducers in [`replay`] re-derive the full
/// per-round records from these alone, so every field that feeds a
/// `RoundRecord` travels in the event that witnesses it.
#[derive(Clone, Debug, PartialEq)]
pub enum RunEvent {
    /// Run header: everything replay needs from the config.
    /// `mode` is 0 = over-commit, 1 = deadline, 2 = async (buffered).
    RunStart {
        label: String,
        perplexity: bool,
        mode: u8,
        buffer_k: u64,
        max_staleness: Option<u64>,
        rounds: u64,
        eval_every: u64,
        use_saa: bool,
        staleness_threshold: Option<u64>,
    },
    /// Sync: a round opens at virtual time `now`.
    RoundStart { round: u64, now: f64 },
    /// Eligible-population size after the availability sync (audit only;
    /// replay ignores it).
    Eligibility { count: u64 },
    /// Sync: one learner entered the selected set.
    Selected { learner: u64 },
    /// A fault decision fired (`kind` is a `FaultKind` code).
    FaultDecision { kind: u8, learner: u64, round: u64 },
    /// Sync: a selected learner dropped mid-task after `spent` seconds.
    TaskDropout { learner: u64, spent: f64 },
    /// Sync: a straggler's device time was spent; `fate` is `FATE_*`.
    StragglerSpend { learner: u64, duration: f64, fate: u8 },
    /// Sync: an in-window participant's device time was spent.
    FreshSpend { learner: u64, duration: f64, corrupt: bool },
    /// Sync: a local training outcome was routed (fresh aggregate or
    /// scheduled stale delivery).
    Trained { learner: u64, mean_loss: f64, duration: f64, fresh: bool },
    /// Sync: a stale update from `origin_round` was popped this round.
    StaleDelivery { learner: u64, origin_round: u64, duration: f64 },
    /// Sync: the round evaluated the global model.
    EvalDone { loss: f64, acc: f64 },
    /// Sync: the round closed (both the normal and the aborted path).
    RoundEnd { round_duration: f64 },
    /// Async: the kernel popped an event at time `at`
    /// (`class` is an `EventClass` code).
    KernelPop { at: f64, class: u8 },
    /// Async: a task was spawned; `dropped_after` is the crash point when
    /// the learner will die mid-task instead of delivering.
    AsyncSpawn { learner: u64, duration: f64, dropped_after: Option<f64> },
    /// Async: a mid-task departure arrived at the server.
    AsyncDropout { learner: u64, spent: f64 },
    /// Async: a task completion arrived at the server.
    AsyncDelivery {
        learner: u64,
        origin_version: u64,
        duration: f64,
        mean_loss: f64,
        corrupt: bool,
    },
    /// Async: the buffer reached K and committed a merge; `eval` carries
    /// the (loss, accuracy) pair when the new version evaluated.
    MergeCommit { eval: Option<(f64, f64)> },
    /// Async: a starved interval burned to `end` as a failed version.
    AsyncBurn { end: f64 },
    /// Work still outstanding at run end, swept to waste (the engine's
    /// computed value, logged so replay reproduces it bit-exactly).
    SweepLeftover { secs: f64 },
    /// The run finished cleanly.
    RunEnd,
    /// Multi-job: the job-set header (must be the stream's first event —
    /// `relay replay`/`watch` route on it). `policy` is the arbitration
    /// policy name; `rounds`/`eval_every` apply to every job.
    JobSetStart { label: String, jobs: u64, policy: String, rounds: u64, eval_every: u64 },
    /// Multi-job: one job's static spec (`mode` is the compact spec label,
    /// e.g. "oc1.3"; one per job, in job-id order, right after the header).
    JobStart { job: u64, selector: String, mode: String, target: u64, priority: u64 },
    /// Multi-job: job `job` opened round `round` at virtual time `now`.
    JobRoundStart { job: u64, round: u64, now: f64 },
    /// Multi-job: a device was claimed for `job`; `dropped_after` is the
    /// crash point when the device will die mid-task instead of delivering.
    JobSpawn {
        job: u64,
        learner: u64,
        now: f64,
        duration: f64,
        dropped_after: Option<f64>,
        corrupt: bool,
    },
    /// Multi-job: a task completion arrived at the server; `fate` is
    /// `FATE_TRAINED` (aggregated), `FATE_CORRUPT` (discarded), or
    /// `FATE_DOOMED` (arrived after its cohort closed — wasted).
    JobDelivery { job: u64, learner: u64, duration: f64, mean_loss: f64, fate: u8 },
    /// Multi-job: job `job` closed round `round`. Carries the engine's
    /// computed per-round aggregates so replay reproduces them bit-exactly.
    JobRoundEnd {
        job: u64,
        round: u64,
        now: f64,
        round_duration: f64,
        fresh: u64,
        failed: bool,
        train_loss: Option<f64>,
        eval_loss: Option<f64>,
        eval_acc: Option<f64>,
    },
    /// Multi-job: job `job`'s in-flight seconds swept to waste at run end.
    JobSweep { job: u64, secs: f64 },
    /// Multi-job: the job set finished cleanly.
    JobSetEnd,
}

// ---------------------------------------------------------------- codec --

fn put_u64v(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(b);
            break;
        }
        buf.push(b | 0x80);
    }
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_bool(buf: &mut Vec<u8>, v: bool) {
    buf.push(u8::from(v));
}

fn put_opt_u64(buf: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(x) => {
            buf.push(1);
            put_u64v(buf, x);
        }
        None => buf.push(0),
    }
}

fn put_opt_f64(buf: &mut Vec<u8>, v: Option<f64>) {
    match v {
        Some(x) => {
            buf.push(1);
            put_f64(buf, x);
        }
        None => buf.push(0),
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64v(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

/// Bounds-checked little reader over one frame payload.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn pos(&self) -> usize {
        self.pos
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn u8(&mut self) -> Result<u8> {
        let b = *self
            .buf
            .get(self.pos)
            .ok_or_else(|| anyhow!("truncated payload at byte {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn u64v(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift >= 64 || (shift == 63 && b > 1) {
                bail!("varint overflows u64");
            }
            v |= u64::from(b & 0x7F) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn f64(&mut self) -> Result<f64> {
        let end = self.pos + 8;
        let bytes = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| anyhow!("truncated f64 at byte {}", self.pos))?;
        let mut raw = [0u8; 8];
        raw.copy_from_slice(bytes);
        self.pos = end;
        Ok(f64::from_bits(u64::from_le_bytes(raw)))
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b}"),
        }
    }

    fn opt_u64(&mut self) -> Result<Option<u64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64v()?)),
            b => bail!("invalid option byte {b}"),
        }
    }

    fn opt_f64(&mut self) -> Result<Option<f64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            b => bail!("invalid option byte {b}"),
        }
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u64v()? as usize;
        let end = self.pos + len;
        let bytes = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| anyhow!("truncated string at byte {}", self.pos))?;
        let s = std::str::from_utf8(bytes)
            .map_err(|e| anyhow!("invalid utf-8 in string: {e}"))?
            .to_string();
        self.pos = end;
        Ok(s)
    }
}

/// Serialize one event into `buf` (tag byte + payload, no framing).
pub fn encode_event(ev: &RunEvent, buf: &mut Vec<u8>) {
    match ev {
        RunEvent::RunStart {
            label,
            perplexity,
            mode,
            buffer_k,
            max_staleness,
            rounds,
            eval_every,
            use_saa,
            staleness_threshold,
        } => {
            buf.push(0);
            put_str(buf, label);
            put_bool(buf, *perplexity);
            buf.push(*mode);
            put_u64v(buf, *buffer_k);
            put_opt_u64(buf, *max_staleness);
            put_u64v(buf, *rounds);
            put_u64v(buf, *eval_every);
            put_bool(buf, *use_saa);
            put_opt_u64(buf, *staleness_threshold);
        }
        RunEvent::RoundStart { round, now } => {
            buf.push(1);
            put_u64v(buf, *round);
            put_f64(buf, *now);
        }
        RunEvent::Eligibility { count } => {
            buf.push(2);
            put_u64v(buf, *count);
        }
        RunEvent::Selected { learner } => {
            buf.push(3);
            put_u64v(buf, *learner);
        }
        RunEvent::FaultDecision { kind, learner, round } => {
            buf.push(4);
            buf.push(*kind);
            put_u64v(buf, *learner);
            put_u64v(buf, *round);
        }
        RunEvent::TaskDropout { learner, spent } => {
            buf.push(5);
            put_u64v(buf, *learner);
            put_f64(buf, *spent);
        }
        RunEvent::StragglerSpend { learner, duration, fate } => {
            buf.push(6);
            put_u64v(buf, *learner);
            put_f64(buf, *duration);
            buf.push(*fate);
        }
        RunEvent::FreshSpend { learner, duration, corrupt } => {
            buf.push(7);
            put_u64v(buf, *learner);
            put_f64(buf, *duration);
            put_bool(buf, *corrupt);
        }
        RunEvent::Trained { learner, mean_loss, duration, fresh } => {
            buf.push(8);
            put_u64v(buf, *learner);
            put_f64(buf, *mean_loss);
            put_f64(buf, *duration);
            put_bool(buf, *fresh);
        }
        RunEvent::StaleDelivery { learner, origin_round, duration } => {
            buf.push(9);
            put_u64v(buf, *learner);
            put_u64v(buf, *origin_round);
            put_f64(buf, *duration);
        }
        RunEvent::EvalDone { loss, acc } => {
            buf.push(10);
            put_f64(buf, *loss);
            put_f64(buf, *acc);
        }
        RunEvent::RoundEnd { round_duration } => {
            buf.push(11);
            put_f64(buf, *round_duration);
        }
        RunEvent::KernelPop { at, class } => {
            buf.push(12);
            put_f64(buf, *at);
            buf.push(*class);
        }
        RunEvent::AsyncSpawn { learner, duration, dropped_after } => {
            buf.push(13);
            put_u64v(buf, *learner);
            put_f64(buf, *duration);
            put_opt_f64(buf, *dropped_after);
        }
        RunEvent::AsyncDropout { learner, spent } => {
            buf.push(14);
            put_u64v(buf, *learner);
            put_f64(buf, *spent);
        }
        RunEvent::AsyncDelivery {
            learner,
            origin_version,
            duration,
            mean_loss,
            corrupt,
        } => {
            buf.push(15);
            put_u64v(buf, *learner);
            put_u64v(buf, *origin_version);
            put_f64(buf, *duration);
            put_f64(buf, *mean_loss);
            put_bool(buf, *corrupt);
        }
        RunEvent::MergeCommit { eval } => {
            buf.push(16);
            match eval {
                Some((loss, acc)) => {
                    buf.push(1);
                    put_f64(buf, *loss);
                    put_f64(buf, *acc);
                }
                None => buf.push(0),
            }
        }
        RunEvent::AsyncBurn { end } => {
            buf.push(17);
            put_f64(buf, *end);
        }
        RunEvent::SweepLeftover { secs } => {
            buf.push(18);
            put_f64(buf, *secs);
        }
        RunEvent::RunEnd => buf.push(19),
        RunEvent::JobSetStart { label, jobs, policy, rounds, eval_every } => {
            buf.push(20);
            put_str(buf, label);
            put_u64v(buf, *jobs);
            put_str(buf, policy);
            put_u64v(buf, *rounds);
            put_u64v(buf, *eval_every);
        }
        RunEvent::JobStart { job, selector, mode, target, priority } => {
            buf.push(21);
            put_u64v(buf, *job);
            put_str(buf, selector);
            put_str(buf, mode);
            put_u64v(buf, *target);
            put_u64v(buf, *priority);
        }
        RunEvent::JobRoundStart { job, round, now } => {
            buf.push(22);
            put_u64v(buf, *job);
            put_u64v(buf, *round);
            put_f64(buf, *now);
        }
        RunEvent::JobSpawn { job, learner, now, duration, dropped_after, corrupt } => {
            buf.push(23);
            put_u64v(buf, *job);
            put_u64v(buf, *learner);
            put_f64(buf, *now);
            put_f64(buf, *duration);
            put_opt_f64(buf, *dropped_after);
            put_bool(buf, *corrupt);
        }
        RunEvent::JobDelivery { job, learner, duration, mean_loss, fate } => {
            buf.push(24);
            put_u64v(buf, *job);
            put_u64v(buf, *learner);
            put_f64(buf, *duration);
            put_f64(buf, *mean_loss);
            buf.push(*fate);
        }
        RunEvent::JobRoundEnd {
            job,
            round,
            now,
            round_duration,
            fresh,
            failed,
            train_loss,
            eval_loss,
            eval_acc,
        } => {
            buf.push(25);
            put_u64v(buf, *job);
            put_u64v(buf, *round);
            put_f64(buf, *now);
            put_f64(buf, *round_duration);
            put_u64v(buf, *fresh);
            put_bool(buf, *failed);
            put_opt_f64(buf, *train_loss);
            put_opt_f64(buf, *eval_loss);
            put_opt_f64(buf, *eval_acc);
        }
        RunEvent::JobSweep { job, secs } => {
            buf.push(26);
            put_u64v(buf, *job);
            put_f64(buf, *secs);
        }
        RunEvent::JobSetEnd => buf.push(27),
    }
}

/// Deserialize one event from a frame payload; the payload must be
/// consumed exactly (trailing bytes are a format error).
pub fn decode_event(payload: &[u8]) -> Result<RunEvent> {
    let mut r = Reader::new(payload);
    let tag = r.u8()?;
    let ev = match tag {
        0 => RunEvent::RunStart {
            label: r.string()?,
            perplexity: r.bool()?,
            mode: r.u8()?,
            buffer_k: r.u64v()?,
            max_staleness: r.opt_u64()?,
            rounds: r.u64v()?,
            eval_every: r.u64v()?,
            use_saa: r.bool()?,
            staleness_threshold: r.opt_u64()?,
        },
        1 => RunEvent::RoundStart { round: r.u64v()?, now: r.f64()? },
        2 => RunEvent::Eligibility { count: r.u64v()? },
        3 => RunEvent::Selected { learner: r.u64v()? },
        4 => RunEvent::FaultDecision {
            kind: r.u8()?,
            learner: r.u64v()?,
            round: r.u64v()?,
        },
        5 => RunEvent::TaskDropout { learner: r.u64v()?, spent: r.f64()? },
        6 => RunEvent::StragglerSpend {
            learner: r.u64v()?,
            duration: r.f64()?,
            fate: r.u8()?,
        },
        7 => RunEvent::FreshSpend {
            learner: r.u64v()?,
            duration: r.f64()?,
            corrupt: r.bool()?,
        },
        8 => RunEvent::Trained {
            learner: r.u64v()?,
            mean_loss: r.f64()?,
            duration: r.f64()?,
            fresh: r.bool()?,
        },
        9 => RunEvent::StaleDelivery {
            learner: r.u64v()?,
            origin_round: r.u64v()?,
            duration: r.f64()?,
        },
        10 => RunEvent::EvalDone { loss: r.f64()?, acc: r.f64()? },
        11 => RunEvent::RoundEnd { round_duration: r.f64()? },
        12 => RunEvent::KernelPop { at: r.f64()?, class: r.u8()? },
        13 => RunEvent::AsyncSpawn {
            learner: r.u64v()?,
            duration: r.f64()?,
            dropped_after: r.opt_f64()?,
        },
        14 => RunEvent::AsyncDropout { learner: r.u64v()?, spent: r.f64()? },
        15 => RunEvent::AsyncDelivery {
            learner: r.u64v()?,
            origin_version: r.u64v()?,
            duration: r.f64()?,
            mean_loss: r.f64()?,
            corrupt: r.bool()?,
        },
        16 => RunEvent::MergeCommit {
            eval: match r.u8()? {
                0 => None,
                1 => Some((r.f64()?, r.f64()?)),
                b => bail!("invalid option byte {b}"),
            },
        },
        17 => RunEvent::AsyncBurn { end: r.f64()? },
        18 => RunEvent::SweepLeftover { secs: r.f64()? },
        19 => RunEvent::RunEnd,
        20 => RunEvent::JobSetStart {
            label: r.string()?,
            jobs: r.u64v()?,
            policy: r.string()?,
            rounds: r.u64v()?,
            eval_every: r.u64v()?,
        },
        21 => RunEvent::JobStart {
            job: r.u64v()?,
            selector: r.string()?,
            mode: r.string()?,
            target: r.u64v()?,
            priority: r.u64v()?,
        },
        22 => RunEvent::JobRoundStart { job: r.u64v()?, round: r.u64v()?, now: r.f64()? },
        23 => RunEvent::JobSpawn {
            job: r.u64v()?,
            learner: r.u64v()?,
            now: r.f64()?,
            duration: r.f64()?,
            dropped_after: r.opt_f64()?,
            corrupt: r.bool()?,
        },
        24 => RunEvent::JobDelivery {
            job: r.u64v()?,
            learner: r.u64v()?,
            duration: r.f64()?,
            mean_loss: r.f64()?,
            fate: r.u8()?,
        },
        25 => RunEvent::JobRoundEnd {
            job: r.u64v()?,
            round: r.u64v()?,
            now: r.f64()?,
            round_duration: r.f64()?,
            fresh: r.u64v()?,
            failed: r.bool()?,
            train_loss: r.opt_f64()?,
            eval_loss: r.opt_f64()?,
            eval_acc: r.opt_f64()?,
        },
        26 => RunEvent::JobSweep { job: r.u64v()?, secs: r.f64()? },
        27 => RunEvent::JobSetEnd,
        t => bail!("unknown event tag {t}"),
    };
    if !r.done() {
        bail!("{} trailing bytes after event tag {tag}", payload.len() - r.pos());
    }
    Ok(ev)
}

/// Bitwise CRC-32 (IEEE 802.3 polynomial, reflected). Slow but dependency-
/// free; log framing is nowhere near the simulator's hot path.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= u32::from(b);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Frame one event: `varint(len) ++ payload ++ crc32_le(payload)`.
pub fn encode_frame(ev: &RunEvent) -> Vec<u8> {
    let mut payload = Vec::with_capacity(32);
    encode_event(ev, &mut payload);
    let mut frame = Vec::with_capacity(payload.len() + 8);
    put_u64v(&mut frame, payload.len() as u64);
    frame.extend_from_slice(&payload);
    frame.extend_from_slice(&crc32(&payload).to_le_bytes());
    frame
}

/// What [`decode_segments`] found. `clean == false` means decoding stopped
/// early (truncation, CRC mismatch, parse error) and the returned events
/// are the clean prefix; `note` says where and why.
#[derive(Clone, Debug)]
pub struct DecodeStats {
    /// Segments whose magic checked out.
    pub segments: usize,
    /// Frames decoded successfully.
    pub frames: usize,
    pub clean: bool,
    pub note: Option<String>,
}

/// Decode an ordered list of segment byte-buffers into events. Never
/// panics: any corruption stops decoding and returns the clean prefix with
/// a diagnostic in [`DecodeStats::note`].
pub fn decode_segments(segments: &[Vec<u8>]) -> (Vec<RunEvent>, DecodeStats) {
    let mut events = Vec::new();
    let mut stats = DecodeStats { segments: 0, frames: 0, clean: true, note: None };
    'segments: for (si, seg) in segments.iter().enumerate() {
        if seg.len() < MAGIC.len() || &seg[..MAGIC.len()] != MAGIC {
            stats.clean = false;
            stats.note = Some(format!("segment {si}: bad or missing magic"));
            break;
        }
        stats.segments += 1;
        let mut pos = MAGIC.len();
        while pos < seg.len() {
            let mut r = Reader::new(&seg[pos..]);
            let len = match r.u64v() {
                Ok(l) => l as usize,
                Err(_) => {
                    stats.clean = false;
                    stats.note =
                        Some(format!("segment {si}: truncated frame header at {pos}"));
                    break 'segments;
                }
            };
            let header = r.pos();
            let Some(end) = pos
                .checked_add(header)
                .and_then(|p| p.checked_add(len))
                .and_then(|p| p.checked_add(4))
            else {
                stats.clean = false;
                stats.note = Some(format!("segment {si}: frame length overflow at {pos}"));
                break 'segments;
            };
            if end > seg.len() {
                stats.clean = false;
                stats.note = Some(format!("segment {si}: truncated frame at {pos}"));
                break 'segments;
            }
            let payload = &seg[pos + header..end - 4];
            let crc = &seg[end - 4..end];
            let stored = u32::from_le_bytes([crc[0], crc[1], crc[2], crc[3]]);
            if crc32(payload) != stored {
                stats.clean = false;
                stats.note = Some(format!("segment {si}: CRC mismatch at {pos}"));
                break 'segments;
            }
            match decode_event(payload) {
                Ok(ev) => {
                    events.push(ev);
                    stats.frames += 1;
                }
                Err(e) => {
                    stats.clean = false;
                    stats.note = Some(format!("segment {si}: bad frame at {pos}: {e}"));
                    break 'segments;
                }
            }
            pos = end;
        }
    }
    (events, stats)
}

// ---------------------------------------------------------------- sinks --

/// Where encoded frames go. `Send` so a boxed sink doesn't strip the
/// coordinator of its auto-traits.
pub trait LogSink: Send {
    /// Append one encoded frame to the current segment.
    fn write(&mut self, frame: &[u8]) -> io::Result<()>;
    /// Close the current segment and open the next.
    fn rotate(&mut self) -> io::Result<()>;
    /// Flush and close everything.
    fn finish(&mut self) -> io::Result<()>;
}

/// On-disk sink: one `seg-NNNNN.rlog` file per segment under a directory.
pub struct DirSink {
    dir: PathBuf,
    idx: usize,
    writer: Option<BufWriter<fs::File>>,
}

impl DirSink {
    pub fn create(dir: impl Into<PathBuf>) -> io::Result<DirSink> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let mut sink = DirSink { dir, idx: 0, writer: None };
        sink.open_segment()?;
        Ok(sink)
    }

    fn open_segment(&mut self) -> io::Result<()> {
        let path = self.dir.join(format!("seg-{:05}.rlog", self.idx));
        let mut w = BufWriter::new(fs::File::create(path)?);
        w.write_all(MAGIC)?;
        self.writer = Some(w);
        Ok(())
    }
}

impl LogSink for DirSink {
    fn write(&mut self, frame: &[u8]) -> io::Result<()> {
        match self.writer.as_mut() {
            Some(w) => w.write_all(frame),
            None => Err(io::Error::other("run log sink already finished")),
        }
    }

    fn rotate(&mut self) -> io::Result<()> {
        if let Some(mut w) = self.writer.take() {
            w.flush()?;
        }
        self.idx += 1;
        self.open_segment()
    }

    fn finish(&mut self) -> io::Result<()> {
        if let Some(mut w) = self.writer.take() {
            w.flush()?;
        }
        Ok(())
    }
}

/// In-memory sink for tests and the fuzzer's replay oracle. Cloning shares
/// the underlying segments, so a caller can keep a handle while the boxed
/// sink lives inside the coordinator.
#[derive(Clone)]
pub struct MemSink {
    segments: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl MemSink {
    pub fn new() -> MemSink {
        MemSink { segments: Arc::new(Mutex::new(vec![MAGIC.to_vec()])) }
    }

    /// Snapshot of the segments written so far.
    pub fn segments(&self) -> Vec<Vec<u8>> {
        self.segments
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .clone()
    }
}

impl Default for MemSink {
    fn default() -> Self {
        MemSink::new()
    }
}

impl LogSink for MemSink {
    fn write(&mut self, frame: &[u8]) -> io::Result<()> {
        let mut segs = self
            .segments
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        match segs.last_mut() {
            Some(seg) => {
                seg.extend_from_slice(frame);
                Ok(())
            }
            None => Err(io::Error::other("memory sink has no open segment")),
        }
    }

    fn rotate(&mut self) -> io::Result<()> {
        self.segments
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .push(MAGIC.to_vec());
        Ok(())
    }

    fn finish(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Read a [`DirSink`] directory back as ordered segment buffers.
pub fn read_dir_segments(dir: &Path) -> Result<Vec<Vec<u8>>> {
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(dir)
        .map_err(|e| anyhow!("cannot read run log dir {}: {e}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("seg-") && name.ends_with(".rlog") {
            paths.push(entry.path());
        }
    }
    if paths.is_empty() {
        bail!("no seg-*.rlog segments under {}", dir.display());
    }
    paths.sort();
    paths
        .iter()
        .map(|p| fs::read(p).map_err(|e| anyhow!("cannot read {}: {e}", p.display())))
        .collect()
}

// --------------------------------------------------------------- logger --

/// An in-process consumer of the live event stream (the telemetry layer's
/// hook). Observers see each event by reference after it is durably handed
/// to the sink; they cannot fail and cannot perturb the run — the same
/// zero-cost-when-absent discipline as the sink itself.
pub trait EventObserver: Send {
    fn observe(&mut self, ev: &RunEvent);
}

/// The hook the engines call. Disabled by default: `emit` takes a closure
/// so a disabled logger never even constructs the event — an event is built
/// only when a sink or an observer is attached. The first sink error
/// poisons the logger (subsequent emits are dropped) and surfaces from
/// [`RunLogger::finish`], keeping the engine's hot path infallible.
pub struct RunLogger {
    sink: Option<Box<dyn LogSink>>,
    observer: Option<Box<dyn EventObserver>>,
    events: u64,
    error: Option<String>,
}

impl RunLogger {
    /// The zero-cost no-op logger.
    pub fn disabled() -> RunLogger {
        RunLogger { sink: None, observer: None, events: 0, error: None }
    }

    pub fn new(sink: Box<dyn LogSink>) -> RunLogger {
        RunLogger { sink: Some(sink), observer: None, events: 0, error: None }
    }

    /// A logger that only feeds an in-process observer (no disk/memory log).
    pub fn observing(observer: Box<dyn EventObserver>) -> RunLogger {
        RunLogger { sink: None, observer: Some(observer), events: 0, error: None }
    }

    /// Attach an observer alongside whatever sink is already configured.
    pub fn with_observer(mut self, observer: Box<dyn EventObserver>) -> RunLogger {
        self.observer = Some(observer);
        self
    }

    pub fn enabled(&self) -> bool {
        (self.sink.is_some() || self.observer.is_some()) && self.error.is_none()
    }

    /// Events written so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Log one event. The closure only runs when the logger is live.
    #[inline]
    pub fn emit<F: FnOnce() -> RunEvent>(&mut self, make: F) {
        if self.error.is_some() {
            return;
        }
        if self.sink.is_none() && self.observer.is_none() {
            return;
        }
        let ev = make();
        if let Some(sink) = self.sink.as_mut() {
            if self.events > 0 && self.events % SEGMENT_EVENTS == 0 {
                if let Err(e) = sink.rotate() {
                    self.error = Some(format!("run log rotate failed: {e}"));
                    return;
                }
            }
            let frame = encode_frame(&ev);
            if let Err(e) = sink.write(&frame) {
                self.error = Some(format!("run log write failed: {e}"));
                return;
            }
        }
        if let Some(obs) = self.observer.as_mut() {
            obs.observe(&ev);
        }
        self.events += 1;
    }

    /// Flush and close, reporting the first deferred sink error if any.
    pub fn finish(&mut self) -> Result<()> {
        if let Some(e) = self.error.take() {
            self.sink = None;
            return Err(anyhow!(e));
        }
        if let Some(mut sink) = self.sink.take() {
            sink.finish().map_err(|e| anyhow!("run log close failed: {e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<RunEvent> {
        vec![
            RunEvent::RunStart {
                label: "smoke".into(),
                perplexity: false,
                mode: 2,
                buffer_k: 3,
                max_staleness: Some(4),
                rounds: 5,
                eval_every: 2,
                use_saa: true,
                staleness_threshold: None,
            },
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Eligibility { count: 14 },
            RunEvent::Selected { learner: 3 },
            RunEvent::FaultDecision { kind: 4, learner: 9, round: 1 },
            RunEvent::TaskDropout { learner: 1, spent: 12.5 },
            RunEvent::StragglerSpend { learner: 2, duration: 90.25, fate: FATE_DOOMED },
            RunEvent::FreshSpend { learner: 3, duration: 33.0, corrupt: true },
            RunEvent::Trained { learner: 3, mean_loss: 1.75, duration: 33.0, fresh: true },
            RunEvent::StaleDelivery { learner: 2, origin_round: 0, duration: 90.25 },
            RunEvent::EvalDone { loss: 2.5, acc: 0.125 },
            RunEvent::RoundEnd { round_duration: 120.0 },
            RunEvent::KernelPop { at: 7.5, class: 0 },
            RunEvent::AsyncSpawn { learner: 5, duration: 40.0, dropped_after: Some(8.0) },
            RunEvent::AsyncDropout { learner: 5, spent: 8.0 },
            RunEvent::AsyncDelivery {
                learner: 6,
                origin_version: 2,
                duration: 41.5,
                mean_loss: 0.5,
                corrupt: false,
            },
            RunEvent::MergeCommit { eval: Some((1.0, 0.5)) },
            RunEvent::MergeCommit { eval: None },
            RunEvent::AsyncBurn { end: 99.0 },
            RunEvent::SweepLeftover { secs: 17.25 },
            RunEvent::RunEnd,
            RunEvent::JobSetStart {
                label: "storm".into(),
                jobs: 4,
                policy: "fair".into(),
                rounds: 6,
                eval_every: 3,
            },
            RunEvent::JobStart {
                job: 1,
                selector: "oort".into(),
                mode: "dl60".into(),
                target: 8,
                priority: 2,
            },
            RunEvent::JobRoundStart { job: 1, round: 0, now: 5.5 },
            RunEvent::JobSpawn {
                job: 1,
                learner: 7,
                now: 5.5,
                duration: 42.0,
                dropped_after: Some(10.5),
                corrupt: false,
            },
            RunEvent::JobDelivery {
                job: 1,
                learner: 7,
                duration: 42.0,
                mean_loss: 0.75,
                fate: FATE_TRAINED,
            },
            RunEvent::JobRoundEnd {
                job: 1,
                round: 0,
                now: 65.5,
                round_duration: 60.0,
                fresh: 1,
                failed: false,
                train_loss: Some(0.75),
                eval_loss: Some(2.0),
                eval_acc: Some(0.25),
            },
            RunEvent::JobRoundEnd {
                job: 2,
                round: 3,
                now: 400.0,
                round_duration: 100.0,
                fresh: 0,
                failed: true,
                train_loss: None,
                eval_loss: None,
                eval_acc: None,
            },
            RunEvent::JobSweep { job: 1, secs: 13.5 },
            RunEvent::JobSetEnd,
        ]
    }

    #[test]
    fn every_variant_round_trips() {
        for ev in sample_events() {
            let mut payload = Vec::new();
            encode_event(&ev, &mut payload);
            assert_eq!(decode_event(&payload).unwrap(), ev, "{ev:?}");
        }
    }

    #[test]
    fn f64_bits_survive_exactly() {
        for v in [0.0, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, 1e300, -7.25] {
            let ev = RunEvent::SweepLeftover { secs: v };
            let mut payload = Vec::new();
            encode_event(&ev, &mut payload);
            let RunEvent::SweepLeftover { secs } = decode_event(&payload).unwrap() else {
                panic!("wrong variant");
            };
            assert_eq!(secs.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn crc32_matches_known_vector() {
        // the classic IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut payload = Vec::new();
        encode_event(&RunEvent::RunEnd, &mut payload);
        payload.push(0);
        assert!(decode_event(&payload).is_err());
    }

    #[test]
    fn mem_sink_logs_and_decodes() {
        let sink = MemSink::new();
        let mut logger = RunLogger::new(Box::new(sink.clone()));
        assert!(logger.enabled());
        let events = sample_events();
        for ev in &events {
            let ev = ev.clone();
            logger.emit(move || ev);
        }
        logger.finish().unwrap();
        let (decoded, stats) = decode_segments(&sink.segments());
        assert!(stats.clean, "{:?}", stats.note);
        assert_eq!(decoded, events);
        assert_eq!(stats.frames, events.len());
    }

    #[test]
    fn observer_sees_every_event_in_order() {
        struct Collect(Arc<Mutex<Vec<RunEvent>>>);
        impl EventObserver for Collect {
            fn observe(&mut self, ev: &RunEvent) {
                self.0
                    .lock()
                    .unwrap_or_else(|poisoned| poisoned.into_inner())
                    .push(ev.clone());
            }
        }
        let seen = Arc::new(Mutex::new(Vec::new()));
        let sink = MemSink::new();
        let mut logger =
            RunLogger::new(Box::new(sink.clone())).with_observer(Box::new(Collect(seen.clone())));
        assert!(logger.enabled());
        let events = sample_events();
        for ev in &events {
            let ev = ev.clone();
            logger.emit(move || ev);
        }
        logger.finish().unwrap();
        let observed = seen.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).clone();
        assert_eq!(observed, events, "observer sees the same stream the sink wrote");
        let (decoded, stats) = decode_segments(&sink.segments());
        assert!(stats.clean);
        assert_eq!(decoded, events, "attaching an observer does not perturb the log");
        // observer-only logger counts events but writes nothing
        let seen2 = Arc::new(Mutex::new(Vec::new()));
        let mut solo = RunLogger::observing(Box::new(Collect(seen2.clone())));
        assert!(solo.enabled());
        solo.emit(|| RunEvent::RunEnd);
        assert_eq!(solo.events(), 1);
        solo.finish().unwrap();
        assert_eq!(
            seen2.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).len(),
            1
        );
    }

    #[test]
    fn disabled_logger_never_runs_the_closure() {
        let mut logger = RunLogger::disabled();
        assert!(!logger.enabled());
        logger.emit(|| panic!("closure must not run when disabled"));
        assert_eq!(logger.events(), 0);
        logger.finish().unwrap();
    }

    #[test]
    fn logger_rotates_segments() {
        let sink = MemSink::new();
        let mut logger = RunLogger::new(Box::new(sink.clone()));
        for _ in 0..(SEGMENT_EVENTS + 1) {
            logger.emit(|| RunEvent::RunEnd);
        }
        logger.finish().unwrap();
        let segs = sink.segments();
        assert_eq!(segs.len(), 2, "one rotation after {SEGMENT_EVENTS} events");
        let (decoded, stats) = decode_segments(&segs);
        assert!(stats.clean);
        assert_eq!(decoded.len(), (SEGMENT_EVENTS + 1) as usize);
        assert_eq!(stats.segments, 2);
    }

    #[test]
    fn truncated_tail_yields_clean_prefix() {
        let sink = MemSink::new();
        let mut logger = RunLogger::new(Box::new(sink.clone()));
        for ev in sample_events() {
            logger.emit(move || ev);
        }
        logger.finish().unwrap();
        let mut segs = sink.segments();
        let seg = &mut segs[0];
        seg.truncate(seg.len() - 3);
        let (decoded, stats) = decode_segments(&segs);
        assert!(!stats.clean);
        assert_eq!(decoded.len(), sample_events().len() - 1);
        assert!(stats.note.unwrap().contains("truncated"));
    }

    #[test]
    fn corrupt_byte_yields_clean_prefix() {
        let sink = MemSink::new();
        let mut logger = RunLogger::new(Box::new(sink.clone()));
        for ev in sample_events() {
            logger.emit(move || ev);
        }
        logger.finish().unwrap();
        let mut segs = sink.segments();
        let mid = segs[0].len() / 2;
        segs[0][mid] ^= 0xFF;
        let (decoded, stats) = decode_segments(&segs);
        assert!(!stats.clean);
        assert!(decoded.len() < sample_events().len());
    }

    #[test]
    fn bad_magic_decodes_nothing() {
        let (decoded, stats) = decode_segments(&[b"NOTALOG!".to_vec()]);
        assert!(decoded.is_empty());
        assert!(!stats.clean);
    }

    #[test]
    fn dir_sink_round_trips_through_disk() {
        let dir =
            std::env::temp_dir().join(format!("relay-runlog-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let sink = DirSink::create(&dir).unwrap();
        let mut logger = RunLogger::new(Box::new(sink));
        let events = sample_events();
        for ev in &events {
            let ev = ev.clone();
            logger.emit(move || ev);
        }
        logger.finish().unwrap();
        let segs = read_dir_segments(&dir).unwrap();
        let (decoded, stats) = decode_segments(&segs);
        assert!(stats.clean, "{:?}", stats.note);
        assert_eq!(decoded, events);
        let _ = fs::remove_dir_all(&dir);
    }
}
