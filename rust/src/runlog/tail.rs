//! Tailing decoder: incrementally consume runlog segments *while they are
//! being written*, without ever blocking or perturbing the writer.
//!
//! The batch decoder ([`super::decode_segments`]) answers "what does this
//! finished log say"; the tailer answers "what has the log said *so far*"
//! and keeps answering as bytes arrive. The contract:
//!
//! * **exactly-once** — every CRC-valid frame is yielded exactly once
//!   across any sequence of polls, no matter how the reads interleave with
//!   the writer's appends;
//! * **torn tails are not errors** — a partial frame at the end of the
//!   *current* segment just means the writer hasn't finished it; the
//!   cursor waits. Only a finalized segment (one whose successor already
//!   exists — [`super::DirSink::rotate`] flushes a segment to disk before
//!   creating the next) can be declared truncated or corrupt;
//! * **corruption skips forward at rotation** — a corrupt region stops
//!   decoding for the rest of that segment (frame boundaries are
//!   unrecoverable mid-stream), and the tailer resumes at the next
//!   segment's first frame, recording what it skipped in [`TailStats`].
//!
//! Reading a file that another process appends to is racy by nature; the
//! one ordering fact the tailer leans on is that `rotate()` fully flushes
//! segment N before creating `seg-(N+1)`, so observing the successor
//! *before* reading segment N proves the bytes read are final.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use super::{crc32, decode_event, RunEvent, MAGIC};

/// Upper bound on a single frame's payload length. Real frames are tens of
/// bytes; anything past this is garbage masquerading as a length, and
/// without the bound a corrupt varint could make the tailer wait forever
/// for petabytes that will never arrive.
pub const MAX_FRAME_BYTES: u64 = 1 << 20;

/// What the tailer has seen so far, across all segments.
#[derive(Clone, Debug, Default)]
pub struct TailStats {
    /// Segments fully consumed and left behind (their successor existed).
    pub segments_finalized: usize,
    /// Frames decoded and yielded.
    pub frames: usize,
    /// One note per finalized segment whose tail was truncated or corrupt
    /// (decoding resumed at the next segment boundary).
    pub skipped: Vec<String>,
}

enum FrameStep {
    /// A complete, CRC-valid frame: the event and the bytes it consumed.
    Event(RunEvent, usize),
    /// Not enough bytes yet — the writer may still be appending.
    Torn,
    /// The bytes can never become a valid frame.
    Corrupt(String),
}

/// Try to decode one frame from the front of `buf`. Distinguishes "not
/// enough bytes yet" ([`FrameStep::Torn`]) from "can never be valid"
/// ([`FrameStep::Corrupt`]) — the distinction the batch decoder never
/// needs, and the whole reason this module exists.
fn next_frame(buf: &[u8]) -> FrameStep {
    let mut len = 0u64;
    let mut shift = 0u32;
    let mut header = 0usize;
    loop {
        let Some(&b) = buf.get(header) else {
            return FrameStep::Torn;
        };
        header += 1;
        if shift >= 64 || (shift == 63 && b > 1) {
            return FrameStep::Corrupt("frame length varint overflows u64".into());
        }
        len |= u64::from(b & 0x7F) << shift;
        if b & 0x80 == 0 {
            break;
        }
        shift += 7;
    }
    if len > MAX_FRAME_BYTES {
        return FrameStep::Corrupt(format!(
            "frame length {len} exceeds the {MAX_FRAME_BYTES}-byte bound"
        ));
    }
    let len = len as usize;
    let end = header + len + 4;
    if buf.len() < end {
        return FrameStep::Torn;
    }
    let payload = &buf[header..header + len];
    let crc = &buf[header + len..end];
    let stored = u32::from_le_bytes([crc[0], crc[1], crc[2], crc[3]]);
    if crc32(payload) != stored {
        return FrameStep::Corrupt("CRC mismatch".into());
    }
    match decode_event(payload) {
        Ok(ev) => FrameStep::Event(ev, end),
        Err(e) => FrameStep::Corrupt(format!("bad frame: {e}")),
    }
}

/// Incremental clean-prefix decoder over one segment's byte stream. Feed it
/// ever-longer snapshots of the same segment; it remembers how far it got
/// and yields each frame exactly once.
#[derive(Default)]
pub struct SegmentCursor {
    /// Bytes fully consumed (magic + whole frames).
    pos: usize,
    /// Set once decoding hit bytes that can never become a valid frame;
    /// the cursor stays stuck there (recovery happens at segment rotation).
    corrupt: Option<String>,
}

impl SegmentCursor {
    pub fn new() -> SegmentCursor {
        SegmentCursor::default()
    }

    /// Decode every newly-complete frame from `buf` (a fresh snapshot of
    /// the whole segment, magic included) into `out`; returns how many
    /// events were appended.
    pub fn drain(&mut self, buf: &[u8], out: &mut Vec<RunEvent>) -> usize {
        if self.corrupt.is_some() {
            return 0;
        }
        if buf.len() < self.pos {
            self.corrupt = Some(format!(
                "segment shrank from {} to {} bytes",
                self.pos,
                buf.len()
            ));
            return 0;
        }
        if self.pos == 0 {
            // the magic header may itself arrive torn
            let have = buf.len().min(MAGIC.len());
            if buf[..have] != MAGIC[..have] {
                self.corrupt = Some("bad or missing magic".into());
                return 0;
            }
            if buf.len() < MAGIC.len() {
                return 0;
            }
            self.pos = MAGIC.len();
        }
        let mut appended = 0;
        loop {
            match next_frame(&buf[self.pos..]) {
                FrameStep::Event(ev, used) => {
                    out.push(ev);
                    self.pos += used;
                    appended += 1;
                }
                FrameStep::Torn => break,
                FrameStep::Corrupt(why) => {
                    self.corrupt = Some(format!("{why} at byte {}", self.pos));
                    break;
                }
            }
        }
        appended
    }

    /// Bytes consumed so far (magic + whole frames).
    pub fn consumed(&self) -> usize {
        self.pos
    }

    /// Why the cursor is stuck, if it is.
    pub fn corrupt(&self) -> Option<&str> {
        self.corrupt.as_deref()
    }

    /// True when a `len`-byte snapshot was consumed completely — i.e. a
    /// finalized segment of that size ends exactly on a frame boundary.
    pub fn is_clean_at(&self, len: usize) -> bool {
        self.corrupt.is_none() && self.pos == len
    }
}

/// Tails a [`super::DirSink`] directory: repeated [`poll`] calls yield the
/// newly-arrived events, following segment rotations, exactly once each.
///
/// [`poll`]: DirTailer::poll
pub struct DirTailer {
    dir: PathBuf,
    idx: usize,
    cursor: SegmentCursor,
    stats: TailStats,
}

impl DirTailer {
    /// Start tailing `dir` from the first segment. The directory (or the
    /// first segment) need not exist yet — polls just return nothing until
    /// it does.
    pub fn open(dir: impl Into<PathBuf>) -> DirTailer {
        DirTailer {
            dir: dir.into(),
            idx: 0,
            cursor: SegmentCursor::new(),
            stats: TailStats::default(),
        }
    }

    fn seg_path(&self, idx: usize) -> PathBuf {
        self.dir.join(format!("seg-{idx:05}.rlog"))
    }

    /// The directory being tailed.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Index of the segment the cursor currently sits in.
    pub fn segment_index(&self) -> usize {
        self.idx
    }

    pub fn stats(&self) -> &TailStats {
        &self.stats
    }

    /// Collect every event that has become decodable since the last poll.
    /// Never blocks, never writes; an empty vec just means nothing new.
    pub fn poll(&mut self) -> io::Result<Vec<RunEvent>> {
        let mut out = Vec::new();
        loop {
            // Order matters: observe the successor BEFORE reading this
            // segment. rotate() flushes seg-N to disk before creating
            // seg-(N+1), so a successor seen *first* proves the bytes we
            // are about to read are final. (The other order could pair a
            // stale pre-flush read with a fresh successor sighting and
            // wrongly declare a still-growing tail truncated.)
            let has_next = self.seg_path(self.idx + 1).exists();
            let buf = match fs::read(self.seg_path(self.idx)) {
                Ok(b) => b,
                Err(e) if e.kind() == io::ErrorKind::NotFound => break,
                Err(e) => return Err(e),
            };
            let n = self.cursor.drain(&buf, &mut out);
            self.stats.frames += n;
            if !has_next {
                break;
            }
            // finalized: record anything undecodable at its tail, move on
            if let Some(why) = self.cursor.corrupt() {
                self.stats.skipped.push(format!("segment {}: {why}", self.idx));
            } else if !self.cursor.is_clean_at(buf.len()) {
                self.stats.skipped.push(format!(
                    "segment {}: truncated tail ({} of {} bytes)",
                    self.idx,
                    self.cursor.consumed(),
                    buf.len()
                ));
            }
            self.stats.segments_finalized += 1;
            self.idx += 1;
            self.cursor = SegmentCursor::new();
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::encode_frame;
    use super::*;

    fn sample() -> Vec<RunEvent> {
        vec![
            RunEvent::RoundStart { round: 0, now: 0.0 },
            RunEvent::Selected { learner: 7 },
            RunEvent::Trained { learner: 7, mean_loss: 0.5, duration: 3.25, fresh: true },
            RunEvent::RoundEnd { round_duration: 4.5 },
        ]
    }

    fn segment_bytes(events: &[RunEvent]) -> Vec<u8> {
        let mut buf = MAGIC.to_vec();
        for ev in events {
            buf.extend_from_slice(&encode_frame(ev));
        }
        buf
    }

    #[test]
    fn byte_by_byte_feed_yields_each_event_exactly_once() {
        let events = sample();
        let full = segment_bytes(&events);
        let mut cursor = SegmentCursor::new();
        let mut got = Vec::new();
        for n in 0..=full.len() {
            cursor.drain(&full[..n], &mut got);
        }
        assert_eq!(got, events);
        assert!(cursor.is_clean_at(full.len()));
        // one more full drain yields nothing new
        assert_eq!(cursor.drain(&full, &mut got), 0);
        assert_eq!(got, events);
    }

    #[test]
    fn torn_magic_waits_and_wrong_magic_is_corrupt() {
        let mut cursor = SegmentCursor::new();
        let mut out = Vec::new();
        assert_eq!(cursor.drain(&MAGIC[..3], &mut out), 0);
        assert!(cursor.corrupt().is_none(), "partial magic is torn, not corrupt");
        let mut bad = MAGIC.to_vec();
        bad[2] ^= 0xFF;
        let mut cursor = SegmentCursor::new();
        cursor.drain(&bad, &mut out);
        assert!(cursor.corrupt().is_some());
        assert!(out.is_empty());
    }

    #[test]
    fn corrupt_byte_sticks_until_rotation() {
        let events = sample();
        let mut buf = segment_bytes(&events);
        // flip a byte inside the second frame's payload
        let first_len = MAGIC.len() + encode_frame(&events[0]).len();
        buf[first_len + 2] ^= 0xFF;
        let mut cursor = SegmentCursor::new();
        let mut out = Vec::new();
        cursor.drain(&buf, &mut out);
        assert_eq!(out, &events[..1], "clean prefix only");
        assert!(cursor.corrupt().is_some());
        // more bytes never un-stick a corrupt cursor
        buf.extend_from_slice(&encode_frame(&events[3]));
        assert_eq!(cursor.drain(&buf, &mut out), 0);
    }

    #[test]
    fn shrinking_segment_is_corrupt() {
        let events = sample();
        let full = segment_bytes(&events);
        let mut cursor = SegmentCursor::new();
        let mut out = Vec::new();
        cursor.drain(&full, &mut out);
        assert_eq!(cursor.drain(&full[..full.len() - 1], &mut out), 0);
        assert!(cursor.corrupt().expect("shrink must stick").contains("shrank"));
    }

    #[test]
    fn oversized_frame_length_is_corrupt_not_torn() {
        let mut buf = MAGIC.to_vec();
        // varint encoding of a huge length: would be "torn" forever if the
        // tailer waited for the bytes to arrive
        buf.extend_from_slice(&[0xFF, 0xFF, 0xFF, 0x7F]);
        let mut cursor = SegmentCursor::new();
        let mut out = Vec::new();
        cursor.drain(&buf, &mut out);
        assert!(cursor.corrupt().expect("must be corrupt").contains("exceeds"));
    }

    #[test]
    fn dir_tailer_follows_rotation_exactly_once() {
        let dir = std::env::temp_dir()
            .join(format!("relay-tail-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).expect("create tail test dir");
        let events = sample();
        let mut tailer = DirTailer::open(&dir);
        assert!(tailer.poll().expect("poll empty dir").is_empty());
        // seg 0 appears with two events
        fs::write(dir.join("seg-00000.rlog"), segment_bytes(&events[..2]))
            .expect("write seg 0");
        assert_eq!(tailer.poll().expect("poll seg 0"), &events[..2]);
        assert!(tailer.poll().expect("re-poll").is_empty());
        // seg 0 grows, then rotates: seg 1 carries the rest
        fs::write(dir.join("seg-00000.rlog"), segment_bytes(&events[..3]))
            .expect("grow seg 0");
        fs::write(dir.join("seg-00001.rlog"), segment_bytes(&events[3..]))
            .expect("write seg 1");
        let got = tailer.poll().expect("poll across rotation");
        assert_eq!(got, &events[2..]);
        assert_eq!(tailer.segment_index(), 1);
        assert_eq!(tailer.stats().segments_finalized, 1);
        assert_eq!(tailer.stats().frames, events.len());
        assert!(tailer.stats().skipped.is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn dir_tailer_skips_corrupt_tail_at_rotation() {
        let dir = std::env::temp_dir()
            .join(format!("relay-tail-skip-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).expect("create tail test dir");
        let events = sample();
        let mut seg0 = segment_bytes(&events[..2]);
        let first_len = MAGIC.len() + encode_frame(&events[0]).len();
        seg0[first_len + 2] ^= 0xFF;
        fs::write(dir.join("seg-00000.rlog"), &seg0).expect("write seg 0");
        let mut tailer = DirTailer::open(&dir);
        assert_eq!(tailer.poll().expect("poll corrupt seg"), &events[..1]);
        // rotation finalizes seg 0; the tailer records the skip and resumes
        fs::write(dir.join("seg-00001.rlog"), segment_bytes(&events[2..]))
            .expect("write seg 1");
        assert_eq!(tailer.poll().expect("poll past corruption"), &events[2..]);
        assert_eq!(tailer.stats().skipped.len(), 1);
        assert!(tailer.stats().skipped[0].contains("segment 0"));
        let _ = fs::remove_dir_all(&dir);
    }
}
