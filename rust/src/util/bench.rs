//! Micro/macro-benchmark substrate (criterion is unavailable offline):
//! warm-up, automatic iteration calibration to a time budget, and
//! median/p95 reporting. Used by `cargo bench` (`rust/benches/`).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-calibrating the per-sample iteration count so the
/// whole run fits in roughly `budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warm-up + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let total_ns = budget.as_nanos() as f64;
    let samples = 16usize;
    let per_sample = ((total_ns / once / samples as f64).floor() as usize).clamp(1, 1_000_000);

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..per_sample {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / per_sample as f64);
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: per_sample * samples,
        mean_ns: mean,
        median_ns: times[times.len() / 2],
        p95_ns: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
        min_ns: times[0],
    }
}

/// Run + print a bench with the default 1.5 s budget.
pub fn run<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, Duration::from_millis(1500), f);
    println!("{}", r.report());
    r
}

/// Best-effort `git describe --always --dirty` of the working tree, for
/// stamping committed benchmark points with the revision they measured.
/// `None` when git or a repo is unavailable (shipped binaries, tarballs).
pub fn git_describe() -> Option<String> {
    let out = std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let text = String::from_utf8(out.stdout).ok()?;
    let text = text.trim();
    (!text.is_empty()).then(|| text.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let r = bench("sleep1ms", Duration::from_millis(100), || {
            std::thread::sleep(Duration::from_millis(1));
        });
        assert!(r.median_ns > 0.8e6, "median {}", r.median_ns);
        assert!(r.iters >= 16);
    }

    #[test]
    fn git_describe_is_clean_when_present() {
        // environment-dependent: only shape-check what comes back
        if let Some(desc) = git_describe() {
            assert!(!desc.is_empty());
            assert!(!desc.contains('\n'), "{desc:?}");
        }
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
