//! Foundational substrates built from scratch for the offline environment:
//! RNG, JSON, statistics, property testing, CLI parsing, thread pool.

pub mod bench;
pub mod cli;
pub mod json;
pub mod lazy;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
