//! Deterministic RNG substrate (no `rand` crate offline): splitmix64 seeding
//! + xoshiro256++ streams, plus the distributions the simulator needs
//! (uniform, normal, exponential, lognormal, Zipf, shuffle, choice).
//!
//! Every stochastic component of the simulator takes an explicit `Rng` so
//! experiments are reproducible from a single seed (paper runs use 3 seeds).

/// splitmix64: used to derive well-separated seeds/streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, 256-bit state, good statistical quality.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. one per learner) from this RNG's
    /// seed space without correlating with `self`'s future output.
    pub fn stream(&self, stream_id: u64) -> Rng {
        let mut sm = self.s[0] ^ stream_id.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method would be faster; modulo bias is negligible for
        // n << 2^64 and this is not a hot path.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (mean = 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    /// Lognormal with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in [0, n) with exponent `alpha` (paper uses
    /// alpha = 1.95 for label skew). Inverse-CDF over precomputed weights is
    /// wasteful per call; for simulator use, prefer `ZipfSampler`.
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        ZipfSampler::new(n, alpha).sample(self)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices out of [0, n) (partial Fisher-Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index proportionally to `weights` (>= 0, not all zero).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed-CDF Zipf sampler: O(n) build, O(log n) sample.
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, alpha: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 1..=n {
            acc += (r as f64).powf(-alpha);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap_or(&1.0);
        for c in &mut cdf {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.total_cmp(&u))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn streams_are_independent_and_deterministic() {
        let root = Rng::new(99);
        let mut s1 = root.stream(1);
        let mut s1b = root.stream(1);
        let mut s2 = root.stream(2);
        let a: Vec<u64> = (0..4).map(|_| s1.next_u64()).collect();
        let b: Vec<u64> = (0..4).map(|_| s1b.next_u64()).collect();
        let c: Vec<u64> = (0..4).map(|_| s2.next_u64()).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn zipf_rank_frequencies_decay() {
        let mut r = Rng::new(7);
        let sampler = ZipfSampler::new(100, 1.95);
        let mut counts = vec![0usize; 100];
        for _ in 0..100_000 {
            counts[sampler.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[1]);
        assert!(counts[1] > counts[5]);
        assert!(counts[5] > counts[50]);
        // rank-0 share for alpha=1.95, n=100 is ~62%
        let share = counts[0] as f64 / 100_000.0;
        assert!((share - 0.62).abs() < 0.05, "share={share}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(9);
        for _ in 0..100 {
            let picked = r.choose_k(20, 7);
            assert_eq!(picked.len(), 7);
            let mut s = picked.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 7);
        }
    }

    #[test]
    fn choose_k_caps_at_n() {
        let mut r = Rng::new(10);
        assert_eq!(r.choose_k(3, 10).len(), 3);
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }
}
