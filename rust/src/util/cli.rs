//! CLI argument substrate (clap is unavailable offline): positional
//! subcommand + `--flag value` / `--flag` options with typed accessors.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse, treating the first non-flag token as the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--k=v`, `--k v`, or bare `--k`
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.str_opt(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.str_opt(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.str_opt(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.str_opt(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list flag (`--selectors random,oort`); empty entries
    /// are dropped, whitespace around entries is trimmed.
    pub fn list_or(&self, name: &str, default: &str) -> Vec<String> {
        self.str_or(name, default)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("figure 6 extra");
        assert_eq!(a.command.as_deref(), Some("figure"));
        assert_eq!(a.positional, vec!["6", "extra"]);
    }

    #[test]
    fn flag_forms() {
        let a = parse("run --rounds 50 --mode=dl --verbose --seed 7");
        assert_eq!(a.usize_or("rounds", 0), 50);
        assert_eq!(a.str_or("mode", ""), "dl");
        assert!(a.bool("verbose"));
        assert_eq!(a.u64_or("seed", 0), 7);
        assert_eq!(a.f64_or("missing", 1.5), 1.5);
    }

    #[test]
    fn flag_before_command() {
        let a = parse("--config x.json run");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.str_opt("config"), Some("x.json"));
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        parse("run --rounds abc").usize_or("rounds", 0);
    }

    #[test]
    fn list_flags_split_and_trim() {
        let a = parse("sweep --selectors random,oort,priority");
        assert_eq!(a.list_or("selectors", ""), vec!["random", "oort", "priority"]);
        assert_eq!(a.list_or("modes", "oc,dl"), vec!["oc", "dl"]);
        let b = Args::parse(["sweep".into(), "--x".into(), " a , b ,".into()]);
        assert_eq!(b.list_or("x", ""), vec!["a", "b"]);
        assert!(b.list_or("missing", "").is_empty());
    }
}
