//! Lazy per-index slot table: each slot is initialized at most once, at
//! first touch, thread-safely. Shared by the trace and forecaster scale
//! paths (`trace::LazyTraceSet`, `forecast::ForecasterBank`) so the
//! on-demand machinery — and its eager/lazy-equivalence guarantees — live
//! in one place.

use std::sync::OnceLock;

pub struct LazySlots<T> {
    slots: Vec<OnceLock<T>>,
}

impl<T> LazySlots<T> {
    /// `n` empty slots; does no initialization work.
    pub fn new(n: usize) -> LazySlots<T> {
        LazySlots { slots: (0..n).map(|_| OnceLock::new()).collect() }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The slot's value, computing it via `init` at first touch.
    pub fn get_or_init<F: FnOnce() -> T>(&self, index: usize, init: F) -> &T {
        self.slots[index].get_or_init(init)
    }

    /// How many slots have been initialized so far.
    pub fn initialized(&self) -> usize {
        self.slots.iter().filter(|s| s.get().is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initializes_each_slot_at_most_once() {
        let slots: LazySlots<usize> = LazySlots::new(3);
        assert_eq!(slots.len(), 3);
        assert_eq!(slots.initialized(), 0);
        let a = slots.get_or_init(1, || 41) as *const usize;
        assert_eq!(slots.initialized(), 1);
        let b = slots.get_or_init(1, || panic!("must not re-init")) as *const usize;
        assert_eq!(a, b);
        assert_eq!(*slots.get_or_init(1, || 0), 41);
        assert_eq!(slots.initialized(), 1);
    }

    #[test]
    fn empty_table() {
        let slots: LazySlots<u8> = LazySlots::new(0);
        assert!(slots.is_empty());
        assert_eq!(slots.initialized(), 0);
    }
}
