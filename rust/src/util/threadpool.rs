//! Worker-pool substrate (tokio is unavailable offline; the coordinator is
//! event-driven so a work-stealing-free pool suffices). Two primitives:
//!
//! * [`run_parallel`] — the scoped batch pool: run a vector of closures and
//!   return their results **in job order** regardless of completion order.
//!   Used for experiment-level fan-out (sweep cells, availability-index
//!   builds) where the whole batch is known up front.
//! * [`TrainPool`] + [`Ticket`] — the persistent intra-round training pool:
//!   jobs are submitted one at a time as the simulation discovers them
//!   (e.g. per-arrival refills in the buffered-async regime) and each
//!   returns a ticket. Workers complete in any order; callers **commit
//!   outcomes in the order they wait on tickets** — a fixed reduction order
//!   pinned by tests, so every `ExperimentResult` stays byte-identical at
//!   any worker count. A width of 1 runs jobs inline at submit time, which
//!   is exactly the pre-pool serial path.
//!
//! Panic discipline: a panicking job is caught on the worker, carried
//! through the ticket, and **re-thrown at `Ticket::wait`** — the round that
//! submitted it fails loudly instead of deadlocking on a result that will
//! never arrive, and the pool itself stays serviceable for other jobs.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `jobs` closures on up to `workers` threads; return results in the
/// original job order.
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let queue = Arc::new(Mutex::new(
        jobs.into_iter().enumerate().collect::<Vec<_>>(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            scope.spawn(move || loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((i, f)) => {
                        let r = f();
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("worker died")).collect()
    })
}

/// Number of worker threads to use by default.
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A handle to one submitted job's eventual result. Waiting is the commit
/// point: callers decide the reduction order by the order of their `wait`
/// calls, never by completion order.
pub struct Ticket<T> {
    inner: TicketInner<T>,
}

enum TicketInner<T> {
    /// Width-1 (serial) pools run the job inline at submit time.
    Ready(T),
    /// The job is (or was) on the pool; the worker sends the outcome here.
    Pending(mpsc::Receiver<thread::Result<T>>),
}

impl<T> Ticket<T> {
    /// Block until the job finishes and return its result. Re-throws the
    /// job's panic if it had one; panics (loudly, not a deadlock) if the
    /// worker died without reporting.
    pub fn wait(self) -> T {
        match self.inner {
            TicketInner::Ready(v) => v,
            TicketInner::Pending(rx) => match rx.recv() {
                Ok(Ok(v)) => v,
                Ok(Err(panic)) => resume_unwind(panic),
                Err(_) => panic!("train pool worker died without reporting a result"),
            },
        }
    }
}

/// Persistent training pool: `workers` threads pulling submitted jobs in
/// FIFO order. See the module docs for the determinism and panic contracts.
pub struct TrainPool {
    /// `None` = width 1: submit runs the job inline (the serial path).
    inner: Option<PoolInner>,
    workers: usize,
}

struct PoolInner {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl TrainPool {
    /// A pool of `workers.max(1)` lanes; 1 means fully inline/serial.
    pub fn new(workers: usize) -> TrainPool {
        let workers = workers.max(1);
        if workers == 1 {
            return TrainPool { inner: None, workers };
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    // hold the lock only for the dequeue, not the job
                    let job = match rx.lock() {
                        Ok(rx) => rx.recv(),
                        // a sibling worker panicked *outside* catch_unwind
                        // (can't happen for submitted jobs, but don't spin)
                        Err(_) => return,
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => return, // pool dropped: drain and exit
                    }
                })
            })
            .collect();
        TrainPool { inner: Some(PoolInner { tx: Some(tx), handles }), workers }
    }

    /// Pool width (1 = inline).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Submit one job; returns the ticket its result arrives on. Jobs are
    /// dispatched in submission order. Panics inside `f` are delivered at
    /// `Ticket::wait`, not here (inline pools propagate them here, which is
    /// where the serial path would have panicked anyway).
    pub fn submit<T, F>(&self, f: F) -> Ticket<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        match &self.inner {
            None => Ticket { inner: TicketInner::Ready(f()) },
            Some(pool) => {
                let (tx, rx) = mpsc::sync_channel::<thread::Result<T>>(1);
                let job: Job = Box::new(move || {
                    let out = catch_unwind(AssertUnwindSafe(f));
                    // the ticket may have been dropped (e.g. a discarded
                    // async update); the outcome is simply unobserved
                    let _ = tx.send(out);
                });
                pool.tx
                    .as_ref()
                    .expect("train pool sender lives until drop")
                    .send(job)
                    .expect("train pool workers exited early");
                Ticket { inner: TicketInner::Pending(rx) }
            }
        }
    }
}

impl Drop for TrainPool {
    fn drop(&mut self) {
        if let Some(pool) = &mut self.inner {
            drop(pool.tx.take()); // close the queue; workers drain and exit
            for h in pool.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..64)
            .map(|i| move || i * 2)
            .collect();
        let out = run_parallel(8, jobs);
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_parallel(1, jobs), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(run_parallel(4, jobs).is_empty());
    }

    #[test]
    fn pool_commits_in_wait_order_despite_adversarial_sleeps() {
        // later-submitted jobs finish *first* (reverse-sorted sleeps); the
        // committed order must still be the ticket/wait order
        let pool = TrainPool::new(8);
        let tickets: Vec<_> = (0..16u64)
            .map(|i| {
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(2 * (16 - i)));
                    i * 3
                })
            })
            .collect();
        let out: Vec<u64> = tickets.into_iter().map(|t| t.wait()).collect();
        assert_eq!(out, (0..16u64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn pool_width_one_runs_inline() {
        let pool = TrainPool::new(1);
        let here = std::thread::current().id();
        let t = pool.submit(move || std::thread::current().id() == here);
        assert!(t.wait(), "width-1 pool must run on the submitting thread");
        assert_eq!(pool.workers(), 1);
        assert_eq!(TrainPool::new(0).workers(), 1, "0 clamps to inline");
    }

    #[test]
    fn pool_overlaps_submitted_jobs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = TrainPool::new(4);
        let live = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let tickets: Vec<_> = (0..12)
            .map(|_| {
                let live = Arc::clone(&live);
                let peak = Arc::clone(&peak);
                pool.submit(move || {
                    let l = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(l, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    live.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for t in tickets {
            t.wait();
        }
        assert!(peak.load(Ordering::SeqCst) >= 2);
    }

    #[test]
    fn panicking_job_poisons_its_ticket_not_the_pool() {
        let pool = TrainPool::new(2);
        let bad = pool.submit(|| -> u32 { panic!("boom in worker") });
        let good = pool.submit(|| 7u32);
        // the panic is delivered at wait (loud), and only on that ticket
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| bad.wait()))
            .expect_err("panicking job must re-throw at wait");
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("boom in worker"), "panic payload lost: {msg:?}");
        // no deadlock, and the pool still services later jobs
        assert_eq!(good.wait(), 7);
        assert_eq!(pool.submit(|| 9u32).wait(), 9);
    }

    #[test]
    fn dropped_tickets_do_not_wedge_the_pool() {
        let pool = TrainPool::new(2);
        for i in 0..8 {
            let _ = pool.submit(move || i); // ticket dropped immediately
        }
        assert_eq!(pool.submit(|| 42).wait(), 42);
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..16)
            .map(|_| {
                let peak = &peak;
                let live = &live;
                move || {
                    let l = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(l, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    live.fetch_sub(1, Ordering::SeqCst);
                }
            })
            .collect();
        run_parallel(4, jobs);
        assert!(peak.load(Ordering::SeqCst) >= 2);
    }
}
