//! Scoped worker-pool substrate (tokio is unavailable offline; the
//! coordinator is round-synchronous so a work-stealing-free pool suffices).
//! Used to execute the per-participant local-training closures of one round
//! in parallel, mirroring the paper's time-multiplexed simulated learners.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `jobs` closures on up to `workers` threads; return results in the
/// original job order.
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let queue = Arc::new(Mutex::new(
        jobs.into_iter().enumerate().collect::<Vec<_>>(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            scope.spawn(move || loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((i, f)) => {
                        let r = f();
                        if tx.send((i, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("worker died")).collect()
    })
}

/// Number of worker threads to use by default.
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<_> = (0..64)
            .map(|i| move || i * 2)
            .collect();
        let out = run_parallel(8, jobs);
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_parallel(1, jobs), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_jobs() {
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(run_parallel(4, jobs).is_empty());
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..16)
            .map(|_| {
                let peak = &peak;
                let live = &live;
                move || {
                    let l = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(l, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    live.fetch_sub(1, Ordering::SeqCst);
                }
            })
            .collect();
        run_parallel(4, jobs);
        assert!(peak.load(Ordering::SeqCst) >= 2);
    }
}
