//! Statistics substrate: running means, percentiles, CDFs, EMA, linear
//! regression + R^2 (used by the availability forecaster evaluation and the
//! figure harness), and k-means (device-profile clustering, Fig. 13b).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population variance; 0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on the sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Empirical CDF evaluated at `points`: fraction of xs <= point.
pub fn ecdf(xs: &[f64], points: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    points
        .iter()
        .map(|p| {
            let idx = v.partition_point(|x| x <= p);
            idx as f64 / v.len().max(1) as f64
        })
        .collect()
}

/// Exponential moving average with smoothing `alpha` in (0, 1]:
/// new = (1 - alpha) * sample + alpha * old  (paper 4.1 APT convention:
/// mu_t = (1-alpha) D_{t-1} + alpha mu_{t-1}).
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    pub alpha: f64,
    pub value: f64,
    primed: bool,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: 0.0, primed: false }
    }

    pub fn update(&mut self, sample: f64) -> f64 {
        self.value = if self.primed {
            (1.0 - self.alpha) * sample + self.alpha * self.value
        } else {
            self.primed = true;
            sample
        };
        self.value
    }
}

/// Ordinary least squares y = a + b x. Returns (a, b).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(x);
    let my = mean(y);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for i in 0..x.len() {
        sxy += (x[i] - mx) * (y[i] - my);
        sxx += (x[i] - mx) * (x[i] - mx);
    }
    let b = if sxx.abs() < 1e-12 { 0.0 } else { sxy / sxx };
    let _ = n;
    (my - b * mx, b)
}

/// Coefficient of determination of predictions vs ground truth.
pub fn r_squared(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let m = mean(truth);
    let ss_tot: f64 = truth.iter().map(|t| (t - m).powi(2)).sum();
    let ss_res: f64 = truth
        .iter()
        .zip(pred)
        .map(|(t, p)| (t - p).powi(2))
        .sum();
    if ss_tot.abs() < 1e-12 {
        if ss_res.abs() < 1e-12 {
            1.0
        } else {
            0.0
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

pub fn mse(truth: &[f64], pred: &[f64]) -> f64 {
    mean(&truth
        .iter()
        .zip(pred)
        .map(|(t, p)| (t - p).powi(2))
        .collect::<Vec<_>>())
}

pub fn mae(truth: &[f64], pred: &[f64]) -> f64 {
    mean(&truth
        .iter()
        .zip(pred)
        .map(|(t, p)| (t - p).abs())
        .collect::<Vec<_>>())
}

/// 1-D k-means (Lloyd's) used to cluster device speeds (paper Fig. 13b).
/// Returns (centroids sorted ascending, assignment per point).
pub fn kmeans_1d(xs: &[f64], k: usize, iters: usize, seed: u64) -> (Vec<f64>, Vec<usize>) {
    assert!(k >= 1);
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut centroids: Vec<f64> = (0..k).map(|_| xs[rng.below(xs.len())]).collect();
    let mut assign = vec![0usize; xs.len()];
    for _ in 0..iters {
        for (i, x) in xs.iter().enumerate() {
            assign[i] = centroids
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    (x - *a).abs().total_cmp(&(x - *b).abs())
                })
                .map(|(j, _)| j)
                .unwrap();
        }
        for j in 0..k {
            let members: Vec<f64> = xs
                .iter()
                .zip(&assign)
                .filter(|(_, a)| **a == j)
                .map(|(x, _)| *x)
                .collect();
            if !members.is_empty() {
                centroids[j] = mean(&members);
            }
        }
    }
    // sort centroids and remap assignments
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| centroids[a].total_cmp(&centroids[b]));
    let mut rank = vec![0usize; k];
    for (r, &j) in order.iter().enumerate() {
        rank[j] = r;
    }
    let sorted: Vec<f64> = order.iter().map(|&j| centroids[j]).collect();
    for a in &mut assign {
        *a = rank[*a];
    }
    (sorted, assign)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(variance(&[5.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn ecdf_monotone() {
        let xs = [1.0, 2.0, 2.0, 5.0];
        let c = ecdf(&xs, &[0.0, 1.0, 2.0, 5.0, 9.0]);
        assert_eq!(c, vec![0.0, 0.25, 0.75, 1.0, 1.0]);
    }

    #[test]
    fn ema_matches_paper_rule() {
        // mu_t = (1-alpha) D_{t-1} + alpha mu_{t-1}, alpha = 0.25
        let mut e = Ema::new(0.25);
        assert_eq!(e.update(100.0), 100.0); // primes
        let v = e.update(200.0);
        assert!((v - (0.75 * 200.0 + 0.25 * 100.0)).abs() < 1e-12);
    }

    #[test]
    fn linreg_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b) = linreg(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn r2_perfect_and_mean_predictor() {
        let t = [1.0, 2.0, 3.0];
        assert!((r_squared(&t, &t) - 1.0).abs() < 1e-12);
        let m = [2.0, 2.0, 2.0];
        assert!(r_squared(&t, &m).abs() < 1e-12);
    }

    #[test]
    fn mse_mae() {
        let t = [1.0, 2.0];
        let p = [2.0, 0.0];
        assert!((mse(&t, &p) - 2.5).abs() < 1e-12);
        assert!((mae(&t, &p) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn kmeans_separates_clusters() {
        let mut xs = vec![];
        for i in 0..50 {
            xs.push(1.0 + (i % 5) as f64 * 0.01);
            xs.push(10.0 + (i % 5) as f64 * 0.01);
        }
        let (c, assign) = kmeans_1d(&xs, 2, 20, 3);
        assert!((c[0] - 1.02).abs() < 0.1, "{c:?}");
        assert!((c[1] - 10.02).abs() < 0.1, "{c:?}");
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(assign[i], if *x < 5.0 { 0 } else { 1 });
        }
    }
}
