//! Minimal JSON substrate (serde is unavailable offline): a recursive-descent
//! parser and a writer. Used for the artifact manifest written by
//! `python/compile/aot.py`, experiment configs, and metrics output.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated (we never emit them).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- writer ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors used by metrics/config writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, l: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(l.as_bytes()) {
            self.i += l.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{l}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(j.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn roundtrip_escapes() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn errors_report_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert_eq!(e.pos, 6);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn builders() {
        let j = obj(vec![("x", num(1.0)), ("y", arr(vec![s("a")]))]);
        assert_eq!(j.to_string(), r#"{"x":1,"y":["a"]}"#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[ ]").unwrap().to_string(), "[]");
    }
}
