//! Property-testing substrate (proptest is unavailable offline): a small
//! runner that draws cases from `Rng`, checks an invariant, and on failure
//! reports the seed + case index so the exact case replays deterministically.
//!
//! Usage:
//! ```ignore
//! prop_check(200, 0xFEED, |rng| {
//!     let n = rng.range(1, 100);
//!     let v = some_op(n);
//!     prop_assert(v >= n, format!("v={v} n={n}"))
//! });
//! ```

use crate::util::rng::Rng;

pub type PropResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `f`; panic with the replay seed on failure.
pub fn prop_check<F>(cases: usize, seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.stream(case as u64);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property failed at case {case} (replay: seed={seed:#x}, stream={case}): {msg}"
            );
        }
    }
}

/// Replay a single failing case (use the stream index from the panic).
pub fn prop_replay<F>(seed: u64, case: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let mut rng = Rng::new(seed).stream(case as u64);
    if let Err(msg) = f(&mut rng) {
        panic!("replayed failure (seed={seed:#x}, stream={case}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(50, 1, |rng| {
            count += 1;
            let a = rng.f64();
            prop_assert((0.0..1.0).contains(&a), "f64 out of range")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failing_property_reports_case() {
        prop_check(50, 2, |rng| {
            let n = rng.range(0, 10);
            prop_assert(n < 9, format!("n={n}"))
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first: Option<f64> = None;
        prop_replay(3, 7, |rng| {
            let v = rng.f64();
            match first {
                None => first = Some(v),
                Some(f) => assert_eq!(f, v),
            }
            Ok(())
        });
        prop_replay(3, 7, |rng| {
            assert_eq!(first.unwrap(), rng.f64());
            Ok(())
        });
    }
}
