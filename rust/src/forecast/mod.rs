//! Availability forecasting substrate (paper §4.1 + §5.2 "Learner
//! Availability Prediction Model").
//!
//! In RELAY each *learner* keeps a tiny local model of its own charging
//! pattern and, on check-in, reports P(available during the server's next
//! time slot [mu, 2mu]). The paper uses Prophet on the Stunner trace; we
//! build two from-scratch equivalents (DESIGN.md §2):
//!
//! * [`SeasonalForecaster`] — recency-weighted hour-of-week empirical
//!   frequency. This is what learners run inside the simulator: O(1)
//!   predict, incremental update.
//! * [`FourierRidge`] — "Prophet-lite": ridge regression on daily + weekly
//!   Fourier features with a linear trend, used by the §5.2 forecast-quality
//!   experiment (train on first 50% of a device's series, predict the rest,
//!   report R^2 / MSE / MAE).

use crate::trace::{DAY, WEEK};
use crate::util::lazy::LazySlots;
use crate::util::stats;

/// Recency-weighted hour-of-week availability frequency.
#[derive(Clone, Debug)]
pub struct SeasonalForecaster {
    /// 168 hour-of-week bins: (weighted avail, weight).
    bins: Vec<(f64, f64)>,
    /// Per-observation decay applied to old evidence (per week).
    decay: f64,
}

impl Default for SeasonalForecaster {
    fn default() -> Self {
        Self::new(0.8)
    }
}

impl SeasonalForecaster {
    pub fn new(weekly_decay: f64) -> Self {
        SeasonalForecaster { bins: vec![(0.0, 0.0); 168], decay: weekly_decay }
    }

    pub(crate) fn bin_of(t: f64) -> usize {
        ((t.rem_euclid(WEEK)) / 3600.0) as usize % 168
    }

    /// Record one observation: was the device available at time `t`?
    pub fn observe(&mut self, t: f64, available: bool) {
        let b = Self::bin_of(t);
        let (num, den) = &mut self.bins[b];
        *num = *num * self.decay + if available { 1.0 } else { 0.0 };
        *den = *den * self.decay + 1.0;
    }

    /// P(available at time t). 0.5 prior when a bin has no evidence.
    pub fn prob_at(&self, t: f64) -> f64 {
        let (num, den) = self.bins[Self::bin_of(t)];
        if den < 1e-9 {
            0.5
        } else {
            num / den
        }
    }

    /// Bootstrap-train on one sampled week of 0/1 availability (`step`
    /// seconds per sample), replaying it twice — the paper's "learners
    /// maintain a trace of their charging events" bootstrap (Appendix A).
    /// The coordinator's eager and lazy construction paths both come through
    /// here, so their forecasters are bit-identical.
    pub fn train_on_week(series: &[f64], step: f64) -> SeasonalForecaster {
        let mut f = SeasonalForecaster::default();
        for rep in 0..2 {
            for (i, &v) in series.iter().enumerate() {
                let t = rep as f64 * WEEK + i as f64 * step;
                f.observe(t, v > 0.5);
            }
        }
        f
    }

    /// P(available throughout the slot [a, b]) — mean of bin probabilities
    /// across the slot (the learner-side answer to the server's probe).
    pub fn prob_slot(&self, a: f64, b: f64) -> f64 {
        if b <= a {
            return self.prob_at(a);
        }
        let steps = slot_steps(a, b);
        let mut acc = 0.0;
        for i in 0..steps {
            acc += self.prob_at(slot_midpoint(a, b, i, steps));
        }
        acc / steps as f64
    }
}

/// Number of probe midpoints in the slot [a, b] (requires b > a). Shared by
/// [`SeasonalForecaster::prob_slot`] and [`slot_bins`] so the two can never
/// drift apart — the bitwise-equality lemma below depends on both reading
/// the exact same midpoints.
#[inline]
fn slot_steps(a: f64, b: f64) -> usize {
    ((b - a) / 1800.0).ceil().max(1.0) as usize
}

/// The `i`-th probe midpoint of the slot [a, b] (see [`slot_steps`]).
#[inline]
fn slot_midpoint(a: f64, b: f64, i: usize, steps: usize) -> f64 {
    a + (b - a) * (i as f64 + 0.5) / steps as f64
}

/// The hour-of-week bins the midpoints of `prob_slot(a, b)` land in — the
/// probe's piecewise-constant validity signature. A trained forecaster's
/// bins never change afterwards, so **two slots with equal `slot_bins`
/// produce bitwise-equal [`SeasonalForecaster::prob_slot`] answers for
/// every learner** (the sum runs over the same bin values in the same
/// order, divided by the same step count; both functions read the shared
/// `slot_steps`/`slot_midpoint` arithmetic). The selection-index subsystem
/// keys its per-time-bucket availability-probability trees on this.
pub fn slot_bins(a: f64, b: f64) -> Vec<u16> {
    if b <= a {
        return vec![SeasonalForecaster::bin_of(a) as u16];
    }
    let steps = slot_steps(a, b);
    (0..steps)
        .map(|i| SeasonalForecaster::bin_of(slot_midpoint(a, b, i, steps)) as u16)
        .collect()
}

/// A population of per-learner [`SeasonalForecaster`]s trained on demand
/// (at most once each, thread-safe). The coordinator probes only the
/// learners that actually check in, so at 100k+ populations the vast
/// majority of forecasters are never trained — constructing the bank is
/// O(n) empty slots instead of O(n) trace replays.
pub struct ForecasterBank {
    slots: LazySlots<SeasonalForecaster>,
}

impl ForecasterBank {
    pub fn new(n: usize) -> ForecasterBank {
        ForecasterBank { slots: LazySlots::new(n) }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The learner's forecaster, training it via `train` at first touch.
    pub fn get_or_train<F>(&self, learner: usize, train: F) -> &SeasonalForecaster
    where
        F: FnOnce() -> SeasonalForecaster,
    {
        self.slots.get_or_init(learner, train)
    }

    /// How many forecasters have been trained so far.
    pub fn trained(&self) -> usize {
        self.slots.initialized()
    }
}

/// Ridge regression on [trend, daily Fourier, weekly Fourier] features.
pub struct FourierRidge {
    k_daily: usize,
    k_weekly: usize,
    lambda: f64,
    weights: Vec<f64>,
}

impl FourierRidge {
    pub fn new(k_daily: usize, k_weekly: usize, lambda: f64) -> Self {
        FourierRidge { k_daily, k_weekly, lambda, weights: Vec::new() }
    }

    fn features(&self, t: f64) -> Vec<f64> {
        let mut f = Vec::with_capacity(2 + 2 * (self.k_daily + self.k_weekly));
        f.push(1.0);
        f.push(t / WEEK); // linear trend
        for k in 1..=self.k_daily {
            let w = 2.0 * std::f64::consts::PI * k as f64 * t / DAY;
            f.push(w.sin());
            f.push(w.cos());
        }
        for k in 1..=self.k_weekly {
            let w = 2.0 * std::f64::consts::PI * k as f64 * t / WEEK;
            f.push(w.sin());
            f.push(w.cos());
        }
        f
    }

    /// Fit on (times, values) via the normal equations.
    pub fn fit(&mut self, times: &[f64], values: &[f64]) {
        assert_eq!(times.len(), values.len());
        let d = self.features(0.0).len();
        let mut xtx = vec![0.0; d * d];
        let mut xty = vec![0.0; d];
        for (t, y) in times.iter().zip(values) {
            let f = self.features(*t);
            for i in 0..d {
                xty[i] += f[i] * y;
                for j in 0..d {
                    xtx[i * d + j] += f[i] * f[j];
                }
            }
        }
        for i in 0..d {
            xtx[i * d + i] += self.lambda;
        }
        self.weights = solve(&mut xtx, &mut xty, d);
    }

    pub fn predict(&self, t: f64) -> f64 {
        self.features(t)
            .iter()
            .zip(&self.weights)
            .map(|(f, w)| f * w)
            .sum()
    }

    /// Predict clamped to [0, 1] (charging state is binary).
    pub fn predict_prob(&self, t: f64) -> f64 {
        self.predict(t).clamp(0.0, 1.0)
    }
}

/// Gaussian elimination with partial pivoting on A x = b (A is d x d).
fn solve(a: &mut [f64], b: &mut [f64], d: usize) -> Vec<f64> {
    for col in 0..d {
        // pivot
        let mut piv = col;
        for r in col + 1..d {
            if a[r * d + col].abs() > a[piv * d + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for j in 0..d {
                a.swap(col * d + j, piv * d + j);
            }
            b.swap(col, piv);
        }
        let diag = a[col * d + col];
        if diag.abs() < 1e-12 {
            continue;
        }
        for r in col + 1..d {
            let factor = a[r * d + col] / diag;
            if factor == 0.0 {
                continue;
            }
            for j in col..d {
                a[r * d + j] -= factor * a[col * d + j];
            }
            b[r] -= factor * b[col];
        }
    }
    let mut x = vec![0.0; d];
    for col in (0..d).rev() {
        let mut acc = b[col];
        for j in col + 1..d {
            acc -= a[col * d + j] * x[j];
        }
        let diag = a[col * d + col];
        x[col] = if diag.abs() < 1e-12 { 0.0 } else { acc / diag };
    }
    x
}

/// §5.2 protocol: train on the first half of a sampled series, predict the
/// second half; returns (r2, mse, mae).
pub fn evaluate_series(times: &[f64], values: &[f64]) -> (f64, f64, f64) {
    let half = times.len() / 2;
    let mut model = FourierRidge::new(16, 4, 1e-3);
    model.fit(&times[..half], &values[..half]);
    let preds: Vec<f64> = times[half..].iter().map(|&t| model.predict_prob(t)).collect();
    let truth = &values[half..];
    (
        stats::r_squared(truth, &preds),
        stats::mse(truth, &preds),
        stats::mae(truth, &preds),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seasonal_learns_pattern() {
        let mut f = SeasonalForecaster::default();
        // device charges 22:00-02:00 every day for 3 weeks
        for day in 0..21 {
            for hour in 0..24 {
                let t = day as f64 * DAY + hour as f64 * 3600.0 + 10.0;
                let avail = !(2..22).contains(&hour);
                f.observe(t, avail);
            }
        }
        assert!(f.prob_at(23.0 * 3600.0) > 0.9);
        assert!(f.prob_at(12.0 * 3600.0) < 0.1);
        // slot spanning mostly-on hours
        assert!(f.prob_slot(22.0 * 3600.0, 24.0 * 3600.0) > 0.8);
    }

    #[test]
    fn seasonal_prior_is_half() {
        let f = SeasonalForecaster::default();
        assert_eq!(f.prob_at(0.0), 0.5);
    }

    #[test]
    fn seasonal_recency_weighting() {
        let mut f = SeasonalForecaster::new(0.5);
        let t = 5.0 * 3600.0;
        // old evidence says unavailable, new says available
        for w in 0..6 {
            f.observe(t + w as f64 * WEEK, false);
        }
        for w in 6..10 {
            f.observe(t + w as f64 * WEEK, true);
        }
        assert!(f.prob_at(t) > 0.8, "recent evidence should dominate");
    }

    #[test]
    fn train_on_week_matches_manual_replay() {
        // alternating on/off hours, one-week series at 30-min steps
        let step = 1800.0;
        let n = (WEEK / step) as usize;
        let series: Vec<f64> =
            (0..n).map(|i| if (i / 2) % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let trained = SeasonalForecaster::train_on_week(&series, step);
        let mut manual = SeasonalForecaster::default();
        for rep in 0..2 {
            for (i, &v) in series.iter().enumerate() {
                manual.observe(rep as f64 * WEEK + i as f64 * step, v > 0.5);
            }
        }
        for h in 0..168 {
            let t = h as f64 * 3600.0 + 1.0;
            assert_eq!(trained.prob_at(t), manual.prob_at(t), "hour {h}");
        }
    }

    #[test]
    fn equal_slot_bins_imply_equal_prob_slot() {
        // the contract the per-time-bucket probability trees rest on: any
        // two (a, b) slots with identical bin signatures get bitwise-equal
        // prob_slot answers from any trained forecaster
        let step = 1800.0;
        let n = (WEEK / step) as usize;
        let series: Vec<f64> =
            (0..n).map(|i| if (i / 3) % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let f = SeasonalForecaster::train_on_week(&series, step);
        let mut pairs: Vec<(f64, f64)> = Vec::new();
        for i in 0..400 {
            let a = i as f64 * 137.3;
            pairs.push((a, a + 95.0)); // single-midpoint slots
            pairs.push((a, a + 4321.0)); // multi-step slots
        }
        for (i, &(a1, b1)) in pairs.iter().enumerate() {
            for &(a2, b2) in pairs.iter().skip(i + 1) {
                if slot_bins(a1, b1) == slot_bins(a2, b2) {
                    assert_eq!(
                        f.prob_slot(a1, b1).to_bits(),
                        f.prob_slot(a2, b2).to_bits(),
                        "slots ({a1},{b1}) vs ({a2},{b2})"
                    );
                }
            }
        }
        // degenerate slot falls back to the single start bin
        assert_eq!(slot_bins(10.0, 10.0).len(), 1);
    }

    #[test]
    fn bank_trains_each_learner_at_most_once() {
        let step = 1800.0;
        let n = (WEEK / step) as usize;
        let series: Vec<f64> = (0..n).map(|i| (i % 3 == 0) as u8 as f64).collect();
        let bank = ForecasterBank::new(3);
        assert_eq!(bank.len(), 3);
        assert_eq!(bank.trained(), 0);
        let p1 = bank.get_or_train(1, || SeasonalForecaster::train_on_week(&series, step))
            as *const SeasonalForecaster;
        assert_eq!(bank.trained(), 1);
        let p2 = bank.get_or_train(1, || panic!("must not retrain a cached forecaster"))
            as *const SeasonalForecaster;
        assert_eq!(p1, p2, "second touch must return the cached forecaster");
        assert_eq!(bank.trained(), 1);
    }

    #[test]
    fn solver_exact_small_system() {
        // [2 1; 1 3] x = [5; 10] => x = [1, 3]... check: 2*1+3=5 ok; 1+9=10 ok
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = solve(&mut a, &mut b, 2);
        assert!((x[0] - 1.0).abs() < 1e-10);
        assert!((x[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn fourier_fits_sinusoid() {
        let times: Vec<f64> = (0..500).map(|i| i as f64 * WEEK / 500.0).collect();
        let vals: Vec<f64> = times
            .iter()
            .map(|&t| 0.5 + 0.4 * (2.0 * std::f64::consts::PI * t / DAY).sin())
            .collect();
        let mut m = FourierRidge::new(3, 2, 1e-6);
        m.fit(&times, &vals);
        for (&t, &v) in times.iter().zip(&vals).step_by(37) {
            assert!((m.predict(t) - v).abs() < 0.01, "t={t}");
        }
    }

    #[test]
    fn evaluate_series_high_r2_on_periodic_signal() {
        // strongly periodic charging pattern -> forecaster should hit the
        // paper's quality band (R^2 ~ 0.9)
        let step = 900.0;
        let n = (4.0 * WEEK / step) as usize;
        let times: Vec<f64> = (0..n).map(|i| i as f64 * step).collect();
        let vals: Vec<f64> = times
            .iter()
            .map(|&t| {
                let h = (t.rem_euclid(DAY)) / 3600.0;
                if !(6.0..22.0).contains(&h) {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        let (r2, mse, mae) = evaluate_series(&times, &vals);
        assert!(r2 > 0.75, "r2={r2}");
        assert!(mse < 0.08, "mse={mse}");
        assert!(mae < 0.2, "mae={mae}");
    }
}
