//! Semi-centralized baseline (paper §E.2, Table 2): the dataset is split
//! among exactly 10 learners who all participate fully in every round —
//! conventional data-parallel training. Establishes the quality ceiling the
//! FL configurations are measured against.

use std::sync::Arc;

use anyhow::Result;

use crate::aggregation::saa::{merge, UpdateEntry};
use crate::aggregation::scaling::ScalingRule;
use crate::config::ExpConfig;
use crate::coordinator::engine::evaluate_params;
use crate::data::partition::Partitioner;
use crate::data::synth::Dataset;
use crate::runtime::Executor;
use crate::util::rng::Rng;

/// Result of one semi-centralized run.
#[derive(Clone, Debug)]
pub struct CentralizedResult {
    pub final_accuracy: f64,
    pub final_loss: f64,
    pub accuracy_per_round: Vec<f64>,
}

/// Train `rounds` of full-participation FedAvg/YoGi over 10 learners.
pub fn run_centralized(
    cfg: &ExpConfig,
    exec: Arc<dyn Executor>,
    rounds: usize,
) -> Result<CentralizedResult> {
    let info = exec.variant().clone();
    let dataset = Dataset::new(&info, cfg.seed ^ 0xD5);
    let n_learners = 10;
    let partitioner = Partitioner::new(cfg.partition, info.num_classes, cfg.mean_samples);
    let shards = partitioner.assign(n_learners, cfg.seed ^ 0x9A);
    let test = dataset.test_set(cfg.test_per_class);
    let mut server_opt = crate::aggregation::by_name(&cfg.server_opt).unwrap();
    let mut global = exec.init_params(cfg.seed as i32)?;
    let mut accs = Vec::with_capacity(rounds);
    let mut final_loss = f64::NAN;
    let v = exec.variant().clone();

    for round in 0..rounds {
        let mut updates = Vec::with_capacity(n_learners);
        for (learner, shard) in shards.iter().enumerate() {
            let mut params = global.clone();
            let mut rng = Rng::new(cfg.seed ^ round as u64).stream(learner as u64);
            let mut order: Vec<usize> = (0..shard.len()).collect();
            for _ in 0..cfg.local_epochs.max(1) {
                rng.shuffle(&mut order);
                for chunk in order.chunks(v.batch) {
                    let (b, d) = (v.batch, v.input_dim);
                    let mut x = vec![0f32; b * d];
                    let mut y = vec![0i32; b];
                    let mut mask = vec![0f32; b];
                    for (row, &si) in chunk.iter().enumerate() {
                        let label = shard.labels[si] as usize;
                        let f = dataset.features(learner as u64, si as u64, label);
                        x[row * d..(row + 1) * d].copy_from_slice(&f);
                        y[row] = label as i32;
                        mask[row] = 1.0;
                    }
                    let out = exec.train_step(&params, &x, &y, &mask, cfg.lr)?;
                    params = out.params;
                }
            }
            updates.push(UpdateEntry {
                learner,
                delta: params.iter().zip(&global).map(|(p, g)| p - g).collect(),
                origin_round: round,
            });
        }
        let merged = merge(exec.as_ref(), &updates, &[], ScalingRule::Equal, round)?;
        server_opt.apply(&mut global, &merged.delta)?;
        let (loss, acc) = evaluate_params(exec.as_ref(), &test, &global)?;
        accs.push(acc);
        final_loss = loss;
    }

    Ok(CentralizedResult {
        final_accuracy: *accs.last().unwrap_or(&0.0),
        final_loss,
        accuracy_per_round: accs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{builtin_variant, NativeExecutor};

    #[test]
    fn centralized_converges_on_tiny() {
        let cfg = ExpConfig {
            variant: "tiny".into(),
            mean_samples: 30,
            test_per_class: 10,
            lr: 0.1,
            ..Default::default()
        };
        let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new(builtin_variant("tiny")));
        let r = run_centralized(&cfg, exec, 30).unwrap();
        assert!(
            r.final_accuracy > 0.6,
            "centralized tiny should learn well, got {}",
            r.final_accuracy
        );
        // quality should broadly improve over training
        let early = r.accuracy_per_round[2];
        assert!(r.final_accuracy >= early);
    }

    #[test]
    fn label_limited_is_harder_than_iid() {
        use crate::data::partition::{LabelSkew, PartitionScheme};
        let exec: Arc<dyn Executor> = Arc::new(NativeExecutor::new(builtin_variant("tiny")));
        let mk = |p: PartitionScheme| {
            let cfg = ExpConfig {
                variant: "tiny".into(),
                mean_samples: 30,
                test_per_class: 10,
                lr: 0.1,
                partition: p,
                ..Default::default()
            };
            run_centralized(&cfg, exec.clone(), 25).unwrap().final_accuracy
        };
        let iid = mk(PartitionScheme::UniformIid);
        let skew = mk(PartitionScheme::LabelLimited { labels: 2, skew: LabelSkew::Zipf });
        // with 10 fully-participating learners the gap is small but zipf
        // label-limiting should not *beat* iid
        assert!(skew <= iid + 0.1, "iid {iid} vs zipf {skew}");
    }
}
