//! The buffered-asynchronous round regime (`RoundMode::Async`) — FedBuff-
//! style aggregation on the discrete-event kernel.
//!
//! Where the OC/DL regimes sweep the kernel one round window at a time,
//! this driver pops events one by one:
//!
//! * **check-in / departure-triggered selection** — the server keeps up to
//!   `target_participants` tasks in flight; every completion or dropout
//!   immediately re-triggers selection for the freed slot, so "straggler"
//!   stops being a special case (there is no round to straggle past);
//! * **task completions** deliver updates into a server-side buffer; every
//!   `buffer_k` arrivals the buffer is merged with the paper's Eq.-2
//!   staleness weights (`aggregation::saa::merge_buffer`), advancing the
//!   model version;
//! * **staleness bound** — updates older than `max_staleness` versions are
//!   discarded (and waste-accounted) instead of merged; `None` keeps every
//!   arrival, the RELAY default;
//! * **per-event accounting** — every device-second is tracked through
//!   exactly one of three buckets: aggregated, wasted, or still in flight
//!   (`tests/substrate_props.rs` asserts the three always sum to spent).
//!
//! The deterministic fault model (`scenario::faults`) threads through the
//! same life-cycle points as in the sync engines: flaps skip the spawn,
//! crashes flow through the Dropout event, transit delays push the Arrival
//! past the task end, corrupted updates are rejected by validation on
//! arrival, duplicates are deduped at no cost. Crashed and corrupted
//! devices are additionally **quarantined** for a cooldown: fault
//! decisions are keyed on (learner, version), so without the quarantine a
//! flagged device could respawn-and-fail forever at a stuck version.
//! Every fault lands in the usual waste buckets, so the accounting
//! identity below is unchanged.
//!
//! One `RoundRecord` is emitted per merge ("version"), so downstream
//! metrics/figures treat async cells exactly like OC/DL cells. When nothing
//! is in flight and nobody checks in, a failed round slot is burned —
//! mirroring the synchronous engine's aborted round — which also lets
//! version-denominated cooldowns expire. APT does not apply here (there is
//! no round-synchronous target to shrink); the round-duration EMA is still
//! maintained as the forecaster slot/burn-cadence estimate.
//!
//! Scale: per-departure re-selection draws from the population substrate's
//! incrementally-maintained eligible set (`population::Population`) instead
//! of re-running a full `checked_in` scan — availability transitions arrive
//! as index events, busy/cooldown membership is updated at the spawn /
//! arrival / dropout / merge points below, and every eligible-set delta is
//! forwarded to the selector's `on_eligible`/`on_ineligible` hooks so
//! **indexed selectors** (Random via `CandidateSet::sample_k`; Oort and
//! IPS/priority via the `selection::index` score trees; SAFA by streaming
//! the set) select in O(k log n) per fill without ever materializing the
//! pool. The per-event cost is therefore independent of `total_learners`
//! (sub-linear end to end; `relay bench --suite selection` and
//! `cargo bench selection/...` track it), which is what makes
//! million-learner async cells run in seconds. Every indexed path is
//! bit-compatible with materialize-and-select, so results are unchanged.

use anyhow::{anyhow, Result};

use crate::aggregation::saa::{merge_buffer, UpdateEntry};
use crate::config::RoundMode;
use crate::metrics::{ExperimentResult, RoundRecord};
use crate::runlog::RunEvent;
use crate::scenario::faults::FaultKind;
use crate::selection::{SelectPool, SelectionCtx};
use crate::sim::EventClass;

use super::engine::{
    AsyncDrop, AsyncTask, BufferedUpdate, Coordinator, EngineEvent, TaskPayload,
};

/// Mutable state of one async run, threaded through the event handlers.
struct AsyncState {
    buffer_k: usize,
    max_staleness: Option<usize>,
    /// Server model version == merge slots completed so far (burns
    /// included): the RoundRecord index and the loop-termination counter.
    version: usize,
    /// Tasks currently running on devices.
    in_flight: usize,
    /// Device-seconds spent but not yet aggregated or wasted (running tasks
    /// plus buffered, unmerged updates).
    in_flight_secs: f64,
    /// Arrived (and resolved) updates awaiting the next merge.
    buffer: Vec<BufferedUpdate>,
    // ---- per-version (inter-merge interval) statistics -------------------
    selected: usize,
    dropouts: usize,
    discarded: usize,
    /// Injected fault events observed during the interval.
    faults: usize,
    events: usize,
    interval_start: f64,
    /// Time-integral of `in_flight` over the interval (for mean concurrency).
    conc_area: f64,
    conc_last_t: f64,
}

impl AsyncState {
    fn reset_interval(&mut self, at: f64) {
        self.interval_start = at;
        self.conc_area = 0.0;
        self.conc_last_t = at;
        self.selected = 0;
        self.dropouts = 0;
        self.discarded = 0;
        self.faults = 0;
        self.events = 0;
    }
}

impl Coordinator {
    /// Run the buffered-async regime to `cfg.rounds` merges.
    pub(crate) fn run_async(&mut self, result: &mut ExperimentResult) -> Result<()> {
        let RoundMode::Async { buffer_k, max_staleness } = self.cfg.mode else {
            return Err(anyhow!("run_async requires RoundMode::Async"));
        };
        let mut st = AsyncState {
            buffer_k,
            max_staleness,
            version: 0,
            in_flight: 0,
            in_flight_secs: 0.0,
            buffer: Vec::new(),
            selected: 0,
            dropouts: 0,
            discarded: 0,
            faults: 0,
            events: 0,
            interval_start: 0.0,
            conc_area: 0.0,
            conc_last_t: 0.0,
        };
        self.kernel.schedule(0.0, EventClass::CheckIn, EngineEvent::CheckIn);
        while st.version < self.cfg.rounds {
            let Some(ev) = self.kernel.pop_next() else {
                // drained with nothing in flight: retry selection now
                let now = self.kernel.now();
                self.kernel.schedule(now, EventClass::CheckIn, EngineEvent::CheckIn);
                continue;
            };
            let now = ev.at;
            st.events += 1;
            st.conc_area += st.in_flight as f64 * (now - st.conc_last_t);
            st.conc_last_t = now;
            let class = ev.class.code();
            self.runlog.emit(|| RunEvent::KernelPop { at: now, class });
            match ev.payload {
                EngineEvent::CheckIn => {
                    let spawned = self.async_fill(&mut st)?;
                    if spawned == 0 && st.in_flight == 0 {
                        // nobody available, nothing in flight: burn a failed
                        // round slot (the sync engine's aborted round); this
                        // advances time and versions so availability windows
                        // and cooldowns can expire
                        self.async_burn_failed(&mut st, result);
                    }
                }
                EngineEvent::Arrival(task) => {
                    st.in_flight -= 1;
                    // the device is free again as of this instant (whether
                    // the update merges, buffers, or is discarded)
                    self.population
                        .release(task.learner, st.version, now, self.selector.as_mut());
                    self.async_arrival(task, &mut st, result)?;
                    // don't refill after the final merge: newly spawned
                    // tasks could never merge — they'd only burn real SGD
                    // compute and inflate the waste accounting
                    if st.version < self.cfg.rounds {
                        self.async_fill(&mut st)?;
                    }
                }
                EngineEvent::Dropout(d) => {
                    st.in_flight -= 1;
                    st.in_flight_secs -= d.spent;
                    st.dropouts += 1;
                    self.accounting.waste(d.spent);
                    let (learner, spent) = (d.learner as u64, d.spent);
                    self.runlog.emit(|| RunEvent::AsyncDropout { learner, spent });
                    // free again; still eligible iff its session hasn't
                    // actually ended yet (the index decides)
                    self.population
                        .release(d.learner, st.version, now, self.selector.as_mut());
                    if d.crashed {
                        // fault injection: quarantine the crashed device for
                        // a normal cooldown — without it, the (learner,
                        // version)-keyed crash decision would respawn-and-
                        // crash the same device forever at a stuck version
                        self.population.begin_cooldown(
                            d.learner,
                            st.version + 1 + self.cfg.cooldown_rounds,
                            self.selector.as_mut(),
                        );
                    }
                    self.selector.on_departure(st.version, d.learner, self.apt.mu());
                    self.async_fill(&mut st)?;
                }
                EngineEvent::StaleDelivery(_) => {
                    unreachable!("async runs never schedule sync stale deliveries")
                }
            }
            if st.version < self.cfg.rounds && st.in_flight == 0 && self.kernel.is_empty() {
                // keep the loop alive: nothing left to pop, so re-enter
                // selection (which burns a failed slot if nobody shows up)
                let now = self.kernel.now();
                self.kernel.schedule(now, EventClass::CheckIn, EngineEvent::CheckIn);
            }
        }
        // still-running tasks and unmerged buffer entries never made it in.
        // Logged before the waste call: replay mirrors the in-flight
        // arithmetic op for op and cross-checks this value bit-for-bit.
        let leftover = st.in_flight_secs;
        self.runlog.emit(|| RunEvent::SweepLeftover { secs: leftover });
        self.accounting.waste(st.in_flight_secs);
        if let Some(last) = result.rounds.last_mut() {
            last.cum_waste_secs = self.accounting.cum_waste_secs;
            last.in_flight_secs = Some(0.0);
        }
        self.runlog.emit(|| RunEvent::RunEnd);
        Ok(())
    }

    /// Top up the in-flight pool to `target_participants`: per-departure
    /// re-selection against the incrementally-maintained eligible set.
    /// Returns how many tasks were actually spawned.
    fn async_fill(&mut self, st: &mut AsyncState) -> Result<usize> {
        let target = self.cfg.target_participants;
        if st.in_flight >= target {
            return Ok(0);
        }
        let now = self.kernel.now();
        let mu = self.apt.mu();
        // bring the eligible set up to (version, now): availability flips
        // from the index, cooldown/busy-bucket expiries from merges/burns
        self.population.sync_to(st.version, now, self.selector.as_mut());
        if self.runlog.enabled() {
            let count = self.population.eligible_set().len() as u64;
            self.runlog.emit(|| RunEvent::Eligibility { count });
        }
        let need = target - st.in_flight;
        let sampled = {
            let pool = SelectPool {
                set: self.population.eligible_set(),
                probes: &self.population,
                mu,
            };
            self.selector.select_from(&pool, st.version, now, need, &mut self.rng)
        };
        let mut selected = match sampled {
            // indexed selector: O(need log n), never materializes the pool
            Some(ids) => ids,
            // un-indexed selector: materialize the eligible ids only
            None => {
                let candidates = self.population.pool_candidates(now, mu);
                if candidates.is_empty() {
                    return Ok(0);
                }
                let mut ctx = SelectionCtx {
                    round: st.version,
                    now,
                    target: need,
                    candidates: &candidates,
                    rng: &mut self.rng,
                };
                self.selector.select(&mut ctx)
            }
        };
        // SAFA-style selectors return the whole pool; async concurrency is
        // capped at the target either way
        selected.truncate(need);
        let faults = self.cfg.faults;
        // timing + dropout classification first (mirrors the sync engine);
        // (id, task_secs, dropped_after, crashed-by-fault)
        let mut plans: Vec<(usize, f64, Option<f64>, bool)> =
            Vec::with_capacity(selected.len());
        for &id in &selected {
            if faults.flaps(id, st.version) {
                // fault injection: check-in flap — the slot is lost before
                // the task ever starts. Counted in selected + dropouts like
                // the sync engines, and quarantined like crash/corrupt: the
                // (learner, version)-keyed decision would otherwise re-fire
                // on every refill at a stuck version, inflating the
                // counters and starving the slot.
                self.population.begin_cooldown(
                    id,
                    st.version + 1 + self.cfg.cooldown_rounds,
                    self.selector.as_mut(),
                );
                st.selected += 1;
                st.dropouts += 1;
                st.faults += 1;
                let (learner, ver) = (id as u64, st.version as u64);
                self.runlog.emit(|| RunEvent::FaultDecision {
                    kind: FaultKind::Flap.code(),
                    learner,
                    round: ver,
                });
                continue;
            }
            let n_samples = self.shards[id].len();
            let t = self
                .population
                .profile(id)
                .completion_time(n_samples, self.cfg.local_epochs, self.model_bytes);
            let avail = self.population.availability();
            let mut dropped = if avail.available_through(id, now, t) {
                None
            } else {
                // drops out at (approximately) the end of its current session
                let mut lo = 0.0f64;
                let mut hi = t;
                for _ in 0..20 {
                    let mid = 0.5 * (lo + hi);
                    if avail.available_through(id, now, mid) {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                Some(lo)
            };
            let mut crashed = false;
            if dropped.is_none() {
                if let Some(frac) = faults.crashes(id, st.version) {
                    // fault injection: mid-task crash — flows through the
                    // Dropout event like a trace departure (plus quarantine)
                    st.faults += 1;
                    dropped = Some(frac * t);
                    crashed = true;
                    let (learner, ver) = (id as u64, st.version as u64);
                    self.runlog.emit(|| RunEvent::FaultDecision {
                        kind: FaultKind::Crash.code(),
                        learner,
                        round: ver,
                    });
                }
            }
            plans.push((id, t, dropped, crashed));
        }
        // Train against a snapshot of the current global model: the async
        // regime's defining property is that this snapshot ages (by whole
        // model versions) while the device computes. The global only
        // mutates at merges, so the snapshot equals what inline training
        // would see. Jobs are *submitted* to the persistent train pool now
        // but their outcomes are only *committed* when each task's Arrival
        // event pops — a fixed, kernel-ordered reduction order — so results
        // are byte-identical at any pool width while training overlaps
        // event scheduling and later fills. Corrupted tasks skip the real
        // SGD: validation rejects them on arrival, so the model never sees
        // their delta.
        let train_ids: Vec<usize> = plans
            .iter()
            .filter(|&&(id, _, d, _)| d.is_none() && !faults.corrupts(id, st.version))
            .map(|&(id, _, _, _)| id)
            .collect();
        let mut tickets = self.submit_training(&train_ids).into_iter();
        let mut spawned = 0usize;
        for (id, t, dropped, crashed) in plans {
            match dropped {
                Some(dt) if dt <= 0.0 => {
                    // availability boundary: the learner cannot even start.
                    // Spawning a zero-length task would loop at this instant
                    // forever (drop -> reselect -> drop); skip it, time
                    // advances via other events or a burned slot.
                    continue;
                }
                Some(dt) => {
                    // partial work until the session (or the device) dies;
                    // wasted at departure
                    self.accounting.spend(id, dt);
                    st.in_flight_secs += dt;
                    self.population.mark_busy(id, now + dt, self.selector.as_mut());
                    self.kernel.schedule(
                        now + dt,
                        EventClass::Departure,
                        EngineEvent::Dropout(AsyncDrop { learner: id, spent: dt, crashed }),
                    );
                    let learner = id as u64;
                    self.runlog.emit(|| RunEvent::AsyncSpawn {
                        learner,
                        duration: t,
                        dropped_after: Some(dt),
                    });
                }
                None => {
                    // fault injection: in-transit delay pushes the arrival
                    // past the task end (the device stays reserved for the
                    // upload, so no second task can overlap it)
                    let deliver = match faults.delays(id, st.version) {
                        Some(d) => {
                            st.faults += 1;
                            let (learner, ver) = (id as u64, st.version as u64);
                            self.runlog.emit(|| RunEvent::FaultDecision {
                                kind: FaultKind::Delay.code(),
                                learner,
                                round: ver,
                            });
                            now + t + d
                        }
                        None => now + t,
                    };
                    let task = if faults.corrupts(id, st.version) {
                        // fault injection: corrupted at source — rejected by
                        // validation on arrival; no SGD was run, the empty
                        // delta is never read
                        st.faults += 1;
                        let (learner, ver) = (id as u64, st.version as u64);
                        self.runlog.emit(|| RunEvent::FaultDecision {
                            kind: FaultKind::Corrupt.code(),
                            learner,
                            round: ver,
                        });
                        AsyncTask {
                            learner: id,
                            payload: TaskPayload::Corrupt,
                            origin_version: st.version,
                            duration: t,
                        }
                    } else {
                        AsyncTask {
                            learner: id,
                            payload: TaskPayload::Pending(
                                tickets.next().expect("one training ticket per trained plan"),
                            ),
                            origin_version: st.version,
                            duration: t,
                        }
                    };
                    self.accounting.spend(id, t);
                    st.in_flight_secs += t;
                    self.population.mark_busy(id, deliver, self.selector.as_mut());
                    self.kernel.schedule(
                        deliver,
                        EventClass::Delivery,
                        EngineEvent::Arrival(task),
                    );
                    let learner = id as u64;
                    self.runlog.emit(|| RunEvent::AsyncSpawn {
                        learner,
                        duration: t,
                        dropped_after: None,
                    });
                }
            }
            st.in_flight += 1;
            st.selected += 1;
            spawned += 1;
        }
        Ok(spawned)
    }

    /// One update arrived: per-arrival selector feedback, staleness gate,
    /// buffer insert, and a merge whenever `buffer_k` updates are waiting.
    fn async_arrival(
        &mut self,
        task: AsyncTask,
        st: &mut AsyncState,
        result: &mut ExperimentResult,
    ) -> Result<()> {
        let AsyncTask { learner: id, payload, origin_version, duration } = task;
        // Commit point: the training ticket is waited on HERE, as the
        // arrival event is processed — deterministic kernel order, never
        // worker completion order. A corrupt task never ran SGD; its empty
        // delta is rejected below without the model ever seeing it.
        let (corrupt, delta, mean_loss, stat_util) = match payload {
            TaskPayload::Corrupt => (true, Vec::new(), 0.0, 0.0),
            TaskPayload::Pending(t) => {
                let o = t.wait()?;
                (false, o.delta, o.mean_loss, o.stat_util)
            }
        };
        if self.runlog.enabled() {
            let (learner, origin_v) = (id as u64, origin_version as u64);
            // a duplicate decision is logged before its delivery: the
            // delivery that fills the buffer must be immediately followed by
            // the MergeCommit in the event stream (replay enforces this)
            if !corrupt && self.cfg.faults.duplicates(id, origin_version) {
                self.runlog.emit(|| RunEvent::FaultDecision {
                    kind: FaultKind::Duplicate.code(),
                    learner,
                    round: origin_v,
                });
            }
            self.runlog.emit(|| RunEvent::AsyncDelivery {
                learner,
                origin_version: origin_v,
                duration,
                mean_loss,
                corrupt,
            });
        }
        if corrupt {
            // fault injection: server-side validation rejects the corrupted
            // update — missed feedback, no completion credit, and a
            // quarantine cooldown: the (learner, version)-keyed corrupt
            // decision would otherwise respawn-and-reject the same device
            // forever at a stuck version
            self.population.begin_cooldown(
                id,
                st.version + 1 + self.cfg.cooldown_rounds,
                self.selector.as_mut(),
            );
            self.selector.on_departure(st.version, id, self.apt.mu());
            self.async_discard(st, duration);
            return Ok(());
        }
        if self.cfg.faults.duplicates(id, origin_version) {
            // fault injection: the delivery arrived twice; the server
            // dedupes the copy at no cost
            st.faults += 1;
        }
        let tau = st.version - origin_version;
        let within = st.max_staleness.map(|th| tau <= th).unwrap_or(true);
        if !within {
            // beyond the staleness bound on arrival: discarded outright.
            // Mirror the sync engine's discard branch — missed feedback
            // (Oort dampening), no completion credit, no cooldown — so the
            // staleness bound doesn't end up *rewarding* the stalest devices
            self.selector.on_departure(st.version, id, self.apt.mu());
            self.async_discard(st, duration);
            return Ok(());
        }
        self.selector
            .on_arrival(st.version, (id, stat_util, duration), self.apt.mu());
        self.population.begin_cooldown(
            id,
            st.version + 1 + self.cfg.cooldown_rounds,
            self.selector.as_mut(),
        );
        st.buffer.push(BufferedUpdate { learner: id, delta, mean_loss, origin_version, duration });
        if st.buffer.len() >= st.buffer_k {
            self.async_merge(st, result)?;
        }
        Ok(())
    }

    /// Merge the buffered updates (Eq.-2 staleness weights), advance the
    /// model version, and emit this version's RoundRecord.
    fn async_merge(
        &mut self,
        st: &mut AsyncState,
        result: &mut ExperimentResult,
    ) -> Result<()> {
        let end = self.kernel.now();
        let entries = std::mem::take(&mut st.buffer);
        // re-check staleness at merge time: burned (failed) slots may have
        // advanced the version while an entry sat in the buffer
        let mut keep: Vec<BufferedUpdate> = Vec::new();
        for e in entries {
            let tau = st.version - e.origin_version;
            if st.max_staleness.map(|th| tau <= th).unwrap_or(true) {
                keep.push(e);
            } else {
                self.async_discard(st, e.duration);
            }
        }
        let fresh = keep.iter().filter(|e| e.origin_version == st.version).count();
        let stale = keep.len() - fresh;
        let failed = keep.is_empty();
        // None (-> JSON null) when nothing merged, matching the sync
        // engines' nothing-trained rounds
        let train_loss = if keep.is_empty() {
            None
        } else {
            Some(keep.iter().map(|e| e.mean_loss).sum::<f64>() / keep.len() as f64)
        };
        let mut updates: Vec<UpdateEntry> = Vec::with_capacity(keep.len());
        for e in keep {
            self.accounting.aggregate(e.duration);
            st.in_flight_secs -= e.duration;
            updates.push(UpdateEntry {
                learner: e.learner,
                delta: e.delta,
                origin_round: e.origin_version,
            });
        }
        if !updates.is_empty() {
            let outcome =
                merge_buffer(self.exec.as_ref(), updates, self.cfg.scaling, st.version)?;
            self.server_opt.apply(&mut self.global, &outcome.delta)?;
        }
        let interval = end - st.interval_start;
        self.apt.observe_round(interval);
        let mut rec = self.async_record(st, end, failed, fresh, stale, train_loss);
        st.version += 1;
        // evaluation cadence mirrors the sync engine (version == round + 1)
        let eval = if st.version % self.cfg.eval_every == 0 || st.version == self.cfg.rounds {
            Some(self.evaluate()?)
        } else {
            None
        };
        self.runlog.emit(|| RunEvent::MergeCommit { eval });
        if let Some((loss, acc)) = eval {
            rec.test_loss = Some(loss);
            rec.test_accuracy = Some(acc);
        }
        result.rounds.push(rec);
        st.reset_interval(end);
        Ok(())
    }

    /// Discard one spent-but-unmergeable update: the single source of the
    /// waste / in-flight / discarded triple, so the
    /// `spent == aggregated + wasted + in-flight` identity (asserted by
    /// tests/substrate_props.rs) cannot drift between discard sites.
    fn async_discard(&mut self, st: &mut AsyncState, duration: f64) {
        self.accounting.waste(duration);
        st.in_flight_secs -= duration;
        st.discarded += 1;
    }

    /// Nobody available and nothing in flight: burn a failed round slot of
    /// one round-duration estimate, exactly like the sync engine's aborted
    /// round. Advancing the version lets cooldowns expire.
    fn async_burn_failed(&mut self, st: &mut AsyncState, result: &mut ExperimentResult) {
        let dur = self.apt.mu().max(1.0);
        let end = self.kernel.now() + dur;
        // in_flight == 0 here, so the concurrency integral gains nothing
        st.conc_last_t = end;
        self.kernel.advance_to(end);
        self.apt.observe_round(dur);
        self.runlog.emit(|| RunEvent::AsyncBurn { end });
        let rec = self.async_record(st, end, true, 0, 0, None);
        result.rounds.push(rec);
        st.version += 1;
        st.reset_interval(end);
        if st.version < self.cfg.rounds {
            self.kernel.schedule(end, EventClass::CheckIn, EngineEvent::CheckIn);
        }
    }

    /// Assemble this version's RoundRecord from the interval statistics.
    fn async_record(
        &self,
        st: &AsyncState,
        end: f64,
        failed: bool,
        fresh: usize,
        stale: usize,
        train_loss: Option<f64>,
    ) -> RoundRecord {
        let interval = end - st.interval_start;
        let mean_conc = if interval > 0.0 {
            st.conc_area / interval
        } else {
            st.in_flight as f64
        };
        RoundRecord {
            round: st.version,
            sim_time: end,
            round_duration: interval,
            selected: st.selected,
            fresh_updates: fresh,
            stale_updates: stale,
            dropouts: st.dropouts,
            discarded: st.discarded,
            faults: st.faults,
            cum_resource_secs: self.accounting.cum_resource_secs,
            cum_waste_secs: self.accounting.cum_waste_secs,
            unique_participants: self.accounting.unique_participants(),
            failed,
            train_loss,
            mean_concurrency: Some(mean_conc),
            cum_aggregated_secs: Some(self.accounting.cum_aggregated_secs),
            in_flight_secs: Some(st.in_flight_secs),
            kernel_events: Some(st.events),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use crate::config::{AvailMode, ExpConfig, RoundMode};
    use crate::coordinator::run_experiment;
    use crate::runtime::{builtin_variant, Executor, NativeExecutor};

    fn exec() -> Arc<dyn Executor> {
        Arc::new(NativeExecutor::new(builtin_variant("tiny")))
    }

    fn async_cfg() -> ExpConfig {
        ExpConfig {
            variant: "tiny".into(),
            total_learners: 16,
            rounds: 6,
            target_participants: 3,
            mode: RoundMode::Async { buffer_k: 3, max_staleness: Some(4) },
            avail: AvailMode::AllAvail,
            mean_samples: 8,
            test_per_class: 4,
            eval_every: 2,
            cooldown_rounds: 1,
            lr: 0.1,
            ..Default::default()
        }
    }

    #[test]
    fn async_emits_one_record_per_merge() {
        let r = run_experiment(async_cfg(), exec()).unwrap();
        assert_eq!(r.rounds.len(), 6);
        for (i, rec) in r.rounds.iter().enumerate() {
            assert_eq!(rec.round, i);
            assert!(rec.mean_concurrency.is_some(), "round {i} missing concurrency");
            assert!(rec.cum_aggregated_secs.is_some());
            assert!(rec.in_flight_secs.is_some());
            assert!(rec.kernel_events.is_some());
            let conc = rec.mean_concurrency.unwrap();
            assert!(
                (0.0..=3.0 + 1e-9).contains(&conc),
                "round {i}: concurrency {conc} outside [0, target]"
            );
        }
        assert!(r.final_resource_hours() > 0.0);
        assert!(r.final_accuracy().is_some());
    }

    #[test]
    fn async_is_deterministic() {
        let a = run_experiment(async_cfg(), exec()).unwrap();
        let b = run_experiment(async_cfg(), exec()).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn async_unbounded_staleness_never_discards() {
        let mut cfg = async_cfg();
        cfg.mode = RoundMode::Async { buffer_k: 2, max_staleness: None };
        cfg.rounds = 8;
        let r = run_experiment(cfg, exec()).unwrap();
        let discarded: usize = r.rounds.iter().map(|x| x.discarded).sum();
        assert_eq!(discarded, 0);
    }

    #[test]
    fn async_accounting_closes_at_end() {
        // after the final leftover sweep: spent == aggregated + wasted
        let r = run_experiment(async_cfg(), exec()).unwrap();
        let last = r.rounds.last().unwrap();
        assert_eq!(last.in_flight_secs, Some(0.0));
        let agg = last.cum_aggregated_secs.unwrap();
        let closed = agg + last.cum_waste_secs;
        assert!(
            (last.cum_resource_secs - closed).abs() <= 1e-6 * last.cum_resource_secs.max(1.0),
            "spent {} != aggregated {} + wasted {}",
            last.cum_resource_secs,
            agg,
            last.cum_waste_secs
        );
    }

    #[test]
    fn async_learns_on_tiny() {
        let mut cfg = async_cfg();
        cfg.rounds = 40;
        cfg.target_participants = 4;
        cfg.mode = RoundMode::Async { buffer_k: 4, max_staleness: Some(6) };
        let r = run_experiment(cfg, exec()).unwrap();
        let acc = r.final_accuracy().unwrap();
        assert!(acc > 0.3, "async tiny run failed to learn: {acc}");
    }

    #[test]
    fn async_fault_injection_keeps_accounting_closed() {
        use crate::coordinator::Coordinator;
        use crate::scenario::faults::FaultConfig;
        let mut cfg = async_cfg();
        cfg.rounds = 10;
        cfg.faults = FaultConfig {
            flap: 0.2,
            crash: 0.25,
            delay: 0.4,
            delay_secs: 20.0,
            corrupt: 0.3,
            duplicate: 0.3,
            fault_seed: 13,
        };
        let mut coord = Coordinator::new(cfg.clone(), exec()).unwrap();
        let r = coord.run().unwrap();
        assert_eq!(r.rounds.len(), 10);
        let injected: usize = r.rounds.iter().map(|x| x.faults).sum();
        assert!(injected > 0, "fault rates this high must fire");
        // identity: after the final sweep, spent == aggregated + wasted
        let (spent, agg, wasted) = coord.accounting_totals();
        assert!(
            (spent - (agg + wasted)).abs() <= 1e-6 * spent.max(1.0),
            "spent {spent} != aggregated {agg} + wasted {wasted}"
        );
        // and the whole faulty run stays deterministic
        let b = run_experiment(cfg, exec()).unwrap();
        assert_eq!(r.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn async_dynavail_runs_to_completion() {
        let mut cfg = async_cfg();
        cfg.avail = AvailMode::DynAvail;
        cfg.rounds = 8;
        let r = run_experiment(cfg, exec()).unwrap();
        assert_eq!(r.rounds.len(), 8);
        // availability churn shows up as dropouts, discards or burned slots
        let _eventful: usize = r
            .rounds
            .iter()
            .map(|x| x.dropouts + usize::from(x.failed))
            .sum();
    }
}
