//! The round engine — the paper's Fig. 1 life-cycle made executable, driven
//! by the discrete-event kernel (`sim::EventKernel`):
//!
//! selection window (check-in + availability probe) → participant selection
//! (Random / Oort / IPS / SAFA, optionally APT-adjusted, OC or DL regime) →
//! real local SGD through the AOT executor → reporting (fresh before the
//! round ends, stragglers become stale deliveries) → staleness-aware
//! aggregation (Eq. 2 weights via the L1 kernels) → server optimizer →
//! evaluation; with full resource/waste accounting along the way.
//!
//! All time-ordered state flows through one event kernel: the virtual clock
//! lives in it, and straggler uploads are `EngineEvent::StaleDelivery`
//! events popped back out when their round window sweeps past them. The
//! round-synchronous regimes (OC/DL) sweep the kernel one round window at a
//! time and are **bit-identical** to the pre-refactor monolithic loop
//! (frozen in `coordinator::reference`, locked by
//! `tests/kernel_equivalence.rs`). The buffered-asynchronous regime
//! (`RoundMode::Async`, `coordinator::async_engine`) instead pops events one
//! at a time — check-ins, task completions, dropouts — re-triggering
//! selection per departure and merging every `buffer_k` arrivals.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::aggregation::saa::{merge, UpdateEntry};
use crate::aggregation::ServerOptimizer;
use crate::config::{AvailMode, ExpConfig, RoundMode};
use crate::data::partition::{LearnerShard, Partitioner};
use crate::data::synth::{Dataset, TestSet};
use crate::learners::ProfilePool;
use crate::metrics::{Accounting, ExperimentResult, RoundRecord};
use crate::population::{Population, Registry};
use crate::runlog::{
    EventObserver, LogSink, RunEvent, RunLogger, FATE_CORRUPT, FATE_DOOMED, FATE_TRAINED,
};
use crate::runtime::Executor;
use crate::scenario::faults::FaultKind;
use crate::selection::apt::AdaptiveTarget;
use crate::selection::{RoundFeedback, SelectPool, SelectionCtx, Selector};
use crate::sim::{Availability, EventClass, EventKernel};
use crate::trace::{LazyTraceSet, TraceConfig};
use crate::util::rng::Rng;
use crate::util::threadpool;

/// A straggler's update in flight to the server (sync regimes). Doomed
/// stragglers are waste-accounted up front and never scheduled, so a
/// scheduled delivery always carries a real delta (the pre-refactor
/// `Option<Vec<f32>>` was dead generality with a hidden accounting leak in
/// its `None` branch).
pub(crate) struct PendingUpdate {
    pub(crate) learner: usize,
    pub(crate) delta: Vec<f32>,
    pub(crate) origin_round: usize,
    /// Device-seconds this update cost (for waste accounting on discard).
    pub(crate) spent: f64,
    pub(crate) stat_util: f64,
    pub(crate) duration: f64,
}

/// An async-regime task in flight: its local SGD is *submitted* to the
/// train pool at spawn time against a snapshot of the then-current global
/// model (the model only mutates at merges, so the snapshot equals what
/// inline training would have seen), and *committed* when the arrival event
/// pops — kernel order, a fixed reduction order independent of worker
/// completion order, so results are byte-identical at any pool width.
pub(crate) struct AsyncTask {
    pub(crate) learner: usize,
    pub(crate) payload: TaskPayload,
    /// Server model version the task trained against (staleness base).
    pub(crate) origin_version: usize,
    /// Full task duration in device-seconds.
    pub(crate) duration: f64,
}

/// What an async task carries between spawn and arrival.
pub(crate) enum TaskPayload {
    /// Fault injection: corrupted at source — no SGD was submitted;
    /// server-side validation rejects the update on arrival.
    Corrupt,
    /// The training outcome in flight on the train pool (already resolved
    /// inline when the pool width is 1 — the serial path).
    Pending(threadpool::Ticket<Result<LocalOutcome>>),
}

/// A resolved update sitting in the async merge buffer (the task's ticket
/// has been waited on; the delta is concrete).
pub(crate) struct BufferedUpdate {
    pub(crate) learner: usize,
    pub(crate) delta: Vec<f32>,
    pub(crate) mean_loss: f64,
    pub(crate) origin_version: usize,
    pub(crate) duration: f64,
}

/// An async-regime participant leaving availability mid-task.
pub(crate) struct AsyncDrop {
    pub(crate) learner: usize,
    /// Partial device-seconds spent before dropping (all wasted).
    pub(crate) spent: f64,
    /// Injected mid-task crash (vs a trace departure). Crashed devices are
    /// quarantined for a cooldown on arrival of the Dropout event: fault
    /// decisions are keyed on (learner, version), so without the cooldown
    /// a crash-flagged learner could respawn-and-crash forever at a stuck
    /// version (versions only advance on merges/burns).
    pub(crate) crashed: bool,
}

/// Payloads flowing through the coordinator's event kernel.
pub(crate) enum EngineEvent {
    /// A straggler update finishing after its origin round (sync regimes).
    StaleDelivery(PendingUpdate),
    /// An async-regime task completing and delivering its update.
    Arrival(AsyncTask),
    /// An async-regime participant dropping out mid-task.
    Dropout(AsyncDrop),
    /// An async-regime (re-)selection retry when nothing is in flight.
    CheckIn,
}

/// Outcome of one participant's local training task.
pub(crate) struct LocalOutcome {
    pub(crate) delta: Vec<f32>,
    pub(crate) mean_loss: f64,
    pub(crate) stat_util: f64,
}

pub struct Coordinator {
    pub cfg: ExpConfig,
    pub(crate) exec: Arc<dyn Executor>,
    pub(crate) dataset: Arc<Dataset>,
    pub(crate) shards: Arc<Vec<LearnerShard>>,
    /// Persistent intra-round training pool (width from
    /// `cfg.train_workers`, falling back to `cfg.workers`). Jobs are
    /// submitted as the round discovers them; outcomes are committed in a
    /// fixed reduction order, so results are byte-identical at any width.
    pub(crate) train_pool: threadpool::TrainPool,
    /// The population substrate: who exists (sharded registry), who is
    /// available (incremental availability index), who is selectable
    /// (candidate set) — replaces the per-engine O(total_learners) scans.
    pub(crate) population: Population,
    pub(crate) selector: Box<dyn Selector>,
    pub(crate) server_opt: Box<dyn ServerOptimizer>,
    pub(crate) apt: AdaptiveTarget,
    pub global: Vec<f32>,
    /// The discrete-event kernel: virtual clock + unified event heap.
    pub(crate) kernel: EventKernel<EngineEvent>,
    pub(crate) accounting: Accounting,
    pub(crate) rng: Rng,
    pub(crate) test: TestSet,
    pub(crate) model_bytes: usize,
    /// SAFA+O: the set of (learner, origin_round) straggler updates that a
    /// first (plain) pass aggregated; the oracle pass only trains these.
    pub(crate) oracle_plan: Option<std::collections::HashSet<(usize, usize)>>,
    /// Recorded by every run: which straggler updates got aggregated.
    pub(crate) aggregated_stale: std::collections::HashSet<(usize, usize)>,
    /// Event-sourced run log hook (disabled by default — a disabled logger
    /// never constructs an event, so unlogged runs stay byte-identical).
    pub(crate) runlog: RunLogger,
}

/// Width of the intra-round training pool for `cfg`: `train_workers` if
/// set, else `workers` (the pre-existing knob), else a capped autodetect.
/// The resolved width never changes results — only wall-clock.
fn resolve_train_workers(cfg: &ExpConfig) -> usize {
    if cfg.train_workers != 0 {
        cfg.train_workers
    } else if cfg.workers != 0 {
        cfg.workers
    } else {
        threadpool::default_workers().min(8)
    }
}

/// Number of coordinator shards for `cfg`: `coord_shards` if set, else a
/// capped autodetect from the core count. Every sharded structure
/// (registry, availability kernels, eligible set, score indices) derives
/// its layout from this one number, and results are byte-identical for
/// any value (`tests/coord_shard_props.rs`) — only per-round wall-clock
/// at large populations changes.
pub(crate) fn resolve_coord_shards(cfg: &ExpConfig) -> usize {
    if cfg.coord_shards != 0 {
        cfg.coord_shards
    } else {
        threadpool::default_workers().min(8)
    }
}

impl Coordinator {
    pub fn new(cfg: ExpConfig, exec: Arc<dyn Executor>) -> Result<Coordinator> {
        cfg.validate()?;
        if cfg.jobs > 1 {
            return Err(anyhow!(
                "config asks for {} concurrent jobs; the single-job coordinator cannot \
                 run it — route through jobs::run_jobset",
                cfg.jobs
            ));
        }
        let info = exec.variant().clone();
        if info.name != cfg.variant {
            return Err(anyhow!(
                "executor variant '{}' != config variant '{}'",
                info.name,
                cfg.variant
            ));
        }
        let rng = Rng::new(cfg.seed);
        let dataset = Dataset::new(&info, cfg.seed ^ 0xD5);
        let partitioner =
            Partitioner::new(cfg.partition, info.num_classes, cfg.mean_samples);
        let shards = partitioner.assign(cfg.total_learners, cfg.seed ^ 0x9A);
        let profiles = ProfilePool::generate(cfg.total_learners, cfg.seed ^ 0x0F, cfg.hardware);
        // Scale path: traces and learner-side forecasters are generated at
        // first touch (bit-identical to eager generation — the trace comes
        // from the same per-learner RNG stream, the forecaster from the same
        // two-week replay), so a 100k-learner DynAvail population constructs
        // in milliseconds instead of materializing every learner up front.
        let avail = match cfg.avail {
            AvailMode::AllAvail => Availability::All,
            AvailMode::DynAvail => Availability::Lazy(LazyTraceSet::new(
                cfg.total_learners,
                cfg.seed ^ 0x7A,
                TraceConfig::default(),
            )),
        };
        let selector = crate::selection::by_name(&cfg.selector)
            .ok_or_else(|| anyhow!("unknown selector"))?;
        let server_opt = crate::aggregation::by_name(&cfg.server_opt)
            .ok_or_else(|| anyhow!("unknown server optimizer"))?;
        let initial_mu = match cfg.mode {
            RoundMode::Deadline { deadline } => deadline,
            RoundMode::OverCommit { .. } | RoundMode::Async { .. } => 100.0,
        };
        let apt = AdaptiveTarget::new(cfg.target_participants, cfg.apt_alpha, initial_mu);
        let global = exec.init_params(cfg.seed as i32)?;
        let test = dataset.test_set(cfg.test_per_class);
        let model_bytes = info.num_params * 4;
        // population substrate: sharded registry over the (eagerly-sampled,
        // value-compatible) device profiles + per-learner dynamic state,
        // with the availability index building lazily at first selection
        // (parallel when the run has workers, result-identical either way)
        let n_samples: Vec<u32> = shards.iter().map(|s| s.len() as u32).collect();
        let build_workers = if cfg.workers == 0 {
            threadpool::default_workers().min(8)
        } else {
            cfg.workers
        };
        let population = Population::new(
            Registry::eager(profiles, n_samples, resolve_coord_shards(&cfg)),
            avail,
            cfg.avail,
            cfg.local_epochs,
            model_bytes,
            build_workers,
        );
        let train_pool = threadpool::TrainPool::new(resolve_train_workers(&cfg));
        Ok(Coordinator {
            accounting: Accounting::default(),
            rng: rng.stream(0xC0),
            population,
            selector,
            server_opt,
            apt,
            global,
            kernel: EventKernel::default(),
            dataset: Arc::new(dataset),
            shards: Arc::new(shards),
            train_pool,
            test,
            model_bytes,
            exec,
            cfg,
            oracle_plan: None,
            aggregated_stale: std::collections::HashSet::new(),
            runlog: RunLogger::disabled(),
        })
    }

    /// Attach a run logger; every kernel event the engines process is then
    /// appended to its sink. Call before [`Coordinator::run`].
    pub fn set_runlog(&mut self, logger: RunLogger) {
        self.runlog = logger;
    }

    /// Run the configured experiment; returns the full result log. OC/DL
    /// regimes sweep the kernel one round window at a time; `Async` runs the
    /// fully event-driven buffered loop (`coordinator::async_engine`).
    pub fn run(&mut self) -> Result<ExperimentResult> {
        let mut result = ExperimentResult {
            label: self.cfg.label.clone(),
            perplexity_metric: self.exec.variant().perplexity,
            ..Default::default()
        };
        if self.runlog.enabled() {
            let (mode, buffer_k, max_staleness) = match self.cfg.mode {
                RoundMode::OverCommit { .. } => (0u8, 0u64, None),
                RoundMode::Deadline { .. } => (1u8, 0u64, None),
                RoundMode::Async { buffer_k, max_staleness } => {
                    (2u8, buffer_k as u64, max_staleness.map(|v| v as u64))
                }
            };
            let label = result.label.clone();
            let perplexity = result.perplexity_metric;
            let rounds = self.cfg.rounds as u64;
            let eval_every = self.cfg.eval_every as u64;
            let use_saa = self.cfg.use_saa;
            let staleness_threshold = self.cfg.staleness_threshold.map(|v| v as u64);
            self.runlog.emit(move || RunEvent::RunStart {
                label,
                perplexity,
                mode,
                buffer_k,
                max_staleness,
                rounds,
                eval_every,
                use_saa,
                staleness_threshold,
            });
        }
        if matches!(self.cfg.mode, RoundMode::Async { .. }) {
            self.run_async(&mut result)?;
            return Ok(result);
        }
        for round in 0..self.cfg.rounds {
            let rec = self.run_round(round)?;
            result.rounds.push(rec);
        }
        // whatever is still in flight at the end never got aggregated
        let leftover: f64 = self
            .kernel
            .iter()
            .map(|e| match &e.payload {
                EngineEvent::StaleDelivery(p) => p.spent,
                _ => 0.0,
            })
            .sum();
        // Logged before the waste call: the replay oracle re-derives waste
        // from this very value (heap iteration order is unspecified, so the
        // sum is not reproducible op-for-op from the event stream alone).
        self.runlog.emit(|| RunEvent::SweepLeftover { secs: leftover });
        self.accounting.waste(leftover);
        if let Some(last) = result.rounds.last_mut() {
            last.cum_waste_secs = self.accounting.cum_waste_secs;
        }
        self.runlog.emit(|| RunEvent::RunEnd);
        Ok(result)
    }

    /// The paper's Fig. 1 sequence for one round-synchronous (OC/DL) round,
    /// expressed as one sweep of the event kernel: pull the round's
    /// parameters, schedule this cohort's straggler uploads as future
    /// delivery events, then pop every delivery due within the round window.
    fn run_round(&mut self, round: usize) -> Result<RoundRecord> {
        let now = self.kernel.now();
        let mu = self.apt.mu();
        let mut rec = RoundRecord { round, ..Default::default() };
        let round_u = round as u64;
        self.runlog.emit(|| RunEvent::RoundStart { round: round_u, now });

        // ---- selection window: check-in + availability probe ------------
        // Incremental: availability flips from the index, cooldown/busy
        // re-admissions from the expiry buckets. The resulting eligible set
        // equals the old full scan's id list element-for-element, and every
        // set transition is forwarded to the selector's index hooks.
        self.population.sync_to(round, now, self.selector.as_mut());
        if self.runlog.enabled() {
            let count = self.population.eligible_set().len() as u64;
            self.runlog.emit(|| RunEvent::Eligibility { count });
        }

        // ---- target adjustment (APT) + overcommit ------------------------
        let mut target = self.cfg.target_participants;
        if self.cfg.apt {
            // probe in-flight stragglers (pending delivery events) for their
            // remaining upload times
            let remaining: Vec<f64> = self
                .kernel
                .iter()
                .filter_map(|e| match &e.payload {
                    EngineEvent::StaleDelivery(_) => Some((e.at - now).max(0.0)),
                    _ => None,
                })
                .collect();
            target = self.apt.target(&remaining);
        }
        let n_select = match self.cfg.mode {
            RoundMode::OverCommit { factor } => {
                ((target as f64) * factor).ceil() as usize
            }
            RoundMode::Deadline { .. } => target,
            RoundMode::Async { .. } => unreachable!("async mode uses run_async"),
        };

        // indexed selectors draw straight from the eligible set (sub-linear
        // in the pool); the fallback materializes the exact candidate
        // vector the pre-population full scan produced. Both paths are
        // element-for-element identical (same RNG draws), which is what
        // keeps this engine byte-identical to the frozen reference.
        let picked = {
            let pool = SelectPool {
                set: self.population.eligible_set(),
                probes: &self.population,
                mu,
            };
            self.selector.select_from(&pool, round, now, n_select, &mut self.rng)
        };
        let selected = match picked {
            Some(ids) => ids,
            None => {
                let candidates = self.population.pool_candidates(now, mu);
                if candidates.is_empty() {
                    Vec::new()
                } else {
                    let mut ctx = SelectionCtx {
                        round,
                        now,
                        target: n_select,
                        candidates: &candidates,
                        rng: &mut self.rng,
                    };
                    self.selector.select(&mut ctx)
                }
            }
        };
        rec.selected = selected.len();
        for &id in &selected {
            let learner = id as u64;
            self.runlog.emit(|| RunEvent::Selected { learner });
        }

        if selected.is_empty() {
            // Nothing checked in: burn a round slot (paper: round aborted).
            let dur = mu.max(1.0);
            self.kernel.advance_to(now + dur);
            self.apt.observe_round(dur);
            rec.failed = true;
            rec.round_duration = dur;
            rec.sim_time = self.kernel.now();
            rec.cum_resource_secs = self.accounting.cum_resource_secs;
            rec.cum_waste_secs = self.accounting.cum_waste_secs;
            rec.unique_participants = self.accounting.unique_participants();
            self.runlog.emit(|| RunEvent::RoundEnd { round_duration: dur });
            return Ok(rec);
        }

        // ---- per-participant task timing ---------------------------------
        // (id, completion_secs, dropped_after) — dropped_after = Some(t) if
        // the learner leaves availability (or crashes) before finishing.
        let faults = self.cfg.faults;
        let mut tasks: Vec<(usize, f64, Option<f64>)> = Vec::with_capacity(selected.len());
        for &id in &selected {
            if faults.flaps(id, round) {
                // fault injection: check-in flap — the learner vanishes
                // between selection and configuration, so the task never
                // starts (no device time spent, the slot is simply lost)
                rec.dropouts += 1;
                rec.faults += 1;
                let learner = id as u64;
                self.runlog.emit(|| RunEvent::FaultDecision {
                    kind: FaultKind::Flap.code(),
                    learner,
                    round: round_u,
                });
                continue;
            }
            let n_samples = self.shards[id].len();
            let t = self
                .population
                .profile(id)
                .completion_time(n_samples, self.cfg.local_epochs, self.model_bytes);
            let avail = self.population.availability();
            let mut dropped = if avail.available_through(id, now, t) {
                None
            } else {
                // drops out at (approximately) the end of its current session
                let mut lo = 0.0f64;
                let mut hi = t;
                for _ in 0..20 {
                    let mid = 0.5 * (lo + hi);
                    if avail.available_through(id, now, mid) {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                Some(lo)
            };
            if dropped.is_none() {
                if let Some(frac) = faults.crashes(id, round) {
                    // fault injection: mid-task crash — accounted exactly
                    // like a trace dropout at the crash point
                    rec.faults += 1;
                    dropped = Some(frac * t);
                    let learner = id as u64;
                    self.runlog.emit(|| RunEvent::FaultDecision {
                        kind: FaultKind::Crash.code(),
                        learner,
                        round: round_u,
                    });
                }
            }
            tasks.push((id, t, dropped));
        }

        // ---- round end ----------------------------------------------------
        let mut completions: Vec<f64> = tasks
            .iter()
            .filter(|(_, _, d)| d.is_none())
            .map(|(_, t, _)| *t)
            .collect();
        completions.sort_by(|a, b| a.total_cmp(b));
        let round_duration = match self.cfg.mode {
            RoundMode::Deadline { deadline } => {
                if self.cfg.selector == "safa" {
                    // SAFA: round ends when the target fraction reported,
                    // capped by the deadline.
                    let k = ((selected.len() as f64 * self.cfg.safa_target_ratio).ceil()
                        as usize)
                        .max(1);
                    if completions.len() >= k {
                        completions[k - 1].min(deadline)
                    } else {
                        deadline
                    }
                } else {
                    deadline
                }
            }
            RoundMode::OverCommit { .. } => {
                // OC: round ends when `target` updates have arrived
                if completions.is_empty() {
                    mu.max(1.0)
                } else if self.cfg.selector == "safa" {
                    let k = ((selected.len() as f64 * self.cfg.safa_target_ratio).ceil()
                        as usize)
                        .clamp(1, completions.len());
                    completions[k - 1]
                } else {
                    let k = target.min(completions.len());
                    completions[k - 1]
                }
            }
            RoundMode::Async { .. } => unreachable!("async mode uses run_async"),
        };
        // selection-window/configuration floor (Fig. 1 phases); never
        // extends past a configured reporting deadline
        let floor = match self.cfg.mode {
            RoundMode::Deadline { deadline } => self.cfg.min_round_duration.min(deadline),
            RoundMode::OverCommit { .. } => self.cfg.min_round_duration,
            RoundMode::Async { .. } => unreachable!("async mode uses run_async"),
        };
        let round_duration = round_duration.max(floor);
        let round_end = now + round_duration;

        // ---- classify tasks: fresh / straggler / dropout ------------------
        let mut fresh_ids = Vec::new();
        let mut straggler_ids = Vec::new(); // complete, but after round end
        for &(id, t, dropped) in &tasks {
            match dropped {
                Some(dt) => {
                    // partial work, all wasted
                    self.accounting.spend(id, dt);
                    self.accounting.waste(dt);
                    rec.dropouts += 1;
                    self.population.mark_busy(id, now + dt, self.selector.as_mut());
                    let learner = id as u64;
                    self.runlog.emit(|| RunEvent::TaskDropout { learner, spent: dt });
                }
                None if t <= round_duration => {
                    fresh_ids.push((id, t));
                }
                None => {
                    straggler_ids.push((id, t));
                }
            }
        }

        // ---- oracle / doomed-straggler analysis ---------------------------
        // Estimated staleness if the update lands during round
        // `round + ceil((t - dur) / expected_future_round_duration)`.
        let est_round_dur = match self.cfg.mode {
            RoundMode::Deadline { deadline } => deadline,
            RoundMode::OverCommit { .. } => mu.max(1.0),
            RoundMode::Async { .. } => unreachable!("async mode uses run_async"),
        };
        // Staleness-doom analysis for the non-oracle training-skip
        // optimization: skip the SGD only when the update CERTAINLY exceeds
        // the staleness threshold (2x slack on the round-duration estimate);
        // borderline cases still train and are re-checked (and
        // waste-accounted) at delivery time, so the model trajectory is
        // unaffected either way.
        let doomed = |t: f64| -> bool {
            if !self.cfg.use_saa {
                return true; // never aggregated without SAA
            }
            match self.cfg.staleness_threshold {
                None => false,
                Some(th) => {
                    let extra = (t - round_duration).max(0.0);
                    let tau_est = (extra / est_round_dur).ceil() as usize;
                    tau_est > 2 * th + 1
                }
            }
        };

        // ---- run real local training --------------------------------------
        // Fresh participants always train. Stragglers train unless the
        // oracle knows (or conservative analysis proves) the update dies.
        // Corrupted updates are rejected by server validation at delivery,
        // so their SGD is skipped too (the model never sees the delta).
        let mut corrupted_fresh: Vec<usize> = Vec::new();
        let mut train_ids: Vec<(usize, f64, bool)> = Vec::new(); // (id, task_time, is_fresh)
        for &(id, t) in &fresh_ids {
            if faults.corrupts(id, round) {
                continue; // spend/waste accounted in the fresh spend loop
            }
            train_ids.push((id, t, true));
        }
        for &(id, t) in &straggler_ids {
            let oracle_doomed = match &self.oracle_plan {
                // SAFA+O (Fig. 2): the perfect oracle knows exactly which
                // stale updates get aggregated (the plan recorded by the
                // first pass); everything else is never even started.
                Some(plan) => !plan.contains(&(id, round)),
                None => false,
            };
            if oracle_doomed {
                // SAFA+O: the oracle prevents the learner from training at
                // all — no resources spent, nothing delivered. The learner
                // stays reserved for the same window so the system timeline
                // (selection dynamics) is identical to plain SAFA.
                self.population.mark_busy(id, now + t, self.selector.as_mut());
                continue;
            }
            self.accounting.spend(id, t);
            self.population.mark_busy(id, now + t, self.selector.as_mut());
            let learner = id as u64;
            if faults.corrupts(id, round) {
                // fault injection: corrupted straggler update — validation
                // rejects it on delivery, so the spend is pure waste and
                // nothing is ever scheduled
                self.accounting.waste(t);
                rec.discarded += 1;
                rec.faults += 1;
                self.runlog.emit(|| RunEvent::FaultDecision {
                    kind: FaultKind::Corrupt.code(),
                    learner,
                    round: round_u,
                });
                self.runlog.emit(|| RunEvent::StragglerSpend {
                    learner,
                    duration: t,
                    fate: FATE_CORRUPT,
                });
                continue;
            }
            if doomed(t) {
                // Will certainly be discarded (no SAA, or staleness bound
                // certainly exceeded): account the waste now and skip the
                // actual SGD — the model never sees this update.
                self.accounting.waste(t);
                rec.discarded += 1;
                self.runlog.emit(|| RunEvent::StragglerSpend {
                    learner,
                    duration: t,
                    fate: FATE_DOOMED,
                });
                continue;
            }
            self.runlog.emit(|| RunEvent::StragglerSpend {
                learner,
                duration: t,
                fate: FATE_TRAINED,
            });
            train_ids.push((id, t, false));
        }
        for &(id, t) in &fresh_ids {
            self.accounting.spend(id, t);
            self.population.mark_busy(id, now + t, self.selector.as_mut());
            let learner = id as u64;
            let corrupt = faults.corrupts(id, round);
            if corrupt {
                // fault injection: corrupted fresh update — rejected at
                // delivery, full spend wasted
                self.accounting.waste(t);
                rec.discarded += 1;
                rec.faults += 1;
                corrupted_fresh.push(id);
                self.runlog.emit(|| RunEvent::FaultDecision {
                    kind: FaultKind::Corrupt.code(),
                    learner,
                    round: round_u,
                });
            }
            self.runlog.emit(|| RunEvent::FreshSpend { learner, duration: t, corrupt });
        }

        let outcomes = self.train_participants(
            &train_ids.iter().map(|&(id, _, _)| id).collect::<Vec<_>>(),
        )?;

        // ---- route updates: fresh vs scheduled stale deliveries -----------
        let mut fresh_updates: Vec<UpdateEntry> = Vec::new();
        let mut feedback_completed: Vec<(usize, f64, f64)> = Vec::new();
        let mut losses = Vec::new();
        for ((id, task_time, is_fresh), outcome) in train_ids.iter().zip(outcomes) {
            let outcome = outcome?;
            losses.push(outcome.mean_loss);
            if self.runlog.enabled() {
                let (learner, mean_loss) = (*id as u64, outcome.mean_loss);
                let (duration, fresh) = (*task_time, *is_fresh);
                self.runlog.emit(|| RunEvent::Trained {
                    learner,
                    mean_loss,
                    duration,
                    fresh,
                });
            }
            if *is_fresh {
                self.accounting.aggregate(*task_time);
                feedback_completed.push((*id, outcome.stat_util, *task_time));
                fresh_updates.push(UpdateEntry {
                    learner: *id,
                    delta: outcome.delta,
                    origin_round: round,
                });
            } else {
                let mut deliver_at = now + task_time;
                if let Some(d) = faults.delays(*id, round) {
                    // fault injection: the upload is delayed in transit —
                    // it arrives late and may die to the staleness bound.
                    // (Sync rounds model in-transit uploads only for
                    // stragglers; within-window reports are atomic with the
                    // round. The async engine delays every completion.)
                    rec.faults += 1;
                    deliver_at += d;
                    let learner = *id as u64;
                    self.runlog.emit(|| RunEvent::FaultDecision {
                        kind: FaultKind::Delay.code(),
                        learner,
                        round: round_u,
                    });
                }
                self.kernel.schedule(
                    deliver_at,
                    EventClass::Delivery,
                    EngineEvent::StaleDelivery(PendingUpdate {
                        learner: *id,
                        delta: outcome.delta,
                        origin_round: round,
                        spent: *task_time,
                        stat_util: outcome.stat_util,
                        duration: *task_time,
                    }),
                );
            }
        }

        // ---- pop stale deliveries that landed during this round -----------
        let mut stale_updates: Vec<UpdateEntry> = Vec::new();
        for ev in self.kernel.pop_due(round_end) {
            let EngineEvent::StaleDelivery(p) = ev.payload else {
                unreachable!("sync rounds schedule only stale deliveries");
            };
            let (learner, origin_round, duration) =
                (p.learner as u64, p.origin_round as u64, p.duration);
            if faults.duplicates(p.learner, p.origin_round) {
                // fault injection: the upload arrived twice; the server
                // dedupes the second copy (no accounting impact)
                rec.faults += 1;
                self.runlog.emit(|| RunEvent::FaultDecision {
                    kind: FaultKind::Duplicate.code(),
                    learner,
                    round: origin_round,
                });
            }
            self.runlog.emit(|| RunEvent::StaleDelivery { learner, origin_round, duration });
            let tau = round - p.origin_round;
            let within = self
                .cfg
                .staleness_threshold
                .map(|th| tau <= th)
                .unwrap_or(true);
            if self.cfg.use_saa && within {
                self.accounting.aggregate(p.duration);
                feedback_completed.push((p.learner, p.stat_util, p.duration));
                self.aggregated_stale.insert((p.learner, p.origin_round));
                stale_updates.push(UpdateEntry {
                    learner: p.learner,
                    delta: p.delta,
                    origin_round: p.origin_round,
                });
            } else {
                self.accounting.waste(p.spent);
                rec.discarded += 1;
            }
        }

        rec.fresh_updates = fresh_updates.len();
        rec.stale_updates = stale_updates.len();
        // None (-> JSON null) when nothing trained this round: the seed's
        // f64::NAN here produced invalid JSON. Fixed jointly with the frozen
        // reference oracle so byte-equivalence pins both sides.
        rec.train_loss = if losses.is_empty() {
            None
        } else {
            Some(losses.iter().sum::<f64>() / losses.len() as f64)
        };

        // ---- aggregate + server update ------------------------------------
        if fresh_updates.is_empty() && stale_updates.is_empty() {
            rec.failed = true;
        } else {
            let outcome = merge(
                self.exec.as_ref(),
                &fresh_updates,
                &stale_updates,
                self.cfg.scaling,
                round,
            )?;
            self.server_opt.apply(&mut self.global, &outcome.delta)?;
        }

        // ---- cooldowns, feedback, clock ------------------------------------
        for (id, _, _) in &feedback_completed {
            self.population.begin_cooldown(
                *id,
                round + 1 + self.cfg.cooldown_rounds,
                self.selector.as_mut(),
            );
        }
        let mut missed: Vec<usize> = straggler_ids.iter().map(|&(id, _)| id).collect();
        missed.extend(corrupted_fresh);
        self.selector.feedback(&RoundFeedback {
            round,
            completed: &feedback_completed,
            missed: &missed,
            round_duration,
        });
        self.apt.observe_round(round_duration);
        self.kernel.advance_to(round_end);

        // ---- evaluation ------------------------------------------------------
        if (round + 1) % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds {
            let (loss, acc) = self.evaluate()?;
            rec.test_loss = Some(loss);
            rec.test_accuracy = Some(acc);
            self.runlog.emit(|| RunEvent::EvalDone { loss, acc });
        }

        rec.round_duration = round_duration;
        rec.sim_time = self.kernel.now();
        rec.cum_resource_secs = self.accounting.cum_resource_secs;
        rec.cum_waste_secs = self.accounting.cum_waste_secs;
        rec.unique_participants = self.accounting.unique_participants();
        self.runlog.emit(|| RunEvent::RoundEnd { round_duration });
        Ok(rec)
    }

    /// Submit local-SGD jobs for `ids` to the training pool and return one
    /// ticket per learner, in `ids` order. Each job trains against a
    /// snapshot of the *current* global model — callers must only commit
    /// (wait on) tickets at points where the global has not advanced past
    /// that snapshot for the learner in question, which both engines
    /// guarantee: the sync path merges after the whole batch, and the async
    /// path only mutates the global at buffered merges *after* the arrival
    /// that waits on the ticket.
    pub(crate) fn submit_training(
        &self,
        ids: &[usize],
    ) -> Vec<threadpool::Ticket<Result<LocalOutcome>>> {
        if ids.is_empty() {
            return Vec::new();
        }
        let global = Arc::new(self.global.clone());
        ids.iter()
            .map(|&id| {
                let exec = Arc::clone(&self.exec);
                let dataset = Arc::clone(&self.dataset);
                let shards = Arc::clone(&self.shards);
                let global = Arc::clone(&global);
                let (lr, epochs, seed) = (self.cfg.lr, self.cfg.local_epochs, self.cfg.seed);
                self.train_pool.submit(move || {
                    local_train(
                        exec.as_ref(),
                        &dataset,
                        &shards[id],
                        id,
                        &global,
                        lr,
                        epochs,
                        seed,
                    )
                })
            })
            .collect()
    }

    /// Execute real local SGD for each participant (concurrent over
    /// learners; outcomes committed in `ids` order regardless of completion
    /// order, so results are byte-identical at any pool width).
    pub(crate) fn train_participants(&self, ids: &[usize]) -> Result<Vec<Result<LocalOutcome>>> {
        Ok(self
            .submit_training(ids)
            .into_iter()
            .map(|t| t.wait())
            .collect())
    }

    /// Build the availability index up front (idempotent — it is exactly the
    /// first `sync_to` a run performs). The train bench calls this so the
    /// timed window measures training fan-out, not the one-off index build.
    pub fn warm(&mut self) {
        self.population.sync_to(0, 0.0, self.selector.as_mut());
    }

    /// Test-set evaluation: (mean loss, top-1 accuracy).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        evaluate_params(self.exec.as_ref(), &self.test, &self.global)
    }

    /// Pre-generate every learner's trace and forecaster — the pre-refactor
    /// eager construction. Tests and benches use this to prove the lazy
    /// path is result-identical and to measure what laziness saves.
    pub fn materialize_all(&self) {
        self.population.materialize_all();
    }

    /// Learner traces generated so far (== total_learners on the eager path).
    pub fn materialized_traces(&self) -> usize {
        self.population.materialized_traces()
    }

    /// Learner forecasters trained so far.
    pub fn trained_forecasters(&self) -> usize {
        self.population.trained_forecasters()
    }

    /// Terminal resource buckets: `(spent, aggregated, wasted)`
    /// device-seconds. After [`Coordinator::run`] returns, every spent
    /// second sits in exactly one terminal bucket — `spent == aggregated +
    /// wasted` (in-flight work is swept to waste at the end) — the
    /// accounting identity the fuzz harness checks on every sampled
    /// scenario, sync and async alike.
    pub fn accounting_totals(&self) -> (f64, f64, f64) {
        (
            self.accounting.cum_resource_secs,
            self.accounting.cum_aggregated_secs,
            self.accounting.cum_waste_secs,
        )
    }
}

/// One participant's local training task (pure function of its inputs so it
/// can run on the worker pool). Shared with the frozen reference engine —
/// both must execute identical floating-point kernels for the bytewise
/// equivalence suite to be meaningful.
#[allow(clippy::too_many_arguments)]
pub(crate) fn local_train(
    exec: &dyn Executor,
    dataset: &Dataset,
    shard: &LearnerShard,
    learner: usize,
    global: &[f32],
    lr: f32,
    epochs: usize,
    seed: u64,
) -> Result<LocalOutcome> {
    let v = exec.variant();
    let (b, d) = (v.batch, v.input_dim);
    let mut params = global.to_vec();
    let mut rng = Rng::new(seed ^ 0x10CA1).stream(learner as u64);
    let mut losses = Vec::new();
    let n = shard.len();
    if n == 0 {
        return Err(anyhow!("learner {learner} has an empty shard"));
    }
    let mut order: Vec<usize> = (0..n).collect();
    for _ in 0..epochs.max(1) {
        rng.shuffle(&mut order);
        for chunk in order.chunks(b) {
            let mut x = vec![0f32; b * d];
            let mut y = vec![0i32; b];
            let mut mask = vec![0f32; b];
            for (row, &sample_idx) in chunk.iter().enumerate() {
                let label = shard.labels[sample_idx] as usize;
                let f = dataset.features(learner as u64, sample_idx as u64, label);
                x[row * d..(row + 1) * d].copy_from_slice(&f);
                y[row] = label as i32;
                mask[row] = 1.0;
            }
            let out = exec.train_step(&params, &x, &y, &mask, lr)?;
            params = out.params;
            losses.push(out.loss as f64);
        }
    }
    let delta: Vec<f32> = params.iter().zip(global).map(|(p, g)| p - g).collect();
    let mean_loss = losses.iter().sum::<f64>() / losses.len() as f64;
    // Oort's statistical utility: |B_i| * sqrt(mean of squared losses).
    let sq_mean = losses.iter().map(|l| l * l).sum::<f64>() / losses.len() as f64;
    let stat_util = n as f64 * sq_mean.sqrt();
    Ok(LocalOutcome { delta, mean_loss, stat_util })
}

/// Evaluate arbitrary parameters on a test set.
pub fn evaluate_params(
    exec: &dyn Executor,
    test: &TestSet,
    params: &[f32],
) -> Result<(f64, f64)> {
    let v = exec.variant();
    let mut sum_loss = 0f64;
    let mut correct = 0f64;
    let mut total = 0f64;
    for (x, y, mask) in test.batches(v.batch) {
        let (l, c) = exec.eval_batch(params, &x, &y, &mask)?;
        sum_loss += l as f64;
        correct += c as f64;
        total += mask.iter().sum::<f32>() as f64;
    }
    if total == 0.0 {
        return Err(anyhow!("empty test set"));
    }
    Ok((sum_loss / total, correct / total))
}

/// Convenience: build a coordinator (native or artifact backend chosen by
/// the caller) and run to completion.
///
/// `cfg.oracle` (SAFA+O, Fig. 2) runs TWO passes: a plain pass to learn
/// exactly which straggler updates end up aggregated, then the accounted
/// pass in which the perfect oracle prevents all other stragglers from ever
/// training. The model trajectory is identical across both by construction.
pub fn run_experiment(cfg: ExpConfig, exec: Arc<dyn Executor>) -> Result<ExperimentResult> {
    if cfg.oracle {
        let mut probe_cfg = cfg.clone();
        probe_cfg.oracle = false;
        let mut probe = Coordinator::new(probe_cfg, Arc::clone(&exec))?;
        probe.run()?;
        let plan = probe.aggregated_stale;
        let mut coord = Coordinator::new(cfg, exec)?;
        coord.oracle_plan = Some(plan);
        return coord.run();
    }
    Coordinator::new(cfg, exec)?.run()
}

/// [`run_experiment`], but with every kernel event the engines process
/// appended to `sink` as an event-sourced run log (`runlog` module). The
/// returned result is byte-identical to [`run_experiment`] on the same
/// config — logging observes the run, it never perturbs it — and the log
/// alone is enough for [`crate::runlog::replay`] to re-derive it. Oracle
/// (SAFA+O) configs log only the accounted second pass.
pub fn run_experiment_logged(
    cfg: ExpConfig,
    exec: Arc<dyn Executor>,
    sink: Box<dyn LogSink>,
) -> Result<ExperimentResult> {
    run_experiment_instrumented(cfg, exec, RunLogger::new(sink))
}

/// [`run_experiment`], but with every kernel event fed to an in-process
/// [`EventObserver`] (the live-telemetry hook) — no disk or memory log.
/// Same non-perturbation guarantee as [`run_experiment_logged`]: the
/// result is byte-identical to the unobserved run.
pub fn run_experiment_observed(
    cfg: ExpConfig,
    exec: Arc<dyn Executor>,
    observer: Box<dyn EventObserver>,
) -> Result<ExperimentResult> {
    run_experiment_instrumented(cfg, exec, RunLogger::observing(observer))
}

/// The general form behind [`run_experiment_logged`] /
/// [`run_experiment_observed`]: run with an arbitrary pre-built
/// [`RunLogger`] (sink, observer, or both). Oracle (SAFA+O) configs run
/// the unaccounted probe pass with the logger detached, so the stream
/// witnesses only the accounted second pass.
pub fn run_experiment_instrumented(
    cfg: ExpConfig,
    exec: Arc<dyn Executor>,
    logger: RunLogger,
) -> Result<ExperimentResult> {
    let mut coord = if cfg.oracle {
        let mut probe_cfg = cfg.clone();
        probe_cfg.oracle = false;
        let mut probe = Coordinator::new(probe_cfg, Arc::clone(&exec))?;
        probe.run()?;
        let plan = probe.aggregated_stale;
        let mut coord = Coordinator::new(cfg, exec)?;
        coord.oracle_plan = Some(plan);
        coord
    } else {
        Coordinator::new(cfg, exec)?
    };
    coord.set_runlog(logger);
    let result = coord.run()?;
    coord.runlog.finish()?;
    Ok(result)
}

/// [`run_experiment`], but with every trace and forecaster materialized at
/// construction — the pre-refactor eager behaviour. Exists so tests can
/// assert the lazy path changes nothing but construction cost.
pub fn run_experiment_eager(
    cfg: ExpConfig,
    exec: Arc<dyn Executor>,
) -> Result<ExperimentResult> {
    if cfg.oracle {
        return Err(anyhow!("run_experiment_eager: oracle configs unsupported"));
    }
    let mut coord = Coordinator::new(cfg, exec)?;
    coord.materialize_all();
    coord.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{builtin_variant, NativeExecutor};

    fn exec() -> Arc<dyn Executor> {
        Arc::new(NativeExecutor::new(builtin_variant("tiny")))
    }

    fn base_cfg() -> ExpConfig {
        ExpConfig {
            variant: "tiny".into(),
            total_learners: 24,
            rounds: 12,
            target_participants: 4,
            mean_samples: 16,
            test_per_class: 8,
            eval_every: 3,
            lr: 0.1,
            ..Default::default()
        }
    }

    #[test]
    fn random_allavail_learns() {
        let mut cfg = base_cfg();
        cfg.avail = AvailMode::AllAvail;
        cfg.rounds = 40;
        let r = run_experiment(cfg, exec()).unwrap();
        let acc = r.final_accuracy().unwrap();
        assert!(acc > 0.5, "tiny 4-class task should exceed 50%, got {acc}");
        assert!(r.final_resource_hours() > 0.0);
    }

    #[test]
    fn variant_mismatch_rejected() {
        let mut cfg = base_cfg();
        cfg.variant = "speech".into();
        assert!(Coordinator::new(cfg, exec()).is_err());
    }

    #[test]
    fn relay_full_stack_runs() {
        let mut cfg = base_cfg().relay();
        cfg.mode = RoundMode::Deadline { deadline: 60.0 };
        let r = run_experiment(cfg, exec()).unwrap();
        assert_eq!(r.rounds.len(), 12);
        // some rounds should have stale updates under a 60s deadline
        let stale: usize = r.rounds.iter().map(|x| x.stale_updates).sum();
        let fresh: usize = r.rounds.iter().map(|x| x.fresh_updates).sum();
        assert!(fresh > 0);
        let _ = stale; // stale may be 0 on fast profiles; asserted in bigger tests
    }

    #[test]
    fn safa_trains_all_available() {
        let mut cfg = base_cfg();
        cfg.selector = "safa".into();
        cfg.use_saa = true;
        cfg.staleness_threshold = Some(5);
        cfg.mode = RoundMode::Deadline { deadline: 60.0 };
        cfg.avail = AvailMode::AllAvail;
        cfg.rounds = 4;
        let r = run_experiment(cfg, exec()).unwrap();
        // all 24 learners (minus cooldowns) should be selected in round 0
        assert!(r.rounds[0].selected >= 20, "selected={}", r.rounds[0].selected);
    }

    #[test]
    fn no_saa_wastes_stragglers() {
        let mut cfg = base_cfg();
        cfg.use_saa = false;
        cfg.mode = RoundMode::Deadline { deadline: 2.0 }; // tight: many stragglers
        cfg.avail = AvailMode::AllAvail;
        let r = run_experiment(cfg, exec()).unwrap();
        assert!(
            r.waste_fraction() > 0.0,
            "tight deadline without SAA must waste work: {}",
            r.waste_fraction()
        );
    }

    #[test]
    fn saa_reduces_waste_vs_no_saa() {
        let mk = |use_saa: bool| {
            let mut cfg = base_cfg();
            cfg.use_saa = use_saa;
            cfg.scaling = crate::aggregation::scaling::ScalingRule::Relay { beta: 0.35 };
            cfg.mode = RoundMode::Deadline { deadline: 2.0 };
            cfg.avail = AvailMode::AllAvail;
            cfg.rounds = 16;
            run_experiment(cfg, exec()).unwrap()
        };
        let with = mk(true);
        let without = mk(false);
        assert!(
            with.waste_fraction() < without.waste_fraction(),
            "SAA should reduce waste: {} vs {}",
            with.waste_fraction(),
            without.waste_fraction()
        );
    }

    #[test]
    fn oracle_uses_fewer_resources() {
        let mk = |oracle: bool| {
            let mut cfg = base_cfg();
            cfg.selector = "safa".into();
            cfg.use_saa = true;
            cfg.staleness_threshold = Some(1);
            cfg.oracle = oracle;
            cfg.mode = RoundMode::Deadline { deadline: 12.0 };
            cfg.avail = AvailMode::AllAvail;
            cfg.rounds = 10;
            run_experiment(cfg, exec()).unwrap()
        };
        let plain = mk(false);
        let oracle = mk(true);
        assert!(
            oracle.final_resource_hours() <= plain.final_resource_hours(),
            "oracle {} vs plain {}",
            oracle.final_resource_hours(),
            plain.final_resource_hours()
        );
    }

    #[test]
    fn dynavail_has_dropouts_or_failures() {
        let mut cfg = base_cfg();
        cfg.avail = AvailMode::DynAvail;
        cfg.rounds = 20;
        let r = run_experiment(cfg, exec()).unwrap();
        let eventful: usize = r
            .rounds
            .iter()
            .map(|x| x.dropouts + usize::from(x.failed))
            .sum();
        assert!(eventful > 0, "dyn availability should cause churn");
    }

    #[test]
    fn cooldown_enforced() {
        let mut cfg = base_cfg();
        cfg.avail = AvailMode::AllAvail;
        cfg.total_learners = 5;
        cfg.target_participants = 5;
        cfg.cooldown_rounds = 3;
        cfg.rounds = 2;
        let r = run_experiment(cfg, exec()).unwrap();
        // round 0 uses all 5; round 1 everyone cools down -> failed round
        assert!(r.rounds[0].selected >= 4);
        assert!(r.rounds[1].failed || r.rounds[1].selected == 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let r1 = run_experiment(base_cfg(), exec()).unwrap();
        let r2 = run_experiment(base_cfg(), exec()).unwrap();
        assert_eq!(r1.final_accuracy(), r2.final_accuracy());
        assert_eq!(
            r1.rounds.last().unwrap().cum_resource_secs,
            r2.rounds.last().unwrap().cum_resource_secs
        );
    }

    #[test]
    fn sync_accounting_identity_closes_at_end() {
        // spent == aggregated + wasted once the final leftover sweep ran —
        // with and without injected faults
        for faulty in [false, true] {
            let mut cfg = base_cfg();
            cfg.mode = RoundMode::Deadline { deadline: 2.0 };
            cfg.use_saa = true;
            cfg.staleness_threshold = Some(2);
            if faulty {
                cfg.faults = crate::scenario::faults::FaultConfig {
                    flap: 0.2,
                    crash: 0.3,
                    delay: 0.4,
                    delay_secs: 10.0,
                    corrupt: 0.3,
                    duplicate: 0.3,
                    fault_seed: 5,
                };
            }
            let mut coord = Coordinator::new(cfg, exec()).unwrap();
            let r = coord.run().unwrap();
            let (spent, agg, wasted) = coord.accounting_totals();
            assert!(spent > 0.0);
            assert!(
                (spent - (agg + wasted)).abs() <= 1e-6 * spent.max(1.0),
                "faulty={faulty}: spent {spent} != aggregated {agg} + wasted {wasted}"
            );
            if faulty {
                let injected: usize = r.rounds.iter().map(|x| x.faults).sum();
                assert!(injected > 0, "fault rates this high must fire");
            } else {
                assert!(r.rounds.iter().all(|x| x.faults == 0));
            }
        }
    }

    #[test]
    fn fault_free_config_is_byte_identical_to_default() {
        // zero rates gate every fault decision: a nonzero fault_seed with
        // all-zero rates must not perturb a single byte
        let r1 = run_experiment(base_cfg(), exec()).unwrap();
        let mut cfg = base_cfg();
        cfg.faults = crate::scenario::faults::FaultConfig {
            fault_seed: 999,
            ..Default::default()
        };
        let r2 = run_experiment(cfg, exec()).unwrap();
        assert_eq!(r1.to_json().to_string(), r2.to_json().to_string());
    }

    #[test]
    fn sync_records_leave_async_accounting_unset() {
        // the async-only RoundRecord fields must stay None on OC/DL paths —
        // the bytewise equivalence vs the frozen reference depends on it
        let r = run_experiment(base_cfg(), exec()).unwrap();
        for rec in &r.rounds {
            assert!(rec.mean_concurrency.is_none());
            assert!(rec.cum_aggregated_secs.is_none());
            assert!(rec.in_flight_secs.is_none());
            assert!(rec.kernel_events.is_none());
        }
    }
}
