//! **Frozen pre-refactor round engine** — the equivalence oracle for the
//! event-kernel engine.
//!
//! This is the monolithic OC/DL round loop exactly as it stood before
//! `engine.rs` was re-expressed on `sim::EventKernel`. It is kept verbatim
//! (modulo the `DeliveryQueue` iterator now yielding `(deliver_at, &item)`
//! tuples) so `tests/kernel_equivalence.rs` can assert, for a grid of
//! OC/DL × AllAvail/DynAvail × selector configs, that the refactored engine
//! produces **byte-identical** `ExperimentResult` JSON. The shared training
//! math (`local_train`, `evaluate_params`) is imported from `engine` — both
//! engines must run the exact same floating-point kernels for bytewise
//! comparison to be meaningful.
//!
//! Do not extend this module with new features; behavioral changes defeat
//! its purpose. It intentionally rejects `RoundMode::Async`, which did not
//! exist pre-refactor. Two sanctioned joint edits, each applied **in both
//! engines in the same commit** so the equivalence suite pins the pair:
//! the seed's `train_loss: NaN` emission for nothing-trained rounds was
//! fixed to `None`/null, and the deterministic fault model
//! (`scenario::faults`: flap / crash / delay / corrupt / duplicate) is
//! threaded through the same life-cycle points as in the kernel engine so
//! the differential fuzz harness can compare fault-injected cells too.
//!
//! One deliberate tradeoff: this oracle rides the kernel-backed
//! `DeliveryQueue` rather than carrying its own copy of the old
//! `BinaryHeap<Pending>` — so the *round-loop logic* is what the suite pins,
//! while the queue substrate (and its equal-time tie-break, which the old
//! heap left arbitrary) is shared with the code under test. Sharing the
//! substrate is what makes bytewise equality a meaningful test of the loop
//! refactor: task completion times are continuous (lognormal), so exact
//! ties essentially never occur, and every floating-point kernel on both
//! sides is literally the same code.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::aggregation::saa::{merge, UpdateEntry};
use crate::aggregation::ServerOptimizer;
use crate::config::{AvailMode, ExpConfig, RoundMode};
use crate::data::partition::{LearnerShard, Partitioner};
use crate::data::synth::{Dataset, TestSet};
use crate::forecast::{ForecasterBank, SeasonalForecaster};
use crate::learners::ProfilePool;
use crate::metrics::{Accounting, ExperimentResult, RoundRecord};
use crate::runtime::Executor;
use crate::selection::apt::AdaptiveTarget;
use crate::selection::{Candidate, RoundFeedback, SelectionCtx, Selector};
use crate::sim::{Availability, Clock, DeliveryQueue};
use crate::trace::{LazyTraceSet, TraceConfig};
use crate::util::rng::Rng;

use super::engine::{evaluate_params, local_train, LocalOutcome};

/// Sampling step (seconds) of the one-week series each learner's personal
/// forecaster is bootstrapped from (Appendix A).
const FORECAST_STEP: f64 = 1800.0;

/// A straggler's update in flight to the server.
struct PendingUpdate {
    learner: usize,
    delta: Option<Vec<f32>>, // None when training was skipped as doomed
    origin_round: usize,
    /// Device-seconds this update cost (for waste accounting on discard).
    spent: f64,
    stat_util: f64,
    duration: f64,
}

/// The pre-refactor coordinator: one synchronous `run_round` per round.
pub struct ReferenceCoordinator {
    pub cfg: ExpConfig,
    exec: Arc<dyn Executor>,
    dataset: Dataset,
    shards: Vec<LearnerShard>,
    profiles: ProfilePool,
    avail: Availability,
    forecasters: ForecasterBank,
    selector: Box<dyn Selector>,
    server_opt: Box<dyn ServerOptimizer>,
    apt: AdaptiveTarget,
    pub global: Vec<f32>,
    clock: Clock,
    pending: DeliveryQueue<PendingUpdate>,
    /// Round index until which each learner holds from checking in.
    cooldown_until: Vec<usize>,
    /// Absolute time until which each learner is busy with a task.
    busy_until: Vec<f64>,
    accounting: Accounting,
    rng: Rng,
    test: TestSet,
    model_bytes: usize,
    /// SAFA+O: the set of (learner, origin_round) straggler updates that a
    /// first (plain) pass aggregated; the oracle pass only trains these.
    oracle_plan: Option<std::collections::HashSet<(usize, usize)>>,
    /// Recorded by every run: which straggler updates got aggregated.
    aggregated_stale: std::collections::HashSet<(usize, usize)>,
}

impl ReferenceCoordinator {
    pub fn new(cfg: ExpConfig, exec: Arc<dyn Executor>) -> Result<ReferenceCoordinator> {
        cfg.validate()?;
        let info = exec.variant().clone();
        if info.name != cfg.variant {
            return Err(anyhow!(
                "executor variant '{}' != config variant '{}'",
                info.name,
                cfg.variant
            ));
        }
        let rng = Rng::new(cfg.seed);
        let dataset = Dataset::new(&info, cfg.seed ^ 0xD5);
        let partitioner =
            Partitioner::new(cfg.partition, info.num_classes, cfg.mean_samples);
        let shards = partitioner.assign(cfg.total_learners, cfg.seed ^ 0x9A);
        let profiles = ProfilePool::generate(cfg.total_learners, cfg.seed ^ 0x0F, cfg.hardware);
        let avail = match cfg.avail {
            AvailMode::AllAvail => Availability::All,
            AvailMode::DynAvail => Availability::Lazy(LazyTraceSet::new(
                cfg.total_learners,
                cfg.seed ^ 0x7A,
                TraceConfig::default(),
            )),
        };
        let forecasters = match &avail {
            Availability::All => ForecasterBank::new(0),
            _ => ForecasterBank::new(cfg.total_learners),
        };
        let selector = crate::selection::by_name(&cfg.selector)
            .ok_or_else(|| anyhow!("unknown selector"))?;
        let server_opt = crate::aggregation::by_name(&cfg.server_opt)
            .ok_or_else(|| anyhow!("unknown server optimizer"))?;
        let initial_mu = match cfg.mode {
            RoundMode::Deadline { deadline } => deadline,
            RoundMode::OverCommit { .. } => 100.0,
            RoundMode::Async { .. } => {
                return Err(anyhow!(
                    "the frozen reference engine predates RoundMode::Async"
                ))
            }
        };
        let apt = AdaptiveTarget::new(cfg.target_participants, cfg.apt_alpha, initial_mu);
        let global = exec.init_params(cfg.seed as i32)?;
        let test = dataset.test_set(cfg.test_per_class);
        let model_bytes = info.num_params * 4;
        Ok(ReferenceCoordinator {
            cooldown_until: vec![0; cfg.total_learners],
            busy_until: vec![0.0; cfg.total_learners],
            accounting: Accounting::default(),
            rng: rng.stream(0xC0),
            forecasters,
            selector,
            server_opt,
            apt,
            global,
            clock: Clock::default(),
            pending: DeliveryQueue::default(),
            dataset,
            shards,
            profiles,
            avail,
            test,
            model_bytes,
            exec,
            cfg,
            oracle_plan: None,
            aggregated_stale: std::collections::HashSet::new(),
        })
    }

    /// Run the configured number of rounds; returns the full result log.
    pub fn run(&mut self) -> Result<ExperimentResult> {
        let mut result = ExperimentResult {
            label: self.cfg.label.clone(),
            perplexity_metric: self.exec.variant().perplexity,
            ..Default::default()
        };
        for round in 0..self.cfg.rounds {
            let rec = self.run_round(round)?;
            result.rounds.push(rec);
        }
        // whatever is still in flight at the end never got aggregated
        let leftover: f64 = self.pending.iter().map(|(_, u)| u.spent).sum();
        self.accounting.waste(leftover);
        if let Some(last) = result.rounds.last_mut() {
            last.cum_waste_secs = self.accounting.cum_waste_secs;
        }
        Ok(result)
    }

    /// The paper's Fig. 1 sequence for one round.
    fn run_round(&mut self, round: usize) -> Result<RoundRecord> {
        let now = self.clock.now;
        let mu = self.apt.mu();
        let mut rec = RoundRecord { round, ..Default::default() };

        // ---- selection window: check-in + availability probe ------------
        let candidates = self.checked_in(round, now, mu);

        // ---- target adjustment (APT) + overcommit ------------------------
        let mut target = self.cfg.target_participants;
        if self.cfg.apt {
            let remaining: Vec<f64> = self
                .pending
                .iter()
                .map(|(deliver_at, _)| (deliver_at - now).max(0.0))
                .collect();
            target = self.apt.target(&remaining);
        }
        let n_select = match self.cfg.mode {
            RoundMode::OverCommit { factor } => {
                ((target as f64) * factor).ceil() as usize
            }
            _ => target,
        };

        let selected = if candidates.is_empty() {
            Vec::new()
        } else {
            let mut ctx = SelectionCtx {
                round,
                now,
                target: n_select,
                candidates: &candidates,
                rng: &mut self.rng,
            };
            self.selector.select(&mut ctx)
        };
        rec.selected = selected.len();

        if selected.is_empty() {
            // Nothing checked in: burn a round slot (paper: round aborted).
            let dur = mu.max(1.0);
            self.clock.advance(dur);
            self.apt.observe_round(dur);
            rec.failed = true;
            rec.round_duration = dur;
            rec.sim_time = self.clock.now;
            rec.cum_resource_secs = self.accounting.cum_resource_secs;
            rec.cum_waste_secs = self.accounting.cum_waste_secs;
            rec.unique_participants = self.accounting.unique_participants();
            return Ok(rec);
        }

        // ---- per-participant task timing ---------------------------------
        // (id, completion_secs, dropped_after) — dropped_after = Some(t) if
        // the learner leaves availability (or crashes) before finishing.
        // The fault model (scenario::faults) is threaded here exactly as in
        // the kernel engine — a sanctioned joint edit, like the train_loss
        // fix, so the equivalence suite pins the fault paths of both
        // engines as a pair.
        let faults = self.cfg.faults;
        let mut tasks: Vec<(usize, f64, Option<f64>)> = Vec::with_capacity(selected.len());
        for &id in &selected {
            if faults.flaps(id, round) {
                // fault injection: check-in flap — the task never starts
                rec.dropouts += 1;
                rec.faults += 1;
                continue;
            }
            let n_samples = self.shards[id].len();
            let t = self
                .profiles
                .get(id)
                .completion_time(n_samples, self.cfg.local_epochs, self.model_bytes);
            let mut dropped = if self.avail.available_through(id, now, t) {
                None
            } else {
                // drops out at (approximately) the end of its current session
                let mut lo = 0.0f64;
                let mut hi = t;
                for _ in 0..20 {
                    let mid = 0.5 * (lo + hi);
                    if self.avail.available_through(id, now, mid) {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                Some(lo)
            };
            if dropped.is_none() {
                if let Some(frac) = faults.crashes(id, round) {
                    // fault injection: mid-task crash, accounted like a
                    // trace dropout at the crash point
                    rec.faults += 1;
                    dropped = Some(frac * t);
                }
            }
            tasks.push((id, t, dropped));
        }

        // ---- round end ----------------------------------------------------
        let mut completions: Vec<f64> = tasks
            .iter()
            .filter(|(_, _, d)| d.is_none())
            .map(|(_, t, _)| *t)
            .collect();
        completions.sort_by(|a, b| a.total_cmp(b));
        let round_duration = match self.cfg.mode {
            RoundMode::Deadline { deadline } => {
                if self.cfg.selector == "safa" {
                    // SAFA: round ends when the target fraction reported,
                    // capped by the deadline.
                    let k = ((selected.len() as f64 * self.cfg.safa_target_ratio).ceil()
                        as usize)
                        .max(1);
                    if completions.len() >= k {
                        completions[k - 1].min(deadline)
                    } else {
                        deadline
                    }
                } else {
                    deadline
                }
            }
            _ => {
                // round ends when `target` updates have arrived
                if completions.is_empty() {
                    mu.max(1.0)
                } else if self.cfg.selector == "safa" {
                    let k = ((selected.len() as f64 * self.cfg.safa_target_ratio).ceil()
                        as usize)
                        .clamp(1, completions.len());
                    completions[k - 1]
                } else {
                    let k = target.min(completions.len());
                    completions[k - 1]
                }
            }
        };
        // selection-window/configuration floor (Fig. 1 phases); never
        // extends past a configured reporting deadline
        let floor = match self.cfg.mode {
            RoundMode::Deadline { deadline } => self.cfg.min_round_duration.min(deadline),
            _ => self.cfg.min_round_duration,
        };
        let round_duration = round_duration.max(floor);
        let round_end = now + round_duration;

        // ---- classify tasks: fresh / straggler / dropout ------------------
        let mut fresh_ids = Vec::new();
        let mut straggler_ids = Vec::new(); // complete, but after round end
        for &(id, t, dropped) in &tasks {
            match dropped {
                Some(dt) => {
                    // partial work, all wasted
                    self.accounting.spend(id, dt);
                    self.accounting.waste(dt);
                    rec.dropouts += 1;
                    self.busy_until[id] = now + dt;
                }
                None if t <= round_duration => {
                    fresh_ids.push((id, t));
                }
                None => {
                    straggler_ids.push((id, t));
                }
            }
        }

        // ---- oracle / doomed-straggler analysis ---------------------------
        // Estimated staleness if the update lands during round
        // `round + ceil((t - dur) / expected_future_round_duration)`.
        let est_round_dur = match self.cfg.mode {
            RoundMode::Deadline { deadline } => deadline,
            _ => mu.max(1.0),
        };
        // Staleness-doom analysis for the non-oracle training-skip
        // optimization: skip the SGD only when the update CERTAINLY exceeds
        // the staleness threshold (2x slack on the round-duration estimate);
        // borderline cases still train and are re-checked (and
        // waste-accounted) at delivery time, so the model trajectory is
        // unaffected either way.
        let doomed = |t: f64| -> bool {
            if !self.cfg.use_saa {
                return true; // never aggregated without SAA
            }
            match self.cfg.staleness_threshold {
                None => false,
                Some(th) => {
                    let extra = (t - round_duration).max(0.0);
                    let tau_est = (extra / est_round_dur).ceil() as usize;
                    tau_est > 2 * th + 1
                }
            }
        };

        // ---- run real local training --------------------------------------
        // Fresh participants always train. Stragglers train unless the
        // oracle knows (or conservative analysis proves) the update dies.
        // Corrupted updates are rejected by server validation at delivery,
        // so their SGD is skipped too (the model never sees the delta).
        let mut corrupted_fresh: Vec<usize> = Vec::new();
        let mut train_ids: Vec<(usize, f64, bool)> = Vec::new(); // (id, task_time, is_fresh)
        for &(id, t) in &fresh_ids {
            if faults.corrupts(id, round) {
                continue; // spend/waste accounted in the fresh spend loop
            }
            train_ids.push((id, t, true));
        }
        for &(id, t) in &straggler_ids {
            let oracle_doomed = match &self.oracle_plan {
                // SAFA+O (Fig. 2): the perfect oracle knows exactly which
                // stale updates get aggregated (the plan recorded by the
                // first pass); everything else is never even started.
                Some(plan) => !plan.contains(&(id, round)),
                None => false,
            };
            if oracle_doomed {
                // SAFA+O: the oracle prevents the learner from training at
                // all — no resources spent, nothing delivered. The learner
                // stays reserved for the same window so the system timeline
                // (selection dynamics) is identical to plain SAFA.
                self.busy_until[id] = now + t;
                continue;
            }
            self.accounting.spend(id, t);
            self.busy_until[id] = now + t;
            if faults.corrupts(id, round) {
                // fault injection: corrupted straggler update — rejected at
                // delivery, the spend is pure waste, nothing scheduled
                self.accounting.waste(t);
                rec.discarded += 1;
                rec.faults += 1;
                continue;
            }
            if doomed(t) {
                // Will certainly be discarded (no SAA, or staleness bound
                // certainly exceeded): account the waste now and skip the
                // actual SGD — the model never sees this update.
                self.accounting.waste(t);
                rec.discarded += 1;
                continue;
            }
            train_ids.push((id, t, false));
        }
        for &(id, t) in &fresh_ids {
            self.accounting.spend(id, t);
            self.busy_until[id] = now + t;
            if faults.corrupts(id, round) {
                // fault injection: corrupted fresh update — rejected at
                // delivery, full spend wasted
                self.accounting.waste(t);
                rec.discarded += 1;
                rec.faults += 1;
                corrupted_fresh.push(id);
            }
        }

        let outcomes = self.train_participants(
            &train_ids.iter().map(|&(id, _, _)| id).collect::<Vec<_>>(),
        )?;

        // ---- route updates: fresh vs pending (stale) ----------------------
        let mut fresh_updates: Vec<UpdateEntry> = Vec::new();
        let mut feedback_completed: Vec<(usize, f64, f64)> = Vec::new();
        let mut losses = Vec::new();
        for ((id, task_time, is_fresh), outcome) in train_ids.iter().zip(outcomes) {
            let outcome = outcome?;
            losses.push(outcome.mean_loss);
            if *is_fresh {
                self.accounting.aggregate(*task_time);
                feedback_completed.push((*id, outcome.stat_util, *task_time));
                fresh_updates.push(UpdateEntry {
                    learner: *id,
                    delta: outcome.delta,
                    origin_round: round,
                });
            } else {
                let mut deliver_at = now + task_time;
                if let Some(d) = faults.delays(*id, round) {
                    // fault injection: upload delayed in transit
                    rec.faults += 1;
                    deliver_at += d;
                }
                self.pending.push(
                    deliver_at,
                    PendingUpdate {
                        learner: *id,
                        delta: Some(outcome.delta),
                        origin_round: round,
                        spent: *task_time,
                        stat_util: outcome.stat_util,
                        duration: *task_time,
                    },
                );
            }
        }

        // ---- collect stale deliveries that landed during this round -------
        let mut stale_updates: Vec<UpdateEntry> = Vec::new();
        for p in self.pending.due(round_end) {
            if faults.duplicates(p.item.learner, p.item.origin_round) {
                // fault injection: duplicate delivery, deduped by the server
                rec.faults += 1;
            }
            let tau = round - p.item.origin_round;
            let within = self
                .cfg
                .staleness_threshold
                .map(|th| tau <= th)
                .unwrap_or(true);
            if self.cfg.use_saa && within {
                if let Some(delta) = p.item.delta {
                    self.accounting.aggregate(p.item.duration);
                    feedback_completed.push((
                        p.item.learner,
                        p.item.stat_util,
                        p.item.duration,
                    ));
                    self.aggregated_stale
                        .insert((p.item.learner, p.item.origin_round));
                    stale_updates.push(UpdateEntry {
                        learner: p.item.learner,
                        delta,
                        origin_round: p.item.origin_round,
                    });
                }
            } else {
                self.accounting.waste(p.item.spent);
                rec.discarded += 1;
            }
        }

        rec.fresh_updates = fresh_updates.len();
        rec.stale_updates = stale_updates.len();
        // A sanctioned post-freeze edit (see module docs): the seed
        // emitted f64::NAN here for nothing-trained rounds, which the JSON
        // writer rendered as invalid `NaN`. Both engines now record None
        // (-> JSON null), changed together so byte-equivalence still pins
        // the pair.
        rec.train_loss = if losses.is_empty() {
            None
        } else {
            Some(losses.iter().sum::<f64>() / losses.len() as f64)
        };

        // ---- aggregate + server update ------------------------------------
        if fresh_updates.is_empty() && stale_updates.is_empty() {
            rec.failed = true;
        } else {
            let outcome = merge(
                self.exec.as_ref(),
                &fresh_updates,
                &stale_updates,
                self.cfg.scaling,
                round,
            )?;
            self.server_opt.apply(&mut self.global, &outcome.delta)?;
        }

        // ---- cooldowns, feedback, clock ------------------------------------
        for (id, _, _) in &feedback_completed {
            self.cooldown_until[*id] = round + 1 + self.cfg.cooldown_rounds;
        }
        let mut missed: Vec<usize> = straggler_ids.iter().map(|&(id, _)| id).collect();
        missed.extend(corrupted_fresh);
        self.selector.feedback(&RoundFeedback {
            round,
            completed: &feedback_completed,
            missed: &missed,
            round_duration,
        });
        self.apt.observe_round(round_duration);
        self.clock.advance(round_duration);

        // ---- evaluation ------------------------------------------------------
        if (round + 1) % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds {
            let (loss, acc) = self.evaluate()?;
            rec.test_loss = Some(loss);
            rec.test_accuracy = Some(acc);
        }

        rec.round_duration = round_duration;
        rec.sim_time = self.clock.now;
        rec.cum_resource_secs = self.accounting.cum_resource_secs;
        rec.cum_waste_secs = self.accounting.cum_waste_secs;
        rec.unique_participants = self.accounting.unique_participants();
        Ok(rec)
    }

    /// Checked-in learners with their probe answers (Algorithm 1 steps 1-3).
    fn checked_in(&mut self, round: usize, now: f64, mu: f64) -> Vec<Candidate> {
        let mut out = Vec::new();
        for id in 0..self.cfg.total_learners {
            if self.cooldown_until[id] > round || self.busy_until[id] > now {
                continue;
            }
            if !self.avail.available(id, now) {
                continue;
            }
            let avail_prob = match self.cfg.avail {
                AvailMode::AllAvail => 1.0,
                AvailMode::DynAvail => {
                    // learner-side forecast for the slot (mu, 2mu)
                    self.forecaster(id).prob_slot(now + mu, now + 2.0 * mu)
                }
            };
            let expected_duration = self.profiles.get(id).completion_time(
                self.shards[id].len(),
                self.cfg.local_epochs,
                self.model_bytes,
            );
            out.push(Candidate { id, avail_prob, expected_duration });
        }
        out
    }

    /// Execute real local SGD for each participant — **strictly serial**,
    /// in ascending `ids` order. The reference engine is the oracle the
    /// pooled path must match byte-for-byte, so it deliberately keeps the
    /// simplest possible execution order with no pool in the loop.
    fn train_participants(&self, ids: &[usize]) -> Result<Vec<Result<LocalOutcome>>> {
        Ok(ids
            .iter()
            .map(|&id| {
                local_train(
                    self.exec.as_ref(),
                    &self.dataset,
                    &self.shards[id],
                    id,
                    &self.global,
                    self.cfg.lr,
                    self.cfg.local_epochs,
                    self.cfg.seed,
                )
            })
            .collect())
    }

    /// Test-set evaluation: (mean loss, top-1 accuracy).
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        evaluate_params(self.exec.as_ref(), &self.test, &self.global)
    }

    /// Terminal resource buckets `(spent, aggregated, wasted)` — mirrors
    /// [`super::Coordinator::accounting_totals`] so the fuzz harness can
    /// check the accounting identity on both engines.
    pub fn accounting_totals(&self) -> (f64, f64, f64) {
        (
            self.accounting.cum_resource_secs,
            self.accounting.cum_aggregated_secs,
            self.accounting.cum_waste_secs,
        )
    }

    /// This learner's personal forecaster, trained at first touch on (two
    /// replayed weeks of) its own trace.
    fn forecaster(&self, id: usize) -> &SeasonalForecaster {
        let avail = &self.avail;
        self.forecasters.get_or_train(id, || {
            let series = avail
                .sample_series(id, FORECAST_STEP)
                .expect("DynAvail always carries a trace");
            SeasonalForecaster::train_on_week(&series, FORECAST_STEP)
        })
    }
}

/// [`super::run_experiment`], but on the frozen pre-refactor loop. Includes
/// the SAFA+O two-pass oracle protocol, mirroring the original
/// `run_experiment` exactly.
pub fn run_reference_experiment(
    cfg: ExpConfig,
    exec: Arc<dyn Executor>,
) -> Result<ExperimentResult> {
    if cfg.oracle {
        let mut probe_cfg = cfg.clone();
        probe_cfg.oracle = false;
        let mut probe = ReferenceCoordinator::new(probe_cfg, Arc::clone(&exec))?;
        probe.run()?;
        let plan = probe.aggregated_stale;
        let mut coord = ReferenceCoordinator::new(cfg, exec)?;
        coord.oracle_plan = Some(plan);
        return coord.run();
    }
    ReferenceCoordinator::new(cfg, exec)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{builtin_variant, NativeExecutor};

    #[test]
    fn reference_rejects_async_mode() {
        let cfg = ExpConfig {
            variant: "tiny".into(),
            mode: RoundMode::Async { buffer_k: 4, max_staleness: None },
            ..Default::default()
        };
        let exec: Arc<dyn Executor> =
            Arc::new(NativeExecutor::new(builtin_variant("tiny")));
        assert!(ReferenceCoordinator::new(cfg, exec).is_err());
    }

    #[test]
    fn reference_runs_a_small_experiment() {
        let cfg = ExpConfig {
            variant: "tiny".into(),
            total_learners: 12,
            rounds: 4,
            target_participants: 3,
            mean_samples: 8,
            test_per_class: 2,
            eval_every: 2,
            lr: 0.1,
            ..Default::default()
        };
        let exec: Arc<dyn Executor> =
            Arc::new(NativeExecutor::new(builtin_variant("tiny")));
        let r = run_reference_experiment(cfg, exec).unwrap();
        assert_eq!(r.rounds.len(), 4);
    }
}
