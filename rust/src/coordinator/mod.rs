//! The FL server (L3): round engine, local-training execution through the
//! runtime, SAFA protocol variant, SAFA+O oracle, and the semi-centralized
//! baseline of Table 2.

pub mod centralized;
pub mod engine;

pub use engine::{run_experiment, run_experiment_eager, Coordinator};
