//! The FL server (L3): the event-kernel round engine (sync OC/DL sweeps +
//! the buffered-async regime), local-training execution through the
//! runtime, SAFA protocol variant, SAFA+O oracle, the frozen pre-refactor
//! reference engine (the equivalence oracle of
//! `tests/kernel_equivalence.rs`), and the semi-centralized baseline of
//! Table 2.

pub mod centralized;
pub mod engine;
pub mod reference;

mod async_engine;

pub use engine::{
    run_experiment, run_experiment_eager, run_experiment_instrumented, run_experiment_logged,
    run_experiment_observed, Coordinator,
};
pub use reference::{run_reference_experiment, ReferenceCoordinator};
