//! Metrics & resource accounting (the paper's evaluation axes):
//!
//! * **resource usage** — cumulative compute + communication seconds spent
//!   by participants, *including* work that is never aggregated (§5.2 fn 3);
//! * **resource waste** — the subset of that time spent producing updates
//!   that were NOT incorporated into the model (§3.2);
//! * **unique participants** — coverage of the learner population (Fig. 3);
//! * accuracy / loss / perplexity timeline against rounds, simulated time
//!   and resources.

use std::collections::HashSet;

use crate::util::json::{arr, num, obj, Json};
use crate::util::stats;

/// Per-round record emitted by the coordinator.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Simulated seconds since experiment start (at round end).
    pub sim_time: f64,
    pub round_duration: f64,
    pub selected: usize,
    pub fresh_updates: usize,
    pub stale_updates: usize,
    pub dropouts: usize,
    pub discarded: usize,
    /// Injected fault events observed this round (flaps, crashes, corrupted
    /// or duplicate deliveries, transit delays); 0 on fault-free runs.
    pub faults: usize,
    /// Resource-seconds consumed this round (compute + comm of everyone).
    pub resource_secs: f64,
    pub cum_resource_secs: f64,
    pub cum_waste_secs: f64,
    pub unique_participants: usize,
    pub failed: bool,
    /// Mean training loss over participants' local steps; `None` when
    /// nothing trained (failed/aborted rounds, empty merges) — serialized
    /// as JSON `null` (the seed's `NaN` here produced invalid JSON).
    pub train_loss: Option<f64>,
    /// Test metrics, present on eval rounds.
    pub test_accuracy: Option<f64>,
    pub test_loss: Option<f64>,
    // ---- async (buffered) regime accounting; None on OC/DL records ------
    /// Time-averaged number of in-flight tasks over this merge interval.
    pub mean_concurrency: Option<f64>,
    /// Device-seconds whose updates were merged into the model so far.
    pub cum_aggregated_secs: Option<f64>,
    /// Device-seconds spent but neither aggregated nor wasted yet (running
    /// tasks + buffered unmerged updates) at record time.
    pub in_flight_secs: Option<f64>,
    /// Kernel events processed during this merge interval.
    pub kernel_events: Option<usize>,
}

/// Running accounting state. In the async regime every spent device-second
/// ends up in exactly one of two terminal buckets — aggregated or wasted —
/// with the difference `spent - aggregated - wasted` being the work still
/// in flight (tests/substrate_props.rs asserts the identity).
#[derive(Default)]
pub struct Accounting {
    pub cum_resource_secs: f64,
    pub cum_waste_secs: f64,
    /// Device-seconds whose updates were merged into the model (maintained
    /// by the async engine; the sync engines leave it 0).
    pub cum_aggregated_secs: f64,
    unique: HashSet<usize>,
}

impl Accounting {
    /// Record that `learner` spent `secs` of device time training/uploading.
    pub fn spend(&mut self, learner: usize, secs: f64) {
        self.cum_resource_secs += secs;
        self.unique.insert(learner);
    }

    /// Record that `secs` of previously-spent time turned out wasted
    /// (update dropped, discarded, or never aggregated).
    pub fn waste(&mut self, secs: f64) {
        self.cum_waste_secs += secs;
    }

    /// Record that `secs` of previously-spent time produced an update that
    /// was merged into the model (async per-event accounting).
    pub fn aggregate(&mut self, secs: f64) {
        self.cum_aggregated_secs += secs;
    }

    pub fn unique_participants(&self) -> usize {
        self.unique.len()
    }
}

/// Full result of one experiment run.
#[derive(Clone, Debug, Default)]
pub struct ExperimentResult {
    pub label: String,
    pub rounds: Vec<RoundRecord>,
    /// Variant reports perplexity instead of accuracy.
    pub perplexity_metric: bool,
}

impl ExperimentResult {
    pub fn final_accuracy(&self) -> Option<f64> {
        self.rounds.iter().rev().find_map(|r| r.test_accuracy)
    }

    pub fn final_resource_hours(&self) -> f64 {
        self.rounds.last().map(|r| r.cum_resource_secs / 3600.0).unwrap_or(0.0)
    }

    pub fn final_sim_time(&self) -> f64 {
        self.rounds.last().map(|r| r.sim_time).unwrap_or(0.0)
    }

    pub fn waste_fraction(&self) -> f64 {
        let r = self.rounds.last().map(|r| r.cum_resource_secs).unwrap_or(0.0);
        let w = self.rounds.last().map(|r| r.cum_waste_secs).unwrap_or(0.0);
        if r > 0.0 {
            w / r
        } else {
            0.0
        }
    }

    /// Mean of the per-round `mean_concurrency` values; `None` unless this
    /// was an async (buffered) run.
    pub fn mean_concurrency(&self) -> Option<f64> {
        let concs: Vec<f64> =
            self.rounds.iter().filter_map(|r| r.mean_concurrency).collect();
        if concs.is_empty() {
            None
        } else {
            Some(concs.iter().sum::<f64>() / concs.len() as f64)
        }
    }

    /// Device-hours whose updates were merged into the model (async runs).
    pub fn final_aggregated_hours(&self) -> Option<f64> {
        self.rounds
            .last()
            .and_then(|r| r.cum_aggregated_secs)
            .map(|s| s / 3600.0)
    }

    /// First (sim_time, resource_hours) at which test accuracy reached `acc`.
    pub fn time_to_accuracy(&self, acc: f64) -> Option<(f64, f64)> {
        self.rounds.iter().find_map(|r| {
            r.test_accuracy
                .filter(|&a| a >= acc)
                .map(|_| (r.sim_time, r.cum_resource_secs / 3600.0))
        })
    }

    /// (resource_hours, accuracy) series — the x/y of most paper figures.
    pub fn accuracy_vs_resources(&self) -> Vec<(f64, f64)> {
        self.rounds
            .iter()
            .filter_map(|r| r.test_accuracy.map(|a| (r.cum_resource_secs / 3600.0, a)))
            .collect()
    }

    /// (round, accuracy) series (Fig. 9/10 style).
    pub fn accuracy_vs_rounds(&self) -> Vec<(usize, f64)> {
        self.rounds
            .iter()
            .filter_map(|r| r.test_accuracy.map(|a| (r.round, a)))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("perplexity_metric", Json::Bool(self.perplexity_metric)),
            (
                "rounds",
                arr(self.rounds.iter().map(|r| {
                    obj(vec![
                        ("round", num(r.round as f64)),
                        ("sim_time", num(r.sim_time)),
                        ("round_duration", num(r.round_duration)),
                        ("selected", num(r.selected as f64)),
                        ("fresh", num(r.fresh_updates as f64)),
                        ("stale", num(r.stale_updates as f64)),
                        ("dropouts", num(r.dropouts as f64)),
                        ("discarded", num(r.discarded as f64)),
                        ("faults", num(r.faults as f64)),
                        ("resource_secs", num(r.resource_secs)),
                        ("cum_resource_secs", num(r.cum_resource_secs)),
                        ("cum_waste_secs", num(r.cum_waste_secs)),
                        ("unique", num(r.unique_participants as f64)),
                        ("failed", Json::Bool(r.failed)),
                        ("train_loss", r.train_loss.map(num).unwrap_or(Json::Null)),
                        (
                            "test_accuracy",
                            r.test_accuracy.map(num).unwrap_or(Json::Null),
                        ),
                        ("test_loss", r.test_loss.map(num).unwrap_or(Json::Null)),
                        (
                            "mean_concurrency",
                            r.mean_concurrency.map(num).unwrap_or(Json::Null),
                        ),
                        (
                            "cum_aggregated_secs",
                            r.cum_aggregated_secs.map(num).unwrap_or(Json::Null),
                        ),
                        (
                            "in_flight_secs",
                            r.in_flight_secs.map(num).unwrap_or(Json::Null),
                        ),
                        (
                            "kernel_events",
                            r.kernel_events.map(|e| num(e as f64)).unwrap_or(Json::Null),
                        ),
                    ])
                })),
            ),
        ])
    }

    /// Compact human-readable summary line (figure harness output).
    pub fn summary(&self) -> String {
        format!(
            "{:<28} rounds={:<5} time={:>9.0}s resources={:>8.2}h waste={:>5.1}% unique={:<5} acc={}",
            self.label,
            self.rounds.len(),
            self.final_sim_time(),
            self.final_resource_hours(),
            100.0 * self.waste_fraction(),
            self.rounds.last().map(|r| r.unique_participants).unwrap_or(0),
            self.final_accuracy()
                .map(|a| format!("{:.1}%", 100.0 * a))
                .unwrap_or_else(|| "n/a".into()),
        )
    }
}

/// Aggregated record for one sweep-grid cell (selector × round-mode ×
/// availability × partition), summarizing the paper's evaluation axes
/// across its seeds. Accuracy statistics are over the runs that reached at
/// least one eval round (`None` when none did — e.g. every round failed).
#[derive(Clone, Debug, Default)]
pub struct CellSummary {
    pub label: String,
    pub selector: String,
    pub mode: String,
    pub avail: String,
    pub partition: String,
    /// Number of runs (seeds) aggregated into this cell.
    pub seeds: usize,
    pub mean_accuracy: Option<f64>,
    pub std_accuracy: Option<f64>,
    pub mean_resource_hours: f64,
    pub std_resource_hours: f64,
    pub mean_waste_fraction: f64,
    pub mean_sim_time: f64,
    pub mean_unique_participants: f64,
    /// Total failed rounds across all seeds (availability churn signal).
    pub failed_rounds: usize,
}

impl CellSummary {
    /// Aggregate one cell's per-seed results. Axis fields (`selector`,
    /// `mode`, ...) are left empty for the caller to fill in.
    pub fn from_results(label: impl Into<String>, results: &[ExperimentResult]) -> CellSummary {
        let accs: Vec<f64> = results.iter().filter_map(|r| r.final_accuracy()).collect();
        let res: Vec<f64> = results.iter().map(|r| r.final_resource_hours()).collect();
        let waste: Vec<f64> = results.iter().map(|r| r.waste_fraction()).collect();
        let sim: Vec<f64> = results.iter().map(|r| r.final_sim_time()).collect();
        let uniq: Vec<f64> = results
            .iter()
            .map(|r| r.rounds.last().map(|x| x.unique_participants).unwrap_or(0) as f64)
            .collect();
        CellSummary {
            label: label.into(),
            seeds: results.len(),
            mean_accuracy: (!accs.is_empty()).then(|| stats::mean(&accs)),
            std_accuracy: (!accs.is_empty()).then(|| stats::std_dev(&accs)),
            mean_resource_hours: stats::mean(&res),
            std_resource_hours: stats::std_dev(&res),
            mean_waste_fraction: stats::mean(&waste),
            mean_sim_time: stats::mean(&sim),
            mean_unique_participants: stats::mean(&uniq),
            failed_rounds: results
                .iter()
                .map(|r| r.rounds.iter().filter(|x| x.failed).count())
                .sum(),
            ..Default::default()
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("selector", Json::Str(self.selector.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("avail", Json::Str(self.avail.clone())),
            ("partition", Json::Str(self.partition.clone())),
            ("seeds", num(self.seeds as f64)),
            ("mean_accuracy", self.mean_accuracy.map(num).unwrap_or(Json::Null)),
            ("std_accuracy", self.std_accuracy.map(num).unwrap_or(Json::Null)),
            ("mean_resource_hours", num(self.mean_resource_hours)),
            ("std_resource_hours", num(self.std_resource_hours)),
            ("mean_waste_fraction", num(self.mean_waste_fraction)),
            ("mean_sim_time", num(self.mean_sim_time)),
            (
                "mean_unique_participants",
                num(self.mean_unique_participants),
            ),
            ("failed_rounds", num(self.failed_rounds as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_with(rounds: Vec<RoundRecord>) -> ExperimentResult {
        ExperimentResult { label: "t".into(), rounds, perplexity_metric: false }
    }

    fn rr(round: usize, cum_res: f64, acc: Option<f64>) -> RoundRecord {
        RoundRecord {
            round,
            sim_time: 100.0 * (round + 1) as f64,
            cum_resource_secs: cum_res,
            test_accuracy: acc,
            ..Default::default()
        }
    }

    #[test]
    fn accounting_tracks_unique_and_waste() {
        let mut a = Accounting::default();
        a.spend(1, 10.0);
        a.spend(1, 5.0);
        a.spend(2, 10.0);
        a.waste(5.0);
        assert_eq!(a.unique_participants(), 2);
        assert_eq!(a.cum_resource_secs, 25.0);
        assert_eq!(a.cum_waste_secs, 5.0);
    }

    #[test]
    fn accounting_tracks_aggregated_bucket() {
        let mut a = Accounting::default();
        a.spend(1, 10.0);
        a.spend(2, 4.0);
        a.aggregate(10.0);
        a.waste(4.0);
        assert_eq!(a.cum_aggregated_secs, 10.0);
        // every spent second landed in a terminal bucket
        assert_eq!(a.cum_resource_secs, a.cum_aggregated_secs + a.cum_waste_secs);
    }

    #[test]
    fn async_fields_serialize_and_default_to_null() {
        // sync-style record: async fields absent -> null in JSON
        let sync_rec = rr(0, 10.0, None);
        let j = result_with(vec![sync_rec]).to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        let r0 = parsed.get("rounds").unwrap().idx(0).unwrap();
        assert_eq!(r0.get("mean_concurrency"), Some(&Json::Null));
        assert_eq!(r0.get("cum_aggregated_secs"), Some(&Json::Null));
        assert_eq!(r0.get("in_flight_secs"), Some(&Json::Null));
        assert_eq!(r0.get("kernel_events"), Some(&Json::Null));

        // async-style record: values survive the JSON writer
        let mut async_rec = rr(0, 10.0, Some(0.5));
        async_rec.mean_concurrency = Some(3.5);
        async_rec.cum_aggregated_secs = Some(7.0);
        async_rec.in_flight_secs = Some(2.0);
        async_rec.kernel_events = Some(11);
        let r = result_with(vec![async_rec]);
        assert_eq!(r.mean_concurrency(), Some(3.5));
        assert!((r.final_aggregated_hours().unwrap() - 7.0 / 3600.0).abs() < 1e-12);
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        let r0 = parsed.get("rounds").unwrap().idx(0).unwrap();
        assert_eq!(r0.get("mean_concurrency").unwrap().as_f64(), Some(3.5));
        assert_eq!(r0.get("kernel_events").unwrap().as_usize(), Some(11));
    }

    #[test]
    fn train_loss_serializes_as_null_when_nothing_trained() {
        // regression: the seed wrote f64::NAN here, which is invalid JSON
        let mut failed = rr(0, 10.0, None);
        failed.failed = true;
        let mut trained = rr(1, 20.0, None);
        trained.train_loss = Some(1.25);
        let j = result_with(vec![failed, trained]).to_json().to_string();
        assert!(!j.contains("NaN"), "{j}");
        let parsed = Json::parse(&j).unwrap();
        let rounds = parsed.get("rounds").unwrap();
        assert_eq!(rounds.idx(0).unwrap().get("train_loss"), Some(&Json::Null));
        assert_eq!(
            rounds.idx(1).unwrap().get("train_loss").unwrap().as_f64(),
            Some(1.25)
        );
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let r = result_with(vec![
            rr(0, 100.0, Some(0.2)),
            rr(1, 200.0, Some(0.5)),
            rr(2, 300.0, Some(0.9)),
        ]);
        let (t, res) = r.time_to_accuracy(0.5).unwrap();
        assert_eq!(t, 200.0);
        assert!((res - 200.0 / 3600.0).abs() < 1e-12);
        assert!(r.time_to_accuracy(0.95).is_none());
    }

    #[test]
    fn final_metrics() {
        let r = result_with(vec![rr(0, 100.0, None), rr(1, 300.0, Some(0.7))]);
        assert_eq!(r.final_accuracy(), Some(0.7));
        assert!((r.final_resource_hours() - 300.0 / 3600.0).abs() < 1e-12);
        assert_eq!(r.accuracy_vs_resources().len(), 1);
        assert_eq!(r.accuracy_vs_rounds(), vec![(1, 0.7)]);
    }

    #[test]
    fn waste_fraction_guards_zero() {
        let r = result_with(vec![]);
        assert_eq!(r.waste_fraction(), 0.0);
    }

    #[test]
    fn json_roundtrips() {
        let r = result_with(vec![rr(0, 50.0, Some(0.4))]);
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("t"));
        let rounds = parsed.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 1);
        assert_eq!(rounds[0].get("test_accuracy").unwrap().as_f64(), Some(0.4));
    }

    #[test]
    fn cell_summary_aggregates_across_seeds() {
        let a = result_with(vec![rr(0, 3600.0, Some(0.4))]);
        let b = result_with(vec![rr(0, 7200.0, Some(0.6))]);
        let s = CellSummary::from_results("cell", &[a, b]);
        assert_eq!(s.seeds, 2);
        assert!((s.mean_accuracy.unwrap() - 0.5).abs() < 1e-12);
        assert!((s.std_accuracy.unwrap() - 0.1).abs() < 1e-12);
        assert!((s.mean_resource_hours - 1.5).abs() < 1e-12);
        let j = s.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("seeds").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("mean_accuracy").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn cell_summary_without_evals_has_null_accuracy() {
        let r = result_with(vec![rr(0, 100.0, None)]);
        let s = CellSummary::from_results("no-eval", &[r]);
        assert!(s.mean_accuracy.is_none());
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("mean_accuracy"), Some(&Json::Null));
    }

    #[test]
    fn summary_contains_key_numbers() {
        let r = result_with(vec![rr(0, 3600.0, Some(0.5))]);
        let s = r.summary();
        assert!(s.contains("1.00h"), "{s}");
        assert!(s.contains("50.0%"), "{s}");
    }
}
