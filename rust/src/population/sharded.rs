//! Sharded coordination: the K-way partition of the coordination hot path.
//!
//! The registry, the availability index, and the eligible set all split the
//! id space into the same K contiguous ranges (one [`ShardPlan`]). Each
//! coordinator shard owns every per-learner transition inside its range —
//! availability flips (its own event kernel), cooldown expiries, and busy
//! expiries — so [`crate::population::Population::sync_to`] becomes a
//! **two-phase** pass:
//!
//! 1. **parallel delta pass** ([`sync_shards_parallel`]): every shard, on
//!    the worker pool, drains its due transitions and applies its
//!    eligibility predicate through a disjoint mutable view of the eligible
//!    [`CandidateSet`], emitting the `(id, now_eligible)` transitions it
//!    caused;
//! 2. **serial hook pass** ([`forward_transitions`]): the per-shard
//!    transition lists are forwarded to the selector's
//!    `on_eligible`/`on_ineligible` hooks in **fixed shard-major order**.
//!
//! The contract that makes this sound is the same shard-invariance
//! discipline [`CandidateSet`] and [`crate::selection::index::ScoreIndex`]
//! already obey: selector hook state is a pure function of each id's final
//! membership (never of cross-id hook order), so reordering transitions
//! *across* shards cannot change results, while each id's transitions keep
//! their relative order because an id lives in exactly one shard. K = 1 is
//! the flat path; `run_experiment` output is byte-identical for any K
//! (`tests/coord_shard_props.rs`, the fuzzer's coord-shards axis, and the
//! CI record/replay `cmp` pin this).

use std::collections::BTreeMap;

use crate::selection::Selector;
use crate::util::threadpool;

use super::avail_index::AvailabilityIndex;
use super::candidate_set::{CandidateSet, ShardViewMut};
use super::registry::Registry;

/// The shared contiguous id-range partition: `K` shards of `shard_size`
/// ids each (the last may be shorter). Mirrors the layout formula of
/// [`CandidateSet::with_shards`] and [`Registry::eager`], so one plan
/// addresses every sharded structure consistently.
#[derive(Clone, Copy, Debug)]
pub struct ShardPlan {
    n: usize,
    shard_size: usize,
    count: usize,
}

impl ShardPlan {
    /// Partition ids `0..n` into (at most) `num_shards` contiguous ranges.
    pub fn new(n: usize, num_shards: usize) -> ShardPlan {
        let shard_size = n.div_ceil(num_shards.max(1)).max(1);
        let count = n.div_ceil(shard_size).max(1);
        ShardPlan { n, shard_size, count }
    }

    /// Effective number of shards (after clamping to the population size).
    pub fn count(&self) -> usize {
        self.count
    }

    /// Ids per shard (the last shard may cover fewer).
    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    /// The shard owning `id`.
    pub fn owner(&self, id: usize) -> usize {
        id / self.shard_size
    }

    /// The id range shard `s` owns.
    pub fn range(&self, s: usize) -> std::ops::Range<usize> {
        let lo = s * self.shard_size;
        lo..(lo + self.shard_size).min(self.n)
    }
}

/// One shard's expiry schedules: the re-admission buckets this shard owns
/// for its id range. Entries can go stale when a cooldown/busy deadline is
/// re-set; the drain re-checks the registry, so stale entries are harmless.
#[derive(Default)]
pub(crate) struct ShardBuckets {
    /// cooldown_until value -> learners parked until that round.
    pub(crate) cooldown: BTreeMap<usize, Vec<usize>>,
    /// busy_until (as order-preserving f64 bits) -> learners busy until
    /// that time.
    pub(crate) busy: BTreeMap<u64, Vec<usize>>,
}

/// One shard's sync outcome: the eligible-set transitions it applied, in
/// the order it applied them.
pub(crate) type ShardTransitions = Vec<(usize, bool)>;

/// Drain one shard's due work — availability flips, then cooldown expiries
/// (ascending round key), then busy expiries (ascending time key), the same
/// intra-shard order the flat path used globally — re-evaluating the
/// eligibility predicate per touched id against this shard's disjoint
/// membership view. Pure per-shard: reads only the touched ids' own state.
fn sync_shard(
    view: &mut ShardViewMut<'_>,
    buckets: &mut ShardBuckets,
    flips: &[(usize, bool)],
    index: &AvailabilityIndex,
    registry: &Registry,
    round: usize,
    now: f64,
) -> ShardTransitions {
    let mut out = Vec::new();
    let mut refresh = |view: &mut ShardViewMut<'_>, out: &mut ShardTransitions, id: usize| {
        let ok = index.is_available(id)
            && registry.busy_until(id) <= now
            && registry.cooldown_until(id) <= round;
        let changed = if ok { view.insert(id) } else { view.remove(id) };
        if changed {
            out.push((id, ok));
        }
    };
    for &(id, _) in flips {
        refresh(view, &mut out, id);
    }
    loop {
        let Some((&k, _)) = buckets.cooldown.first_key_value() else { break };
        if k > round {
            break;
        }
        let (_, ids) = buckets.cooldown.pop_first().expect("non-empty first key");
        for id in ids {
            refresh(view, &mut out, id);
        }
    }
    // busy_until stored as order-preserving bits of a non-negative f64
    let now_bits = now.to_bits();
    loop {
        let Some((&k, _)) = buckets.busy.first_key_value() else { break };
        if k > now_bits {
            break;
        }
        let (_, ids) = buckets.busy.pop_first().expect("non-empty first key");
        for id in ids {
            refresh(view, &mut out, id);
        }
    }
    out
}

/// Phase 1 of the sharded `sync_to`: run every shard's delta pass in
/// parallel on the worker pool. `flips` is the per-shard flip grouping from
/// [`AvailabilityIndex::advance_to_sharded`] (empty under AllAvail). Each
/// shard mutates only its own bucket state and its disjoint view of the
/// eligible set; the result (per-shard transition lists, shard-major) is
/// deterministic for any worker count.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sync_shards_parallel(
    set: &mut CandidateSet,
    buckets: &mut [ShardBuckets],
    flips: &[Vec<(usize, bool)>],
    index: &AvailabilityIndex,
    registry: &Registry,
    round: usize,
    now: f64,
    workers: usize,
) -> Vec<ShardTransitions> {
    let views = set.shard_views_mut();
    debug_assert_eq!(views.len(), buckets.len(), "bucket/shard layout mismatch");
    let jobs: Vec<_> = views
        .into_iter()
        .zip(buckets.iter_mut())
        .enumerate()
        .map(|(si, (mut view, shard_buckets))| {
            let shard_flips: &[(usize, bool)] =
                flips.get(si).map(|v| v.as_slice()).unwrap_or(&[]);
            move || {
                sync_shard(&mut view, shard_buckets, shard_flips, index, registry, round, now)
            }
        })
        .collect();
    let transitions = threadpool::run_parallel(workers, jobs);
    set.rebuild_len();
    transitions
}

/// Phase 2 of the sharded `sync_to`: forward every transition to the
/// selector hooks in fixed shard-major order (shards ascending, each
/// shard's transitions in the order it applied them).
pub(crate) fn forward_transitions(transitions: &[ShardTransitions], sel: &mut dyn Selector) {
    for group in transitions {
        for &(id, on) in group {
            if on {
                sel.on_eligible(id);
            } else {
                sel.on_ineligible(id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_partitions_the_id_space() {
        let plan = ShardPlan::new(100, 7);
        assert_eq!(plan.shard_size(), 15);
        assert_eq!(plan.count(), 7);
        let mut covered = 0usize;
        for s in 0..plan.count() {
            let r = plan.range(s);
            for id in r.clone() {
                assert_eq!(plan.owner(id), s, "id {id}");
            }
            covered += r.len();
        }
        assert_eq!(covered, 100);
    }

    #[test]
    fn plan_clamps_to_population_size() {
        let plan = ShardPlan::new(3, 16);
        assert_eq!(plan.count(), 3);
        assert_eq!(plan.shard_size(), 1);
        let one = ShardPlan::new(0, 4);
        assert_eq!(one.count(), 1);
        assert!(one.range(0).is_empty());
    }

    #[test]
    fn plan_matches_candidate_set_layout() {
        for (n, k) in [(1000usize, 1usize), (1000, 8), (1000, 13), (17, 4), (64, 64)] {
            let plan = ShardPlan::new(n, k);
            let set = CandidateSet::with_shards(n, k);
            assert_eq!(plan.count(), set.num_shards(), "n={n} k={k}");
            assert_eq!(plan.shard_size(), set.shard_size(), "n={n} k={k}");
        }
    }
}
