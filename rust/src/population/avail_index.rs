//! The availability index: *who is available*, maintained incrementally.
//!
//! The pre-population engines rediscovered availability by scanning all
//! `total_learners` trace queries on every selection — O(n) per event, the
//! ROADMAP's scaling blocker. This index instead turns each learner's
//! weekly charging sessions into a stream of **availability-transition
//! events** on the existing discrete-event substrate
//! ([`crate::sim::EventKernel`], class [`EventClass::Availability`]): one
//! pending transition per learner, popped and re-armed as the simulation
//! clock advances. Between transitions a learner's availability is constant,
//! so the maintained [`CandidateSet`] equals a brute-force
//! `Availability::available(id, now)` scan at every advance point
//! (`tests/population_props.rs` checks this against randomized traces and
//! advance orders), while the per-advance cost is O(transitions due ·
//! log n) instead of O(n).
//!
//! **Sharded advance**: the event stream is partitioned into one kernel per
//! contiguous id-range shard (the same ranges as the [`CandidateSet`]'s
//! shards), because a learner's transitions depend only on its own trace —
//! so all K shards advance **in parallel** on the worker pool, each owning
//! its kernel, its cursor slice, and its disjoint membership-shard view.
//! A shard's flip sequence is exactly the flat (single-kernel) flip
//! sequence filtered to its ids, so `advance_to_sharded` is deterministic
//! for any worker count and the concatenated (shard-major) stream drives
//! results that are byte-identical for any shard count.
//!
//! Construction is lazy: a DynAvail index does **no** trace work until its
//! first `advance_to`, preserving the coordinator's construct-without-
//! materializing guarantee (`tests/lazy_equivalence.rs`). The first advance
//! materializes every learner's trace — exactly what the first full scan
//! used to do — optionally in parallel on the worker pool (trace generation
//! is a pure per-learner function, so worker count never changes results).

use crate::sim::{Availability, EventClass, EventKernel};
use crate::trace::WEEK;
use crate::util::threadpool;

use super::candidate_set::{CandidateSet, ShardViewMut};

/// Per-learner replay position: the next boundary index within the weekly
/// schedule, and which week replay we are in.
#[derive(Clone, Copy)]
struct Cursor {
    k: u32,
    week: u32,
}

struct IndexState {
    /// Learners available at the last advance point, in id order.
    set: CandidateSet,
    /// One pending transition event per learner (payload = learner id),
    /// partitioned into one kernel per membership shard.
    kernels: Vec<EventKernel<u32>>,
    cursors: Vec<Cursor>,
}

/// Incremental availability view over an [`Availability`] (see module docs).
pub struct AvailabilityIndex {
    avail: Availability,
    n: usize,
    num_shards: usize,
    state: Option<IndexState>,
}

/// One learner's weekly availability boundaries, derived on the fly from
/// its sorted, non-overlapping session list (no extra storage): session
/// starts flip availability on, session ends flip it off, and a final
/// boundary at `WEEK` re-applies the week-start state (handling sessions
/// clipped at the week edge and the cyclic replay).
struct Bounds<'a> {
    s: &'a [(f64, f64)],
    skip_first: bool,
    skip_last: bool,
    state0: bool,
}

impl<'a> Bounds<'a> {
    fn new(s: &'a [(f64, f64)]) -> Bounds<'a> {
        let m = s.len();
        // first session starting at 0 means the week begins mid-session:
        // its "start" boundary is the WEEK event of the previous replay
        let skip_first = m > 0 && s[0].0 <= 0.0;
        let skip_last = m > 0 && s[m - 1].1 >= WEEK;
        Bounds { s, skip_first, skip_last, state0: skip_first }
    }

    /// Number of boundaries per week, including the final WEEK event.
    fn count(&self) -> usize {
        if self.s.is_empty() {
            0
        } else {
            2 * self.s.len() - self.skip_first as usize - self.skip_last as usize + 1
        }
    }

    /// The `k`-th boundary as (time-in-week, availability-after).
    fn get(&self, k: usize) -> (f64, bool) {
        let vidx = k + self.skip_first as usize;
        let real = 2 * self.s.len() - self.skip_last as usize;
        if vidx < real {
            let j = vidx / 2;
            if vidx % 2 == 0 {
                (self.s[j].0, true)
            } else {
                (self.s[j].1, false)
            }
        } else {
            (WEEK, self.state0)
        }
    }

    /// Smallest `k` whose boundary time is strictly after `tw`. Always
    /// exists for non-empty schedules (the WEEK event is > any `tw < WEEK`).
    fn first_after(&self, tw: f64) -> usize {
        let mut lo = 0usize;
        let mut hi = self.count() - 1; // the WEEK event always qualifies
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.get(mid).0 > tw {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }
}

fn sessions_of(avail: &Availability, id: usize) -> &[(f64, f64)] {
    match avail {
        Availability::All => &[],
        Availability::Dynamic(tr) => &tr.sessions[id],
        Availability::Lazy(tr) => tr.sessions(id),
    }
}

/// Drain one shard's due transitions: pop its kernel while events are due,
/// flip membership through the shard's disjoint view, and re-arm each
/// learner's next boundary. `lo` is the shard's first global id. Returns the
/// shard's flips — exactly the flat flip stream filtered to this id range.
fn advance_shard(
    avail: &Availability,
    kernel: &mut EventKernel<u32>,
    cursors: &mut [Cursor],
    view: &mut ShardViewMut<'_>,
    lo: usize,
    now: f64,
) -> Vec<(usize, bool)> {
    let mut flips = Vec::new();
    while kernel.peek_at().map(|t| t <= now).unwrap_or(false) {
        let ev = kernel.pop_next().expect("peeked event exists");
        let id = ev.payload as usize;
        let s = sessions_of(avail, id);
        let b = Bounds::new(s);
        let cur = cursors[id - lo];
        let (_, on) = b.get(cur.k as usize);
        let changed = if on { view.insert(id) } else { view.remove(id) };
        if changed {
            flips.push((id, on));
        }
        // re-arm this learner's next transition
        let mut k = cur.k as usize + 1;
        let mut week = cur.week;
        if k >= b.count() {
            k = 0;
            week += 1;
        }
        cursors[id - lo] = Cursor { k: k as u32, week };
        let at = week as f64 * WEEK + b.get(k).0;
        kernel.schedule(at, EventClass::Availability, id as u32);
    }
    flips
}

impl AvailabilityIndex {
    /// Wrap an availability view for `n` learners. Does no trace work —
    /// DynAvail indexes build at first `advance_to` (see module docs).
    pub fn new(avail: Availability, n: usize, num_shards: usize) -> AvailabilityIndex {
        AvailabilityIndex { avail, n, num_shards, state: None }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The wrapped availability view (for direct interval queries like
    /// `available_through`, which stay on the trace itself).
    pub fn availability(&self) -> &Availability {
        &self.avail
    }

    /// True under `Availability::All` (every learner, always available).
    pub fn all_mode(&self) -> bool {
        matches!(self.avail, Availability::All)
    }

    /// Has the transition schedule been built yet (trace modes only)?
    pub fn built(&self) -> bool {
        self.state.is_some()
    }

    /// Apply every availability transition due at or before `now`; returns
    /// the learners whose availability actually flipped, as `(id, now_on)`,
    /// grouped per shard (shard-major, each shard's flips in its event
    /// order). Shards advance in parallel when `workers > 1`; the result is
    /// identical at any worker count. Builds the index on first call.
    pub fn advance_to_sharded(&mut self, now: f64, workers: usize) -> Vec<Vec<(usize, bool)>> {
        if matches!(self.avail, Availability::All) {
            return Vec::new();
        }
        if self.state.is_none() {
            self.build(now, workers);
        }
        let st = self.state.as_mut().expect("index built above");
        let shard_size = st.set.shard_size();
        let avail = &self.avail;
        let views = st.set.shard_views_mut();
        let mut jobs = Vec::with_capacity(views.len());
        let mut cursors_rest: &mut [Cursor] = &mut st.cursors;
        for ((si, mut view), kernel) in views.into_iter().enumerate().zip(st.kernels.iter_mut())
        {
            let take = cursors_rest.len().min(shard_size);
            let (chunk, rest) = cursors_rest.split_at_mut(take);
            cursors_rest = rest;
            let lo = si * shard_size;
            jobs.push(move || advance_shard(avail, kernel, chunk, &mut view, lo, now));
        }
        let flips = threadpool::run_parallel(workers, jobs);
        st.set.rebuild_len();
        flips
    }

    /// Flat view of [`AvailabilityIndex::advance_to_sharded`]: the per-shard
    /// flip groups concatenated in shard-major order.
    pub fn advance_to(&mut self, now: f64, workers: usize) -> Vec<(usize, bool)> {
        self.advance_to_sharded(now, workers).into_iter().flatten().collect()
    }

    /// Is the learner available as of the last `advance_to` point? Trace
    /// modes require the index to be built (advance first).
    pub fn is_available(&self, id: usize) -> bool {
        match (&self.avail, &self.state) {
            (Availability::All, _) => true,
            (_, Some(st)) => st.set.contains(id),
            (_, None) => panic!("availability index queried before first advance_to"),
        }
    }

    /// Number of learners available at the last advance point (`n` under
    /// AllAvail).
    pub fn available_count(&self) -> usize {
        match (&self.avail, &self.state) {
            (Availability::All, _) => self.n,
            (_, Some(st)) => st.set.len(),
            (_, None) => 0,
        }
    }

    /// Visit every available learner in ascending id order.
    pub fn for_each_available(&self, mut f: impl FnMut(usize)) {
        match (&self.avail, &self.state) {
            (Availability::All, _) => (0..self.n).for_each(f),
            (_, Some(st)) => st.set.iter().for_each(&mut f),
            (_, None) => panic!("availability index iterated before first advance_to"),
        }
    }

    /// One-time build: materialize every learner's sessions (in parallel
    /// when `workers > 1` — pure per-learner work, result-identical at any
    /// worker count), seed the available set from exact trace queries at
    /// `now`, and arm one transition event per learner in its shard kernel.
    fn build(&mut self, now: f64, workers: usize) {
        if let Availability::Lazy(tr) = &self.avail {
            if workers > 1 && self.n > 1 {
                let chunk = self.n.div_ceil(workers * 4).max(256);
                let jobs: Vec<_> = (0..self.n)
                    .step_by(chunk)
                    .map(|start| {
                        let end = (start + chunk).min(self.n);
                        move || {
                            for id in start..end {
                                tr.sessions(id);
                            }
                        }
                    })
                    .collect();
                threadpool::run_parallel(workers, jobs);
            }
        }
        let tw = now.rem_euclid(WEEK);
        let week = (now / WEEK).floor().max(0.0) as u32;
        let mut set = CandidateSet::with_shards(self.n, self.num_shards);
        let shard_size = set.shard_size();
        let mut kernels: Vec<EventKernel<u32>> =
            (0..set.num_shards()).map(|_| EventKernel::default()).collect();
        let mut cursors = Vec::with_capacity(self.n);
        for id in 0..self.n {
            if self.avail.available(id, now) {
                set.insert(id);
            }
            let s = sessions_of(&self.avail, id);
            let b = Bounds::new(s);
            if b.count() == 0 {
                // never-available learner: no transitions, stays out forever
                cursors.push(Cursor { k: 0, week });
                continue;
            }
            let k = b.first_after(tw);
            cursors.push(Cursor { k: k as u32, week });
            let at = week as f64 * WEEK + b.get(k).0;
            kernels[id / shard_size].schedule(at, EventClass::Availability, id as u32);
        }
        self.state = Some(IndexState { set, kernels, cursors });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{LazyTraceSet, TraceConfig, TraceSet};

    fn brute_force(avail: &Availability, n: usize, t: f64) -> Vec<usize> {
        (0..n).filter(|&id| avail.available(id, t)).collect()
    }

    fn collect(idx: &AvailabilityIndex) -> Vec<usize> {
        let mut v = Vec::new();
        idx.for_each_available(|id| v.push(id));
        v
    }

    #[test]
    fn all_mode_is_trivial() {
        let mut idx = AvailabilityIndex::new(Availability::All, 5, 2);
        assert!(idx.all_mode());
        assert!(idx.advance_to(1000.0, 1).is_empty());
        assert!(idx.is_available(3));
        assert_eq!(collect(&idx), vec![0, 1, 2, 3, 4]);
        assert_eq!(idx.available_count(), 5);
        assert!(!idx.built());
    }

    #[test]
    fn matches_brute_force_scan_over_advancing_time() {
        let n = 40;
        let idx_avail = Availability::Lazy(LazyTraceSet::new(n, 17, TraceConfig::default()));
        let ref_avail = Availability::Lazy(LazyTraceSet::new(n, 17, TraceConfig::default()));
        let mut idx = AvailabilityIndex::new(idx_avail, n, 4);
        // irregular step sizes, crossing the week boundary twice
        let mut t = 0.0;
        let steps = [13.0, 400.0, 7.7, 86_000.0, 3600.0, 250_000.0, 604_000.0, 86_400.0];
        for (i, &dt) in steps.iter().cycle().take(40).enumerate() {
            t += dt;
            idx.advance_to(t, 1);
            assert_eq!(
                collect(&idx),
                brute_force(&ref_avail, n, t),
                "step {i} at t={t}"
            );
        }
    }

    #[test]
    fn flips_report_real_changes_only() {
        let n = 12;
        let avail = Availability::Lazy(LazyTraceSet::new(n, 3, TraceConfig::default()));
        let mut idx = AvailabilityIndex::new(avail, n, 2);
        idx.advance_to(0.0, 1);
        let before = collect(&idx);
        let flips = idx.advance_to(40_000.0, 1);
        let mut state: std::collections::HashSet<usize> = before.into_iter().collect();
        for (id, on) in flips {
            if on {
                assert!(state.insert(id), "flip-on for already-on learner {id}");
            } else {
                assert!(state.remove(&id), "flip-off for already-off learner {id}");
            }
        }
        let mut expect: Vec<usize> = state.into_iter().collect();
        expect.sort_unstable();
        assert_eq!(collect(&idx), expect);
    }

    #[test]
    fn dynamic_trace_supported_too() {
        let n = 10;
        let tr = TraceSet::generate(n, 8, TraceConfig::default());
        let reference = Availability::Dynamic(TraceSet::generate(n, 8, TraceConfig::default()));
        let mut idx = AvailabilityIndex::new(Availability::Dynamic(tr), n, 3);
        for t in [0.0, 500.0, 90_000.0, 700_000.0] {
            idx.advance_to(t, 1);
            assert_eq!(collect(&idx), brute_force(&reference, n, t), "t={t}");
        }
    }

    #[test]
    fn parallel_build_matches_serial() {
        let n = 200;
        let mk = || Availability::Lazy(LazyTraceSet::new(n, 5, TraceConfig::default()));
        let mut a = AvailabilityIndex::new(mk(), n, 8);
        let mut b = AvailabilityIndex::new(mk(), n, 8);
        a.advance_to(12_345.0, 1);
        b.advance_to(12_345.0, 6);
        assert_eq!(collect(&a), collect(&b));
        let fa = a.advance_to(500_000.0, 1);
        let fb = b.advance_to(500_000.0, 6);
        assert_eq!(fa, fb, "flip streams must be worker-count independent");
    }

    #[test]
    fn sharded_flips_are_the_flat_stream_filtered_per_shard() {
        // each shard's flip group must equal the single-shard (flat) flip
        // stream restricted to that shard's id range, for any shard count
        let n = 60;
        let mk = || Availability::Lazy(LazyTraceSet::new(n, 21, TraceConfig::default()));
        let mut flat = AvailabilityIndex::new(mk(), n, 1);
        flat.advance_to(1_000.0, 1);
        let flat_flips = flat.advance_to(300_000.0, 1);
        for shards in [2usize, 7, 16] {
            let mut idx = AvailabilityIndex::new(mk(), n, shards);
            idx.advance_to(1_000.0, 1);
            let groups = idx.advance_to_sharded(300_000.0, 4);
            let shard_size = n.div_ceil(shards).max(1);
            assert_eq!(groups.len(), n.div_ceil(shard_size).max(1), "{shards} shards");
            for (si, group) in groups.iter().enumerate() {
                let lo = si * shard_size;
                let hi = (lo + shard_size).min(n);
                let want: Vec<(usize, bool)> = flat_flips
                    .iter()
                    .copied()
                    .filter(|&(id, _)| id >= lo && id < hi)
                    .collect();
                assert_eq!(group, &want, "{shards} shards, shard {si}");
            }
            assert_eq!(collect(&idx), collect(&flat), "{shards} shards: sets diverged");
        }
    }

    #[test]
    fn bounds_cover_week_edge_sessions() {
        // a session starting at 0 and one clipped at WEEK: the week wraps
        // mid-session on both ends
        let s = vec![(0.0, 100.0), (604_000.0, WEEK)];
        let b = Bounds::new(&s);
        assert!(b.state0);
        assert_eq!(b.count(), 2 * 2 - 1 - 1 + 1);
        assert_eq!(b.get(0), (100.0, false));
        assert_eq!(b.get(1), (604_000.0, true));
        assert_eq!(b.get(2), (WEEK, true));
        assert_eq!(b.first_after(0.0), 0);
        assert_eq!(b.first_after(100.0), 1);
        assert_eq!(b.first_after(604_500.0), 2);
    }
}
