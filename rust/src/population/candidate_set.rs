//! The incrementally-maintained candidate set: a sharded dynamic set of
//! learner ids with O(log n) insert/remove and O(log n) rank queries, the
//! structure selection strategies draw from instead of re-scanning the whole
//! population.
//!
//! Internally each shard covers a contiguous id range and **owns its own
//! storage** — a membership bitmap plus a Fenwick (binary-indexed) tree over
//! it — so the sharded coordination layer ([`crate::population::sharded`])
//! can hand each coordinator shard a disjoint mutable view
//! ([`CandidateSet::shard_views_mut`]) and mutate all K shards in parallel.
//! Rank/select queries walk the shard prefix counts (shard counts are few)
//! and then descend one shard's tree. All order-sensitive operations —
//! ascending-id iteration, `nth` (global rank → id), and `sample_k` — are
//! defined over the *global id space*, so results are byte-identical for
//! any shard count (`tests/population_props.rs` locks this in).
//!
//! `sample_k` reproduces [`Rng::choose_k`] exactly: it runs the same partial
//! Fisher-Yates over the implicit ascending-id candidate array, tracking the
//! (at most k) displaced positions in a sparse map. Sampling k ids from the
//! set therefore consumes the same RNG draws and returns the same ids as
//! materializing the candidate list and calling `choose_k` on it — which is
//! what makes the async engine's sampled fast path bit-compatible with the
//! materializing path it replaces.

use std::collections::HashMap;

use super::registry::DEFAULT_SHARDS;
use crate::util::rng::Rng;

/// Fenwick tree over a 0/1 membership array (counts per node).
struct Fenwick {
    tree: Vec<u32>,
    n: usize,
}

impl Fenwick {
    fn new(n: usize) -> Fenwick {
        Fenwick { tree: vec![0; n + 1], n }
    }

    fn add(&mut self, i: usize, delta: i32) {
        let mut i = i + 1;
        while i <= self.n {
            self.tree[i] = (self.tree[i] as i64 + delta as i64) as u32;
            i += i & i.wrapping_neg();
        }
    }

    /// Shard-local index of the k-th (0-based) member; requires k < total.
    fn select(&self, k: usize) -> usize {
        let mut pos = 0usize;
        let mut rem = k + 1;
        let mut step = self.n.next_power_of_two();
        while step > 0 {
            let next = pos + step;
            if next <= self.n && (self.tree[next] as usize) < rem {
                rem -= self.tree[next] as usize;
                pos = next;
            }
            step >>= 1;
        }
        pos
    }
}

/// One contiguous id range's worth of membership state: the bitmap, the
/// Fenwick over it, and the member count — everything a coordinator shard
/// mutates during a parallel advance, with no storage shared across shards.
struct SetShard {
    fen: Fenwick,
    /// Local membership bitmap over `0..size` (word-packed).
    bits: Vec<u64>,
    /// Number of ids this shard ranges over.
    size: usize,
    /// Members currently present in this shard.
    len: usize,
}

impl SetShard {
    fn new(size: usize) -> SetShard {
        SetShard {
            fen: Fenwick::new(size),
            bits: vec![0u64; size.div_ceil(64).max(1)],
            size,
            len: 0,
        }
    }

    #[inline]
    fn contains(&self, off: usize) -> bool {
        (self.bits[off / 64] >> (off % 64)) & 1 == 1
    }

    fn insert(&mut self, off: usize) -> bool {
        if self.contains(off) {
            return false;
        }
        self.bits[off / 64] |= 1u64 << (off % 64);
        self.fen.add(off, 1);
        self.len += 1;
        true
    }

    fn remove(&mut self, off: usize) -> bool {
        if !self.contains(off) {
            return false;
        }
        self.bits[off / 64] &= !(1u64 << (off % 64));
        self.fen.add(off, -1);
        self.len -= 1;
        true
    }
}

/// Sharded dynamic set of learner ids (see the module docs).
pub struct CandidateSet {
    shards: Vec<SetShard>,
    shard_size: usize,
    n: usize,
    len: usize,
}

impl CandidateSet {
    /// Empty set over ids `0..n` with the default shard count.
    pub fn new(n: usize) -> CandidateSet {
        CandidateSet::with_shards(n, DEFAULT_SHARDS)
    }

    /// Empty set over ids `0..n` split into `num_shards` contiguous ranges.
    /// The shard count affects only internal layout, never results.
    pub fn with_shards(n: usize, num_shards: usize) -> CandidateSet {
        let num_shards = num_shards.max(1);
        let shard_size = n.div_ceil(num_shards).max(1);
        let count = n.div_ceil(shard_size).max(1);
        let shards = (0..count)
            .map(|i| {
                let lo = i * shard_size;
                let hi = ((i + 1) * shard_size).min(n);
                SetShard::new(hi.saturating_sub(lo))
            })
            .collect();
        CandidateSet { shards, shard_size, n, len: 0 }
    }

    /// Number of ids the set ranges over (the population size).
    pub fn capacity(&self) -> usize {
        self.n
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Size of each contiguous shard range (the last shard may be shorter).
    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    pub fn contains(&self, id: usize) -> bool {
        debug_assert!(id < self.n);
        self.shards[id / self.shard_size].contains(id % self.shard_size)
    }

    /// Insert `id`; returns true if it was not already a member.
    pub fn insert(&mut self, id: usize) -> bool {
        let changed = self.shards[id / self.shard_size].insert(id % self.shard_size);
        self.len += changed as usize;
        changed
    }

    /// Remove `id`; returns true if it was a member.
    pub fn remove(&mut self, id: usize) -> bool {
        let changed = self.shards[id / self.shard_size].remove(id % self.shard_size);
        self.len -= changed as usize;
        changed
    }

    /// The `rank`-th smallest member id (0-based); requires `rank < len()`.
    pub fn nth(&self, rank: usize) -> usize {
        assert!(rank < self.len, "rank {rank} out of range (len {})", self.len);
        let mut rem = rank;
        for (si, sh) in self.shards.iter().enumerate() {
            if rem < sh.len {
                return si * self.shard_size + sh.fen.select(rem);
            }
            rem -= sh.len;
        }
        unreachable!("rank within len must land in a shard")
    }

    /// Members in ascending id order.
    pub fn iter(&self) -> SetIter<'_> {
        SetIter {
            set: self,
            shard_idx: 0,
            word_idx: 0,
            cur: self.shards.first().and_then(|s| s.bits.first()).copied().unwrap_or(0),
        }
    }

    /// `k` distinct members, drawn exactly like [`Rng::choose_k`] over the
    /// ascending-id member array (same RNG draws, same ids), but in
    /// O(k log n) without materializing the array. Caps at `len()`.
    pub fn sample_k(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        let n = self.len;
        let k = k.min(n);
        let mut swapped: HashMap<usize, usize> = HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = rng.range(i, n);
            let vj = swapped.get(&j).copied().unwrap_or(j);
            let vi = swapped.get(&i).copied().unwrap_or(i);
            swapped.insert(j, vi);
            out.push(self.nth(vj));
        }
        out
    }

    /// Disjoint per-shard mutable views, one per shard in ascending id-range
    /// order — the handles the sharded coordination layer distributes across
    /// the threadpool so all K shards mutate membership in parallel. The
    /// global `len` is left stale while views are out; callers must
    /// [`CandidateSet::rebuild_len`] after the parallel phase.
    pub(crate) fn shard_views_mut(&mut self) -> Vec<ShardViewMut<'_>> {
        let shard_size = self.shard_size;
        self.shards
            .iter_mut()
            .enumerate()
            .map(|(si, shard)| ShardViewMut { lo: si * shard_size, shard })
            .collect()
    }

    /// Re-derive the global member count from the per-shard counts (after a
    /// parallel mutation phase through [`CandidateSet::shard_views_mut`]).
    pub(crate) fn rebuild_len(&mut self) {
        self.len = self.shards.iter().map(|s| s.len).sum();
    }
}

/// A mutable handle to exactly one shard's membership state, addressed by
/// global learner id. Disjoint across shards, so K views mutate in parallel.
pub(crate) struct ShardViewMut<'a> {
    shard: &'a mut SetShard,
    lo: usize,
}

impl ShardViewMut<'_> {
    /// Insert global `id` (must belong to this shard's range); returns true
    /// if it was not already a member.
    pub(crate) fn insert(&mut self, id: usize) -> bool {
        debug_assert!(id >= self.lo && id - self.lo < self.shard.size, "id outside shard");
        self.shard.insert(id - self.lo)
    }

    /// Remove global `id` (must belong to this shard's range); returns true
    /// if it was a member.
    pub(crate) fn remove(&mut self, id: usize) -> bool {
        debug_assert!(id >= self.lo && id - self.lo < self.shard.size, "id outside shard");
        self.shard.remove(id - self.lo)
    }

    /// Is global `id` (must belong to this shard's range) a member?
    #[cfg(test)]
    pub(crate) fn contains(&self, id: usize) -> bool {
        debug_assert!(id >= self.lo && id - self.lo < self.shard.size, "id outside shard");
        self.shard.contains(id - self.lo)
    }
}

/// Ascending-id iterator over a [`CandidateSet`]'s per-shard bitmaps.
pub struct SetIter<'a> {
    set: &'a CandidateSet,
    shard_idx: usize,
    word_idx: usize,
    cur: u64,
}

impl Iterator for SetIter<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.cur == 0 {
            self.word_idx += 1;
            loop {
                let Some(shard) = self.set.shards.get(self.shard_idx) else {
                    return None;
                };
                if self.word_idx < shard.bits.len() {
                    self.cur = shard.bits[self.word_idx];
                    break;
                }
                self.shard_idx += 1;
                self.word_idx = 0;
            }
        }
        let b = self.cur.trailing_zeros() as usize;
        self.cur &= self.cur - 1;
        Some(self.shard_idx * self.set.shard_size + self.word_idx * 64 + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains_len() {
        let mut s = CandidateSet::new(100);
        assert!(s.is_empty());
        assert!(s.insert(7));
        assert!(!s.insert(7), "double insert must report false");
        assert!(s.insert(99));
        assert!(s.insert(0));
        assert_eq!(s.len(), 3);
        assert!(s.contains(7) && s.contains(99) && s.contains(0));
        assert!(!s.contains(1));
        assert!(s.remove(7));
        assert!(!s.remove(7), "double remove must report false");
        assert_eq!(s.len(), 2);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 99]);
    }

    #[test]
    fn nth_is_rank_order() {
        let mut s = CandidateSet::with_shards(257, 4);
        for id in [5usize, 63, 64, 128, 200, 256] {
            s.insert(id);
        }
        let members: Vec<usize> = s.iter().collect();
        assert_eq!(members, vec![5, 63, 64, 128, 200, 256]);
        for (rank, &id) in members.iter().enumerate() {
            assert_eq!(s.nth(rank), id, "rank {rank}");
        }
    }

    #[test]
    fn iter_matches_naive_filter() {
        let mut rng = Rng::new(11);
        let mut s = CandidateSet::new(500);
        let mut naive = vec![false; 500];
        for _ in 0..1000 {
            let id = rng.below(500);
            if rng.bool(0.6) {
                s.insert(id);
                naive[id] = true;
            } else {
                s.remove(id);
                naive[id] = false;
            }
        }
        let want: Vec<usize> = (0..500).filter(|&i| naive[i]).collect();
        assert_eq!(s.iter().collect::<Vec<_>>(), want);
        assert_eq!(s.len(), want.len());
    }

    #[test]
    fn iter_is_layout_invariant() {
        // shard boundaries falling mid-word must not perturb iteration
        for shards in [1usize, 3, 7, 64] {
            let mut s = CandidateSet::with_shards(300, shards);
            for id in (0..300).filter(|i| i % 5 == 0 || i % 17 == 3) {
                s.insert(id);
            }
            let want: Vec<usize> =
                (0..300).filter(|i| i % 5 == 0 || i % 17 == 3).collect();
            assert_eq!(s.iter().collect::<Vec<_>>(), want, "{shards} shards");
        }
    }

    #[test]
    fn sample_k_equals_choose_k_over_members() {
        // the contract the async fast path relies on: sampling from the set
        // consumes the same draws and returns the same ids as materializing
        // the ascending member list and running Rng::choose_k on it
        let mut s = CandidateSet::new(300);
        for id in (0..300).step_by(3) {
            s.insert(id);
        }
        let members: Vec<usize> = s.iter().collect();
        for seed in 0..20u64 {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let sampled = s.sample_k(&mut r1, 17);
            let picked: Vec<usize> =
                r2.choose_k(members.len(), 17).into_iter().map(|i| members[i]).collect();
            assert_eq!(sampled, picked, "seed {seed}");
            // and the rngs are left in identical states
            assert_eq!(r1.next_u64(), r2.next_u64(), "seed {seed}: rng state diverged");
        }
    }

    #[test]
    fn sampling_is_byte_identical_across_shard_counts() {
        let build = |shards: usize| {
            let mut s = CandidateSet::with_shards(1000, shards);
            for id in (0..1000).filter(|i| i % 7 == 0 || i % 11 == 0) {
                s.insert(id);
            }
            s
        };
        let a = build(1);
        let b = build(8);
        let c = build(13);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.iter().collect::<Vec<_>>(), b.iter().collect::<Vec<_>>());
        for seed in 0..10u64 {
            let mut ra = Rng::new(seed);
            let mut rb = Rng::new(seed);
            let mut rc = Rng::new(seed);
            let sa = a.sample_k(&mut ra, 25);
            assert_eq!(sa, b.sample_k(&mut rb, 25), "seed {seed}: 1 vs 8 shards");
            assert_eq!(sa, c.sample_k(&mut rc, 25), "seed {seed}: 1 vs 13 shards");
        }
    }

    #[test]
    fn sample_caps_at_len_and_handles_empty() {
        let mut s = CandidateSet::new(10);
        let mut rng = Rng::new(1);
        assert!(s.sample_k(&mut rng, 5).is_empty());
        s.insert(3);
        s.insert(8);
        let got = s.sample_k(&mut rng, 5);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![3, 8]);
    }

    #[test]
    fn shard_views_partition_the_id_space() {
        let mut s = CandidateSet::with_shards(100, 4);
        {
            let mut views = s.shard_views_mut();
            assert_eq!(views.len(), 4);
            assert!(views[0].insert(3));
            assert!(views[1].insert(30));
            assert!(!views[1].insert(30), "double insert through a view");
            assert!(views[3].insert(99));
            assert!(views[3].contains(99));
            assert!(views[3].remove(99));
        }
        s.rebuild_len();
        assert_eq!(s.len(), 2);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![3, 30]);
        assert_eq!(s.nth(1), 30);
    }

    #[test]
    fn tiny_and_edge_capacities() {
        let mut s = CandidateSet::with_shards(1, 8);
        assert_eq!(s.capacity(), 1);
        assert!(s.insert(0));
        assert_eq!(s.nth(0), 0);
        let s0 = CandidateSet::new(0);
        assert_eq!(s0.len(), 0);
        assert_eq!(s0.iter().count(), 0);
    }
}
