//! The incrementally-maintained candidate set: a sharded dynamic set of
//! learner ids with O(log n) insert/remove and O(log n) rank queries, the
//! structure selection strategies draw from instead of re-scanning the whole
//! population.
//!
//! Internally each shard covers a contiguous id range and keeps a Fenwick
//! (binary-indexed) tree over a membership bitmap; rank/select queries walk
//! the shard prefix counts (shard counts are few) and then descend one
//! shard's tree. All order-sensitive operations — ascending-id iteration,
//! `nth` (global rank → id), and `sample_k` — are defined over the *global
//! id space*, so results are byte-identical for any shard count
//! (`tests/population_props.rs` locks this in).
//!
//! `sample_k` reproduces [`Rng::choose_k`] exactly: it runs the same partial
//! Fisher-Yates over the implicit ascending-id candidate array, tracking the
//! (at most k) displaced positions in a sparse map. Sampling k ids from the
//! set therefore consumes the same RNG draws and returns the same ids as
//! materializing the candidate list and calling `choose_k` on it — which is
//! what makes the async engine's sampled fast path bit-compatible with the
//! materializing path it replaces.

use std::collections::HashMap;

use super::registry::DEFAULT_SHARDS;
use crate::util::rng::Rng;

/// Fenwick tree over a 0/1 membership array (counts per node).
struct Fenwick {
    tree: Vec<u32>,
    n: usize,
}

impl Fenwick {
    fn new(n: usize) -> Fenwick {
        Fenwick { tree: vec![0; n + 1], n }
    }

    fn add(&mut self, i: usize, delta: i32) {
        let mut i = i + 1;
        while i <= self.n {
            self.tree[i] = (self.tree[i] as i64 + delta as i64) as u32;
            i += i & i.wrapping_neg();
        }
    }

    /// Total number of members in this shard.
    fn total(&self) -> usize {
        let mut i = self.n;
        let mut s = 0usize;
        while i > 0 {
            s += self.tree[i] as usize;
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Shard-local index of the k-th (0-based) member; requires k < total.
    fn select(&self, k: usize) -> usize {
        let mut pos = 0usize;
        let mut rem = k + 1;
        let mut step = self.n.next_power_of_two();
        while step > 0 {
            let next = pos + step;
            if next <= self.n && (self.tree[next] as usize) < rem {
                rem -= self.tree[next] as usize;
                pos = next;
            }
            step >>= 1;
        }
        pos
    }
}

/// Sharded dynamic set of learner ids (see the module docs).
pub struct CandidateSet {
    shards: Vec<Fenwick>,
    /// Membership bitmap over the whole id space (word-packed).
    bits: Vec<u64>,
    shard_size: usize,
    n: usize,
    len: usize,
}

impl CandidateSet {
    /// Empty set over ids `0..n` with the default shard count.
    pub fn new(n: usize) -> CandidateSet {
        CandidateSet::with_shards(n, DEFAULT_SHARDS)
    }

    /// Empty set over ids `0..n` split into `num_shards` contiguous ranges.
    /// The shard count affects only internal layout, never results.
    pub fn with_shards(n: usize, num_shards: usize) -> CandidateSet {
        let num_shards = num_shards.max(1);
        let shard_size = n.div_ceil(num_shards).max(1);
        let count = n.div_ceil(shard_size).max(1);
        let shards = (0..count)
            .map(|i| {
                let lo = i * shard_size;
                let hi = ((i + 1) * shard_size).min(n);
                Fenwick::new(hi.saturating_sub(lo))
            })
            .collect();
        CandidateSet {
            shards,
            bits: vec![0u64; n.div_ceil(64).max(1)],
            shard_size,
            n,
            len: 0,
        }
    }

    /// Number of ids the set ranges over (the population size).
    pub fn capacity(&self) -> usize {
        self.n
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn contains(&self, id: usize) -> bool {
        debug_assert!(id < self.n);
        (self.bits[id / 64] >> (id % 64)) & 1 == 1
    }

    /// Insert `id`; returns true if it was not already a member.
    pub fn insert(&mut self, id: usize) -> bool {
        if self.contains(id) {
            return false;
        }
        self.bits[id / 64] |= 1u64 << (id % 64);
        self.shards[id / self.shard_size].add(id % self.shard_size, 1);
        self.len += 1;
        true
    }

    /// Remove `id`; returns true if it was a member.
    pub fn remove(&mut self, id: usize) -> bool {
        if !self.contains(id) {
            return false;
        }
        self.bits[id / 64] &= !(1u64 << (id % 64));
        self.shards[id / self.shard_size].add(id % self.shard_size, -1);
        self.len -= 1;
        true
    }

    /// The `rank`-th smallest member id (0-based); requires `rank < len()`.
    pub fn nth(&self, rank: usize) -> usize {
        assert!(rank < self.len, "rank {rank} out of range (len {})", self.len);
        let mut rem = rank;
        for (si, sh) in self.shards.iter().enumerate() {
            let t = sh.total();
            if rem < t {
                return si * self.shard_size + sh.select(rem);
            }
            rem -= t;
        }
        unreachable!("rank within len must land in a shard")
    }

    /// Members in ascending id order.
    pub fn iter(&self) -> SetIter<'_> {
        SetIter {
            bits: &self.bits,
            word_idx: 0,
            cur: self.bits.first().copied().unwrap_or(0),
        }
    }

    /// `k` distinct members, drawn exactly like [`Rng::choose_k`] over the
    /// ascending-id member array (same RNG draws, same ids), but in
    /// O(k log n) without materializing the array. Caps at `len()`.
    pub fn sample_k(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        let n = self.len;
        let k = k.min(n);
        let mut swapped: HashMap<usize, usize> = HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = rng.range(i, n);
            let vj = swapped.get(&j).copied().unwrap_or(j);
            let vi = swapped.get(&i).copied().unwrap_or(i);
            swapped.insert(j, vi);
            out.push(self.nth(vj));
        }
        out
    }
}

/// Ascending-id iterator over a [`CandidateSet`]'s membership bitmap.
pub struct SetIter<'a> {
    bits: &'a [u64],
    word_idx: usize,
    cur: u64,
}

impl Iterator for SetIter<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.cur == 0 {
            self.word_idx += 1;
            if self.word_idx >= self.bits.len() {
                return None;
            }
            self.cur = self.bits[self.word_idx];
        }
        let b = self.cur.trailing_zeros() as usize;
        self.cur &= self.cur - 1;
        Some(self.word_idx * 64 + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains_len() {
        let mut s = CandidateSet::new(100);
        assert!(s.is_empty());
        assert!(s.insert(7));
        assert!(!s.insert(7), "double insert must report false");
        assert!(s.insert(99));
        assert!(s.insert(0));
        assert_eq!(s.len(), 3);
        assert!(s.contains(7) && s.contains(99) && s.contains(0));
        assert!(!s.contains(1));
        assert!(s.remove(7));
        assert!(!s.remove(7), "double remove must report false");
        assert_eq!(s.len(), 2);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 99]);
    }

    #[test]
    fn nth_is_rank_order() {
        let mut s = CandidateSet::with_shards(257, 4);
        for id in [5usize, 63, 64, 128, 200, 256] {
            s.insert(id);
        }
        let members: Vec<usize> = s.iter().collect();
        assert_eq!(members, vec![5, 63, 64, 128, 200, 256]);
        for (rank, &id) in members.iter().enumerate() {
            assert_eq!(s.nth(rank), id, "rank {rank}");
        }
    }

    #[test]
    fn iter_matches_naive_filter() {
        let mut rng = Rng::new(11);
        let mut s = CandidateSet::new(500);
        let mut naive = vec![false; 500];
        for _ in 0..1000 {
            let id = rng.below(500);
            if rng.bool(0.6) {
                s.insert(id);
                naive[id] = true;
            } else {
                s.remove(id);
                naive[id] = false;
            }
        }
        let want: Vec<usize> = (0..500).filter(|&i| naive[i]).collect();
        assert_eq!(s.iter().collect::<Vec<_>>(), want);
        assert_eq!(s.len(), want.len());
    }

    #[test]
    fn sample_k_equals_choose_k_over_members() {
        // the contract the async fast path relies on: sampling from the set
        // consumes the same draws and returns the same ids as materializing
        // the ascending member list and running Rng::choose_k on it
        let mut s = CandidateSet::new(300);
        for id in (0..300).step_by(3) {
            s.insert(id);
        }
        let members: Vec<usize> = s.iter().collect();
        for seed in 0..20u64 {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let sampled = s.sample_k(&mut r1, 17);
            let picked: Vec<usize> =
                r2.choose_k(members.len(), 17).into_iter().map(|i| members[i]).collect();
            assert_eq!(sampled, picked, "seed {seed}");
            // and the rngs are left in identical states
            assert_eq!(r1.next_u64(), r2.next_u64(), "seed {seed}: rng state diverged");
        }
    }

    #[test]
    fn sampling_is_byte_identical_across_shard_counts() {
        let build = |shards: usize| {
            let mut s = CandidateSet::with_shards(1000, shards);
            for id in (0..1000).filter(|i| i % 7 == 0 || i % 11 == 0) {
                s.insert(id);
            }
            s
        };
        let a = build(1);
        let b = build(8);
        let c = build(13);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.iter().collect::<Vec<_>>(), b.iter().collect::<Vec<_>>());
        for seed in 0..10u64 {
            let mut ra = Rng::new(seed);
            let mut rb = Rng::new(seed);
            let mut rc = Rng::new(seed);
            let sa = a.sample_k(&mut ra, 25);
            assert_eq!(sa, b.sample_k(&mut rb, 25), "seed {seed}: 1 vs 8 shards");
            assert_eq!(sa, c.sample_k(&mut rc, 25), "seed {seed}: 1 vs 13 shards");
        }
    }

    #[test]
    fn sample_caps_at_len_and_handles_empty() {
        let mut s = CandidateSet::new(10);
        let mut rng = Rng::new(1);
        assert!(s.sample_k(&mut rng, 5).is_empty());
        s.insert(3);
        s.insert(8);
        let got = s.sample_k(&mut rng, 5);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![3, 8]);
    }

    #[test]
    fn tiny_and_edge_capacities() {
        let mut s = CandidateSet::with_shards(1, 8);
        assert_eq!(s.capacity(), 1);
        assert!(s.insert(0));
        assert_eq!(s.nth(0), 0);
        let s0 = CandidateSet::new(0);
        assert_eq!(s0.len(), 0);
        assert_eq!(s0.iter().count(), 0);
    }
}
