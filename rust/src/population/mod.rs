//! The population substrate: **who exists, who is available, who is
//! selectable** — one subsystem owning every per-learner fact and the
//! incremental indexes over them, replacing the per-engine
//! O(total_learners) check-in scans that blocked 100k+-learner cells
//! (ROADMAP "incremental candidate set" item).
//!
//! ```text
//!   Registry ──────────► AvailabilityIndex ─────────► CandidateSet ──► Selector
//!   (sharded profiles,   (trace sessions turned       (eligible ids:    (indexed:
//!    samples, cooldown/   into kernel transition       O(log n) insert/  hooks +
//!    busy state)          events; incremental          remove/sample,    ScoreIndex)
//!                         available-set)               shard-invariant)
//! ```
//!
//! * [`Registry`] — sharded per-learner storage: device profile (eager or
//!   lazy), local dataset size, cooldown round, busy-until time.
//! * [`AvailabilityIndex`] — availability transitions scheduled as events
//!   on the existing [`crate::sim::EventKernel`] substrate (one pending
//!   transition per learner) instead of being rediscovered by scanning;
//!   maintains the available-id set incrementally.
//! * [`CandidateSet`] — the sharded dynamic id set selection strategies
//!   draw from: O(log n) insert/remove/rank with seeded sampling that is
//!   byte-identical for any shard count and bit-compatible with
//!   `Rng::choose_k` over the materialized candidate list.
//!
//! [`Population`] composes the three for the coordinator. Both engines now
//! run **fully incrementally** ([`Population::sync_to`] + `eligible_set`):
//! the *selectable* set (available ∧ not busy ∧ not cooling) is maintained
//! per transition — availability flips from the index, busy expiries from
//! time-keyed buckets, cooldown expiries from round-keyed buckets — and
//! every eligible-set insert/remove is **forwarded to the active selector**
//! through the `Selector::on_eligible`/`on_ineligible` hooks, which is what
//! feeds the selection-index subsystem (`selection::index`). Selectors with
//! an indexed `select_from` draw straight from the set in O(k log n) per
//! selection; the materialized fallback ([`Population::pool_candidates`])
//! produces exactly the candidate vector the old full scan produced, so the
//! OC/DL engines stay byte-identical to the frozen `coordinator::reference`
//! oracle (`tests/kernel_equivalence.rs`). [`Population`] also implements
//! [`ProbeSource`], serving per-learner probe answers (and their
//! [`SlotSig`] validity buckets) lazily to indexed selectors.
//!
//! **Sharded coordination** ([`sharded`]): the registry's shard count K
//! partitions every structure above into the same K contiguous id ranges,
//! and `sync_to` runs as a parallel per-shard delta pass followed by a
//! serial shard-major hook pass — results byte-identical for any K
//! (`tests/coord_shard_props.rs`), per-round wall-clock dropping with the
//! core count at 1M+ learners (`relay bench --suite coord`).

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod avail_index;
pub mod candidate_set;
pub mod registry;
pub mod sharded;

pub use avail_index::AvailabilityIndex;
pub use candidate_set::CandidateSet;
pub use registry::{Registry, DEFAULT_SHARDS};
pub use sharded::ShardPlan;

use sharded::ShardBuckets;

use crate::config::AvailMode;
use crate::forecast::{slot_bins, ForecasterBank, SeasonalForecaster};
use crate::learners::DeviceProfile;
use crate::selection::{Candidate, ProbeSource, Selector, SlotSig};
use crate::sim::Availability;

/// Sampling step (seconds) of the one-week series each learner's personal
/// forecaster is bootstrapped from (paper Appendix A).
const FORECAST_STEP: f64 = 1800.0;

/// Engine eligibility state: the selectable set plus the per-shard expiry
/// schedules that re-admit learners as rounds/time advance. Each shard owns
/// the buckets of its contiguous id range (the sync engines have no
/// per-task release event, so busy expiry is bucket-driven; stale entries
/// are harmless — the drain re-checks the registry).
struct EligibleState {
    set: CandidateSet,
    /// One bucket pair per shard, aligned with `set`'s shard layout.
    buckets: Vec<ShardBuckets>,
    /// Ids per shard (the routing key for bucket pushes).
    shard_size: usize,
}

/// Insert into the eligible set, forwarding the delta to the selector.
fn set_insert(elig: &mut EligibleState, sel: &mut dyn Selector, id: usize) {
    if elig.set.insert(id) {
        sel.on_eligible(id);
    }
}

/// Remove from the eligible set, forwarding the delta to the selector.
fn set_remove(elig: &mut EligibleState, sel: &mut dyn Selector, id: usize) {
    if elig.set.remove(id) {
        sel.on_ineligible(id);
    }
}

/// Re-evaluate one learner's eligibility predicate and update the set.
fn refresh(
    elig: &mut EligibleState,
    index: &AvailabilityIndex,
    registry: &Registry,
    id: usize,
    round: usize,
    now: f64,
    sel: &mut dyn Selector,
) {
    let ok = index.is_available(id)
        && registry.busy_until(id) <= now
        && registry.cooldown_until(id) <= round;
    if ok {
        set_insert(elig, sel, id);
    } else {
        set_remove(elig, sel, id);
    }
}

/// The coordinator-facing population substrate (see the module docs).
pub struct Population {
    registry: Registry,
    index: AvailabilityIndex,
    forecasters: ForecasterBank,
    avail_mode: AvailMode,
    local_epochs: usize,
    model_bytes: usize,
    /// Worker threads for the one-time index build (0/1 = serial).
    workers: usize,
    /// Present once an engine runs incrementally (`sync_to`).
    eligible: Option<EligibleState>,
    /// Per-learner job ownership while busy (`NO_JOB` = unowned) — the
    /// multi-job eligibility dimension. Sized lazily on the first
    /// `mark_busy_for` claim; single-job engines never allocate it.
    owner: Vec<u32>,
}

impl Population {
    pub fn new(
        registry: Registry,
        avail: Availability,
        avail_mode: AvailMode,
        local_epochs: usize,
        model_bytes: usize,
        workers: usize,
    ) -> Population {
        let n = registry.len();
        let forecasters = match &avail {
            Availability::All => ForecasterBank::new(0),
            _ => ForecasterBank::new(n),
        };
        let num_shards = registry.num_shards();
        Population {
            index: AvailabilityIndex::new(avail, n, num_shards),
            forecasters,
            registry,
            avail_mode,
            local_epochs,
            model_bytes,
            workers,
            eligible: None,
            owner: Vec::new(),
        }
    }

    /// Sentinel for "owned by no job".
    pub const NO_JOB: u32 = u32::MAX;

    pub fn len(&self) -> usize {
        self.registry.len()
    }

    pub fn is_empty(&self) -> bool {
        self.registry.is_empty()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The wrapped availability view, for direct interval queries
    /// (`available_through`) that stay on the trace itself.
    pub fn availability(&self) -> &Availability {
        self.index.availability()
    }

    pub fn profile(&self, id: usize) -> &DeviceProfile {
        self.registry.profile(id)
    }

    pub fn cooldown_until(&self, id: usize) -> usize {
        self.registry.cooldown_until(id)
    }

    pub fn busy_until(&self, id: usize) -> f64 {
        self.registry.busy_until(id)
    }

    /// Plain state write for scan-driven callers (tests, the frozen
    /// reference shape). Incremental engines use [`Population::begin_cooldown`].
    pub fn set_cooldown_until(&mut self, id: usize, round: usize) {
        debug_assert!(self.eligible.is_none(), "incremental populations use begin_cooldown");
        self.registry.set_cooldown_until(id, round);
    }

    /// Plain state write for scan-driven callers (see above).
    pub fn set_busy_until(&mut self, id: usize, t: f64) {
        debug_assert!(self.eligible.is_none(), "incremental populations use mark_busy");
        self.registry.set_busy_until(id, t);
    }

    /// This learner's personal forecaster, trained at first touch on (two
    /// replayed weeks of) its own trace — the paper's "learners maintain a
    /// trace of their charging events" (Appendix A). Learners that never
    /// check in never pay the training cost.
    pub fn forecaster(&self, id: usize) -> &SeasonalForecaster {
        let avail = self.index.availability();
        self.forecasters.get_or_train(id, || {
            let series = avail
                .sample_series(id, FORECAST_STEP)
                .expect("DynAvail always carries a trace");
            SeasonalForecaster::train_on_week(&series, FORECAST_STEP)
        })
    }

    /// The probe answer for `id` at `(now, mu)` — shared by candidate
    /// materialization and the lazy [`ProbeSource`] path, so both produce
    /// bitwise-identical values.
    fn probe_avail_prob(&self, id: usize, now: f64, mu: f64) -> f64 {
        match self.avail_mode {
            AvailMode::AllAvail => 1.0,
            AvailMode::DynAvail => {
                // learner-side forecast for the slot (mu, 2mu)
                self.forecaster(id).prob_slot(now + mu, now + 2.0 * mu)
            }
        }
    }

    /// Profile-based expected task duration for `id` (no trace touch).
    fn probe_expected_duration(&self, id: usize) -> f64 {
        self.registry.profile(id).completion_time(
            self.registry.n_samples(id),
            self.local_epochs,
            self.model_bytes,
        )
    }

    fn candidate(&self, id: usize, now: f64, mu: f64) -> Candidate {
        Candidate {
            id,
            avail_prob: self.probe_avail_prob(id, now, mu),
            expected_duration: self.probe_expected_duration(id),
        }
    }

    /// Checked-in learners with their probe answers (Algorithm 1 steps 1-3)
    /// via a per-round scan of the available set — the pre-incremental
    /// query shape, kept for scan-driven callers and as the equivalence
    /// oracle for the incremental path.
    pub fn sync_candidates(&mut self, round: usize, now: f64, mu: f64) -> Vec<Candidate> {
        debug_assert!(self.eligible.is_none(), "incremental populations use pool_candidates");
        self.index.advance_to(now, self.workers);
        let mut out = Vec::new();
        self.index.for_each_available(|id| {
            if self.registry.cooldown_until(id) > round || self.registry.busy_until(id) > now {
                return;
            }
            out.push(self.candidate(id, now, mu));
        });
        out
    }

    /// Bring the eligibility state up to `(round, now)`: apply availability
    /// flips, expire cooldown and busy buckets, and on first call build the
    /// index + selectable set (the only O(n) pass of an incremental run).
    /// Every resulting set transition is forwarded to `sel`'s
    /// `on_eligible`/`on_ineligible` hooks.
    ///
    /// Steady-state syncs run the **two-phase sharded pass** (see
    /// [`sharded`]): every shard drains its own flips and bucket expiries in
    /// parallel on the worker pool, then the transitions are forwarded to
    /// the selector hooks serially in fixed shard-major order — results
    /// byte-identical for any shard count and any worker count.
    pub fn sync_to(&mut self, round: usize, now: f64, sel: &mut dyn Selector) {
        if self.eligible.is_none() {
            self.index.advance_to(now, self.workers);
            let shards = self.registry.num_shards();
            let set = CandidateSet::with_shards(self.registry.len(), shards);
            let mut elig = EligibleState {
                buckets: (0..set.num_shards()).map(|_| ShardBuckets::default()).collect(),
                shard_size: set.shard_size(),
                set,
            };
            for id in 0..self.registry.len() {
                let cd = self.registry.cooldown_until(id);
                let bz = self.registry.busy_until(id);
                let buckets = &mut elig.buckets[id / elig.shard_size];
                if cd > round {
                    buckets.cooldown.entry(cd).or_default().push(id);
                }
                if bz > now {
                    buckets.busy.entry(bz.to_bits()).or_default().push(id);
                }
                if cd <= round && bz <= now && self.index.is_available(id) {
                    set_insert(&mut elig, sel, id);
                }
            }
            self.eligible = Some(elig);
            return;
        }
        let flips = self.index.advance_to_sharded(now, self.workers);
        let elig = self.eligible.as_mut().expect("checked above");
        let transitions = sharded::sync_shards_parallel(
            &mut elig.set,
            &mut elig.buckets,
            &flips,
            &self.index,
            &self.registry,
            round,
            now,
            self.workers,
        );
        sharded::forward_transitions(&transitions, sel);
    }

    /// The selectable set (`sync_to` first). Indexed selectors draw from
    /// this directly.
    pub fn eligible_set(&self) -> &CandidateSet {
        &self.eligible.as_ref().expect("sync_to before selection").set
    }

    /// Materialized candidates for selectors without an indexed path: the
    /// eligible ids in ascending order with their probe answers — identical
    /// to the old full scan's output, built in O(|eligible|).
    pub fn pool_candidates(&self, now: f64, mu: f64) -> Vec<Candidate> {
        let elig = self.eligible.as_ref().expect("sync_to before selection");
        let mut out = Vec::with_capacity(elig.set.len());
        for id in elig.set.iter() {
            out.push(self.candidate(id, now, mu));
        }
        out
    }

    /// Incremental hook: a task was spawned on `id`, busy until `until`.
    /// Schedules the bucket that re-admits it (sync engines have no
    /// completion event; in async runs `release` gets there first and the
    /// drained bucket is a no-op).
    pub fn mark_busy(&mut self, id: usize, until: f64, sel: &mut dyn Selector) {
        self.registry.set_busy_until(id, until);
        if let Some(elig) = self.eligible.as_mut() {
            elig.buckets[id / elig.shard_size].busy.entry(until.to_bits()).or_default().push(id);
            set_remove(elig, sel, id);
        }
    }

    /// Multi-job variant of [`Population::mark_busy`]: the claim also
    /// records which job owns the device for the busy interval, giving the
    /// job-set engine the "a device busy on job A is ineligible for job B"
    /// dimension for free — a claimed device leaves the one shared eligible
    /// set, so no other job can select it until the busy bucket re-admits
    /// it. Single-job engines keep calling `mark_busy` (no allocation).
    pub fn mark_busy_for(&mut self, id: usize, until: f64, job: u32, sel: &mut dyn Selector) {
        if self.owner.is_empty() {
            self.owner = vec![Self::NO_JOB; self.registry.len()];
        }
        self.owner[id] = job;
        self.mark_busy(id, until, sel);
    }

    /// The job occupying `id` while its busy interval is still open at
    /// `now`; `None` = idle (or a single-job run, which never claims).
    pub fn job_owner(&self, id: usize, now: f64) -> Option<u32> {
        if self.registry.busy_until(id) <= now {
            return None;
        }
        self.owner.get(id).copied().filter(|&j| j != Self::NO_JOB)
    }

    /// Incremental hook: `id`'s task ended (arrival or dropout) at `now` —
    /// the learner is selectable again if available and not cooling.
    pub fn release(&mut self, id: usize, round: usize, now: f64, sel: &mut dyn Selector) {
        if let Some(elig) = self.eligible.as_mut() {
            refresh(elig, &self.index, &self.registry, id, round, now, sel);
        }
    }

    /// Incremental hook: `id` enters cooldown until `until` (a future
    /// round, so it leaves the selectable set now and re-enters via the
    /// bucket drain).
    pub fn begin_cooldown(&mut self, id: usize, until: usize, sel: &mut dyn Selector) {
        self.registry.set_cooldown_until(id, until);
        if let Some(elig) = self.eligible.as_mut() {
            elig.buckets[id / elig.shard_size].cooldown.entry(until).or_default().push(id);
            set_remove(elig, sel, id);
        }
    }

    /// Pre-generate every learner's trace and forecaster — the pre-refactor
    /// eager construction. Tests and benches use this to prove the lazy
    /// path is result-identical and to measure what laziness saves.
    pub fn materialize_all(&self) {
        if matches!(self.index.availability(), Availability::All) {
            return;
        }
        for id in 0..self.registry.len() {
            self.forecaster(id);
        }
    }

    /// Learner traces generated so far (== population size on eager paths).
    pub fn materialized_traces(&self) -> usize {
        match self.index.availability() {
            Availability::All => 0,
            Availability::Dynamic(tr) => tr.len(),
            Availability::Lazy(tr) => tr.materialized(),
        }
    }

    /// Learner forecasters trained so far.
    pub fn trained_forecasters(&self) -> usize {
        self.forecasters.trained()
    }
}

impl ProbeSource for Population {
    fn avail_prob(&self, id: usize, now: f64, mu: f64) -> f64 {
        self.probe_avail_prob(id, now, mu)
    }

    fn expected_duration(&self, id: usize) -> f64 {
        self.probe_expected_duration(id)
    }

    fn slot_sig(&self, now: f64, mu: f64) -> SlotSig {
        match self.avail_mode {
            AvailMode::AllAvail => SlotSig::Const,
            AvailMode::DynAvail => SlotSig::Bins(slot_bins(now + mu, now + 2.0 * mu)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learners::{HardwareScenario, ProfilePool};
    use crate::selection::SelectionCtx;
    use crate::trace::{LazyTraceSet, TraceConfig};

    /// Hook-recording no-op selector: lets the tests assert the population
    /// forwards exactly the eligible-set deltas it applies.
    struct Recorder {
        log: Vec<(usize, bool)>,
    }

    impl Recorder {
        fn new() -> Recorder {
            Recorder { log: Vec::new() }
        }
    }

    impl Selector for Recorder {
        fn name(&self) -> &'static str {
            "recorder"
        }
        fn select(&mut self, _ctx: &mut SelectionCtx) -> Vec<usize> {
            Vec::new()
        }
        fn on_eligible(&mut self, id: usize) {
            self.log.push((id, true));
        }
        fn on_ineligible(&mut self, id: usize) {
            self.log.push((id, false));
        }
    }

    fn mk_population(n: usize, avail: Availability, mode: AvailMode) -> Population {
        let pool = ProfilePool::generate(n, 4, HardwareScenario::Hs1);
        let registry = Registry::eager(pool, vec![8; n], 4);
        Population::new(registry, avail, mode, 1, 1000, 1)
    }

    #[test]
    fn sync_candidates_match_brute_force_predicate() {
        let n = 30;
        let mut p = mk_population(
            n,
            Availability::Lazy(LazyTraceSet::new(n, 6, TraceConfig::default())),
            AvailMode::DynAvail,
        );
        let reference = Availability::Lazy(LazyTraceSet::new(n, 6, TraceConfig::default()));
        p.set_cooldown_until(3, 100);
        p.set_busy_until(5, 1e9);
        for (round, now) in [(0usize, 0.0f64), (1, 900.0), (2, 50_000.0), (3, 400_000.0)] {
            let got: Vec<usize> =
                p.sync_candidates(round, now, 60.0).iter().map(|c| c.id).collect();
            let want: Vec<usize> = (0..n)
                .filter(|&id| {
                    reference.available(id, now)
                        && (id != 3 || round >= 100)
                        && (id != 5)
                })
                .collect();
            assert_eq!(got, want, "round {round} now {now}");
        }
    }

    #[test]
    fn incremental_eligibility_tracks_busy_and_cooldown() {
        let n = 10;
        let mut p = mk_population(n, Availability::All, AvailMode::AllAvail);
        let mut sel = Recorder::new();
        p.sync_to(0, 0.0, &mut sel);
        assert_eq!(p.eligible_set().len(), n);
        assert_eq!(sel.log.len(), n, "init build must announce every insert");
        p.mark_busy(2, 50.0, &mut sel);
        p.begin_cooldown(7, 2, &mut sel);
        assert!(!p.eligible_set().contains(2));
        assert!(!p.eligible_set().contains(7));
        assert_eq!(p.eligible_set().len(), n - 2);
        assert_eq!(&sel.log[n..], &[(2, false), (7, false)]);
        // task ends: learner 2 returns
        p.release(2, 0, 50.0, &mut sel);
        assert!(p.eligible_set().contains(2));
        // version advances past the cooldown: learner 7 returns
        p.sync_to(2, 60.0, &mut sel);
        assert!(p.eligible_set().contains(7));
        assert_eq!(p.eligible_set().len(), n);
        assert_eq!(&sel.log[n + 2..], &[(2, true), (7, true)]);
    }

    #[test]
    fn busy_expiry_is_bucket_driven_without_release() {
        // the sync engines never call release: a busy learner must come
        // back purely from the time-keyed bucket drain
        let n = 4;
        let mut p = mk_population(n, Availability::All, AvailMode::AllAvail);
        let mut sel = Recorder::new();
        p.sync_to(0, 0.0, &mut sel);
        p.mark_busy(1, 30.0, &mut sel);
        // also cooling: both triggers must fire before it returns
        p.begin_cooldown(2, 3, &mut sel);
        p.mark_busy(2, 100.0, &mut sel);
        p.sync_to(1, 10.0, &mut sel);
        assert!(!p.eligible_set().contains(1));
        p.sync_to(2, 30.0, &mut sel);
        assert!(p.eligible_set().contains(1), "busy_until == now must re-admit");
        // cooldown expired but still busy
        p.sync_to(3, 50.0, &mut sel);
        assert!(!p.eligible_set().contains(2));
        // busy expired too
        p.sync_to(4, 100.0, &mut sel);
        assert!(p.eligible_set().contains(2));
    }

    #[test]
    fn job_ownership_tracks_the_busy_interval() {
        let n = 6;
        let mut p = mk_population(n, Availability::All, AvailMode::AllAvail);
        let mut sel = Recorder::new();
        p.sync_to(0, 0.0, &mut sel);
        assert_eq!(p.job_owner(2, 0.0), None, "unclaimed devices have no owner");
        p.mark_busy_for(2, 50.0, 3, &mut sel);
        p.mark_busy(4, 50.0, &mut sel); // single-job claim: never owned
        assert_eq!(p.job_owner(2, 10.0), Some(3));
        assert_eq!(p.job_owner(4, 10.0), None);
        assert!(!p.eligible_set().contains(2), "claimed devices leave the shared set");
        // the owner claim ends exactly with the busy interval
        assert_eq!(p.job_owner(2, 50.0), None);
        p.sync_to(1, 50.0, &mut sel);
        assert!(p.eligible_set().contains(2));
        p.mark_busy_for(2, 80.0, 1, &mut sel);
        assert_eq!(p.job_owner(2, 60.0), Some(1), "re-claims overwrite the owner");
    }

    #[test]
    fn pool_candidates_are_id_ordered_and_probed() {
        let n = 6;
        let mut p = mk_population(n, Availability::All, AvailMode::AllAvail);
        let mut sel = Recorder::new();
        p.sync_to(0, 0.0, &mut sel);
        let cands = p.pool_candidates(0.0, 100.0);
        assert_eq!(cands.len(), n);
        for (i, c) in cands.iter().enumerate() {
            assert_eq!(c.id, i);
            assert_eq!(c.avail_prob, 1.0);
            assert!(c.expected_duration > 0.0);
        }
    }

    #[test]
    fn stale_cooldown_buckets_are_harmless() {
        let n = 4;
        let mut p = mk_population(n, Availability::All, AvailMode::AllAvail);
        let mut sel = Recorder::new();
        p.sync_to(0, 0.0, &mut sel);
        // cooldown set to round 2, then re-set (longer) before expiring
        p.begin_cooldown(1, 2, &mut sel);
        p.begin_cooldown(1, 5, &mut sel);
        p.sync_to(2, 10.0, &mut sel); // drains the stale round-2 bucket
        assert!(!p.eligible_set().contains(1), "stale bucket must not resurrect");
        p.sync_to(5, 20.0, &mut sel);
        assert!(p.eligible_set().contains(1));
    }

    #[test]
    fn probe_source_matches_candidate_materialization() {
        let n = 8;
        let mut p = mk_population(
            n,
            Availability::Lazy(LazyTraceSet::new(n, 9, TraceConfig::default())),
            AvailMode::DynAvail,
        );
        let mut sel = Recorder::new();
        p.sync_to(0, 1000.0, &mut sel);
        let (now, mu) = (1000.0, 80.0);
        for c in p.pool_candidates(now, mu) {
            assert_eq!(
                ProbeSource::avail_prob(&p, c.id, now, mu).to_bits(),
                c.avail_prob.to_bits(),
                "learner {}",
                c.id
            );
            assert_eq!(
                ProbeSource::expected_duration(&p, c.id).to_bits(),
                c.expected_duration.to_bits(),
                "learner {}",
                c.id
            );
        }
        assert_eq!(p.slot_sig(now, mu), p.slot_sig(now + 1.0, mu), "same hour, same sig");
        let all = mk_population(2, Availability::All, AvailMode::AllAvail);
        assert_eq!(all.slot_sig(0.0, 100.0), SlotSig::Const);
    }
}
