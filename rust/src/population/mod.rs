//! The population substrate: **who exists, who is available, who is
//! selectable** — one subsystem owning every per-learner fact and the
//! incremental indexes over them, replacing the per-engine
//! O(total_learners) check-in scans that blocked 100k+-learner cells
//! (ROADMAP "incremental candidate set" item).
//!
//! ```text
//!   Registry ──────────► AvailabilityIndex ─────────► CandidateSet ──► Selector
//!   (sharded profiles,   (trace sessions turned       (eligible ids:    (draws by
//!    samples, cooldown/   into kernel transition       O(log n) insert/  rank or
//!    busy state)          events; incremental          remove/sample,    full list)
//!                         available-set)               shard-invariant)
//! ```
//!
//! * [`Registry`] — sharded per-learner storage: device profile (eager or
//!   lazy), local dataset size, cooldown round, busy-until time.
//! * [`AvailabilityIndex`] — availability transitions scheduled as events
//!   on the existing [`crate::sim::EventKernel`] substrate (one pending
//!   transition per learner) instead of being rediscovered by scanning;
//!   maintains the available-id set incrementally.
//! * [`CandidateSet`] — the sharded dynamic id set selection strategies
//!   draw from: O(log n) insert/remove/rank with seeded sampling that is
//!   byte-identical for any shard count and bit-compatible with
//!   `Rng::choose_k` over the materialized candidate list.
//!
//! [`Population`] composes the three for the coordinator. Two query modes:
//!
//! * **round-synchronous** (`sync_candidates`) — iterate the available set
//!   in id order and filter cooldown/busy from the registry. Produces
//!   exactly the candidate vector the old full scan produced (the OC/DL
//!   engines stay byte-identical to the frozen `coordinator::reference`
//!   oracle — `tests/kernel_equivalence.rs`).
//! * **fully-incremental** (`async_sync_to` + `eligible_set` /
//!   `async_candidates`) — the buffered-async engine keeps the *selectable*
//!   set (available ∧ not busy ∧ not cooling) maintained per event:
//!   availability flips from the index, busy transitions at task
//!   spawn/arrival/dropout, cooldown expiries from version-keyed buckets.
//!   Selectors that sample (Random) draw straight from the set in
//!   O(k log n) per selection; rank-the-pool selectors (Oort/IPS/SAFA)
//!   materialize only the eligible ids, never the whole population.

pub mod avail_index;
pub mod candidate_set;
pub mod registry;

pub use avail_index::AvailabilityIndex;
pub use candidate_set::CandidateSet;
pub use registry::{Registry, DEFAULT_SHARDS};

use std::collections::BTreeMap;

use crate::config::AvailMode;
use crate::forecast::{ForecasterBank, SeasonalForecaster};
use crate::learners::DeviceProfile;
use crate::selection::Candidate;
use crate::sim::Availability;

/// Sampling step (seconds) of the one-week series each learner's personal
/// forecaster is bootstrapped from (paper Appendix A).
const FORECAST_STEP: f64 = 1800.0;

/// Async-engine eligibility state: the selectable set plus the
/// cooldown-expiry schedule that re-admits learners as versions advance.
struct EligibleState {
    set: CandidateSet,
    /// cooldown_until value -> learners parked until that round. Entries can
    /// go stale when a cooldown is re-set; `refresh` re-checks the registry.
    buckets: BTreeMap<usize, Vec<usize>>,
}

/// Re-evaluate one learner's eligibility predicate and update the set.
fn refresh(
    elig: &mut EligibleState,
    index: &AvailabilityIndex,
    registry: &Registry,
    id: usize,
    round: usize,
    now: f64,
) {
    let ok = index.is_available(id)
        && registry.busy_until(id) <= now
        && registry.cooldown_until(id) <= round;
    if ok {
        elig.set.insert(id);
    } else {
        elig.set.remove(id);
    }
}

/// The coordinator-facing population substrate (see the module docs).
pub struct Population {
    registry: Registry,
    index: AvailabilityIndex,
    forecasters: ForecasterBank,
    avail_mode: AvailMode,
    local_epochs: usize,
    model_bytes: usize,
    /// Worker threads for the one-time index build (0/1 = serial).
    workers: usize,
    /// Present only while an async run maintains full eligibility.
    eligible: Option<EligibleState>,
}

impl Population {
    pub fn new(
        registry: Registry,
        avail: Availability,
        avail_mode: AvailMode,
        local_epochs: usize,
        model_bytes: usize,
        workers: usize,
    ) -> Population {
        let n = registry.len();
        let forecasters = match &avail {
            Availability::All => ForecasterBank::new(0),
            _ => ForecasterBank::new(n),
        };
        let num_shards = registry.num_shards();
        Population {
            index: AvailabilityIndex::new(avail, n, num_shards),
            forecasters,
            registry,
            avail_mode,
            local_epochs,
            model_bytes,
            workers,
            eligible: None,
        }
    }

    pub fn len(&self) -> usize {
        self.registry.len()
    }

    pub fn is_empty(&self) -> bool {
        self.registry.is_empty()
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The wrapped availability view, for direct interval queries
    /// (`available_through`) that stay on the trace itself.
    pub fn availability(&self) -> &Availability {
        self.index.availability()
    }

    pub fn profile(&self, id: usize) -> &DeviceProfile {
        self.registry.profile(id)
    }

    pub fn cooldown_until(&self, id: usize) -> usize {
        self.registry.cooldown_until(id)
    }

    pub fn busy_until(&self, id: usize) -> f64 {
        self.registry.busy_until(id)
    }

    /// Plain state write for the round-synchronous engines (no eligibility
    /// index to maintain — sync rounds rebuild candidates per round).
    pub fn set_cooldown_until(&mut self, id: usize, round: usize) {
        debug_assert!(self.eligible.is_none(), "async populations use begin_cooldown");
        self.registry.set_cooldown_until(id, round);
    }

    /// Plain state write for the round-synchronous engines.
    pub fn set_busy_until(&mut self, id: usize, t: f64) {
        debug_assert!(self.eligible.is_none(), "async populations use mark_busy");
        self.registry.set_busy_until(id, t);
    }

    /// This learner's personal forecaster, trained at first touch on (two
    /// replayed weeks of) its own trace — the paper's "learners maintain a
    /// trace of their charging events" (Appendix A). Learners that never
    /// check in never pay the training cost.
    pub fn forecaster(&self, id: usize) -> &SeasonalForecaster {
        let avail = self.index.availability();
        self.forecasters.get_or_train(id, || {
            let series = avail
                .sample_series(id, FORECAST_STEP)
                .expect("DynAvail always carries a trace");
            SeasonalForecaster::train_on_week(&series, FORECAST_STEP)
        })
    }

    fn candidate(&self, id: usize, now: f64, mu: f64) -> Candidate {
        let avail_prob = match self.avail_mode {
            AvailMode::AllAvail => 1.0,
            AvailMode::DynAvail => {
                // learner-side forecast for the slot (mu, 2mu)
                self.forecaster(id).prob_slot(now + mu, now + 2.0 * mu)
            }
        };
        let expected_duration = self.registry.profile(id).completion_time(
            self.registry.n_samples(id),
            self.local_epochs,
            self.model_bytes,
        );
        Candidate { id, avail_prob, expected_duration }
    }

    /// Checked-in learners with their probe answers (Algorithm 1 steps 1-3)
    /// for the round-synchronous engines: the available set in ascending id
    /// order, cooldown/busy filtered — element-for-element what the
    /// pre-population full scan produced.
    pub fn sync_candidates(&mut self, round: usize, now: f64, mu: f64) -> Vec<Candidate> {
        debug_assert!(self.eligible.is_none(), "async populations use async_candidates");
        self.index.advance_to(now, self.workers);
        let mut out = Vec::new();
        self.index.for_each_available(|id| {
            if self.registry.cooldown_until(id) > round || self.registry.busy_until(id) > now {
                return;
            }
            out.push(self.candidate(id, now, mu));
        });
        out
    }

    /// Bring the async eligibility state up to `(round, now)`: apply
    /// availability flips, expire cooldown buckets, and on first call build
    /// the index + selectable set (the only O(n) pass of an async run).
    pub fn async_sync_to(&mut self, round: usize, now: f64) {
        if self.eligible.is_none() {
            self.index.advance_to(now, self.workers);
            let shards = self.registry.num_shards();
            let mut set = CandidateSet::with_shards(self.registry.len(), shards);
            let mut buckets: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for id in 0..self.registry.len() {
                let cd = self.registry.cooldown_until(id);
                if cd > round {
                    buckets.entry(cd).or_default().push(id);
                    continue;
                }
                if self.index.is_available(id) && self.registry.busy_until(id) <= now {
                    set.insert(id);
                }
            }
            self.eligible = Some(EligibleState { set, buckets });
            return;
        }
        let flips = self.index.advance_to(now, self.workers);
        let elig = self.eligible.as_mut().expect("checked above");
        for (id, _) in flips {
            refresh(elig, &self.index, &self.registry, id, round, now);
        }
        loop {
            let Some((&k, _)) = elig.buckets.first_key_value() else { break };
            if k > round {
                break;
            }
            let (_, ids) = elig.buckets.pop_first().expect("non-empty first key");
            for id in ids {
                refresh(elig, &self.index, &self.registry, id, round, now);
            }
        }
    }

    /// The selectable set (async runs; `async_sync_to` first). Sampling
    /// selectors draw from this directly.
    pub fn eligible_set(&self) -> &CandidateSet {
        &self.eligible.as_ref().expect("async_sync_to before selection").set
    }

    /// Materialized candidates for rank-the-pool selectors (async runs):
    /// the eligible ids in ascending order with their probe answers —
    /// identical to the old full scan's output, built in O(|eligible|).
    pub fn async_candidates(&self, now: f64, mu: f64) -> Vec<Candidate> {
        let elig = self.eligible.as_ref().expect("async_sync_to before selection");
        let mut out = Vec::with_capacity(elig.set.len());
        for id in elig.set.iter() {
            out.push(self.candidate(id, now, mu));
        }
        out
    }

    /// Async hook: a task was spawned on `id`, busy until `until`.
    pub fn mark_busy(&mut self, id: usize, until: f64) {
        self.registry.set_busy_until(id, until);
        if let Some(elig) = self.eligible.as_mut() {
            elig.set.remove(id);
        }
    }

    /// Async hook: `id`'s task ended (arrival or dropout) at `now` — the
    /// learner is selectable again if available and not cooling.
    pub fn release(&mut self, id: usize, round: usize, now: f64) {
        if let Some(elig) = self.eligible.as_mut() {
            refresh(elig, &self.index, &self.registry, id, round, now);
        }
    }

    /// Async hook: `id` enters cooldown until `until` (a future version, so
    /// it leaves the selectable set now and re-enters via the bucket drain).
    pub fn begin_cooldown(&mut self, id: usize, until: usize) {
        self.registry.set_cooldown_until(id, until);
        if let Some(elig) = self.eligible.as_mut() {
            elig.buckets.entry(until).or_default().push(id);
            elig.set.remove(id);
        }
    }

    /// Pre-generate every learner's trace and forecaster — the pre-refactor
    /// eager construction. Tests and benches use this to prove the lazy
    /// path is result-identical and to measure what laziness saves.
    pub fn materialize_all(&self) {
        if matches!(self.index.availability(), Availability::All) {
            return;
        }
        for id in 0..self.registry.len() {
            self.forecaster(id);
        }
    }

    /// Learner traces generated so far (== population size on eager paths).
    pub fn materialized_traces(&self) -> usize {
        match self.index.availability() {
            Availability::All => 0,
            Availability::Dynamic(tr) => tr.len(),
            Availability::Lazy(tr) => tr.materialized(),
        }
    }

    /// Learner forecasters trained so far.
    pub fn trained_forecasters(&self) -> usize {
        self.forecasters.trained()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learners::{HardwareScenario, ProfilePool};
    use crate::trace::{LazyTraceSet, TraceConfig};

    fn mk_population(n: usize, avail: Availability, mode: AvailMode) -> Population {
        let pool = ProfilePool::generate(n, 4, HardwareScenario::Hs1);
        let registry = Registry::eager(pool, vec![8; n], 4);
        Population::new(registry, avail, mode, 1, 1000, 1)
    }

    #[test]
    fn sync_candidates_match_brute_force_predicate() {
        let n = 30;
        let mut p = mk_population(
            n,
            Availability::Lazy(LazyTraceSet::new(n, 6, TraceConfig::default())),
            AvailMode::DynAvail,
        );
        let reference = Availability::Lazy(LazyTraceSet::new(n, 6, TraceConfig::default()));
        p.set_cooldown_until(3, 100);
        p.set_busy_until(5, 1e9);
        for (round, now) in [(0usize, 0.0f64), (1, 900.0), (2, 50_000.0), (3, 400_000.0)] {
            let got: Vec<usize> =
                p.sync_candidates(round, now, 60.0).iter().map(|c| c.id).collect();
            let want: Vec<usize> = (0..n)
                .filter(|&id| {
                    reference.available(id, now)
                        && (id != 3 || round >= 100)
                        && (id != 5)
                })
                .collect();
            assert_eq!(got, want, "round {round} now {now}");
        }
    }

    #[test]
    fn async_eligibility_tracks_busy_and_cooldown() {
        let n = 10;
        let mut p = mk_population(n, Availability::All, AvailMode::AllAvail);
        p.async_sync_to(0, 0.0);
        assert_eq!(p.eligible_set().len(), n);
        p.mark_busy(2, 50.0);
        p.begin_cooldown(7, 2);
        assert!(!p.eligible_set().contains(2));
        assert!(!p.eligible_set().contains(7));
        assert_eq!(p.eligible_set().len(), n - 2);
        // task ends: learner 2 returns
        p.release(2, 0, 50.0);
        assert!(p.eligible_set().contains(2));
        // version advances past the cooldown: learner 7 returns
        p.async_sync_to(2, 60.0);
        assert!(p.eligible_set().contains(7));
        assert_eq!(p.eligible_set().len(), n);
    }

    #[test]
    fn async_candidates_are_id_ordered_and_probed() {
        let n = 6;
        let p_avail = Availability::All;
        let mut p = mk_population(n, p_avail, AvailMode::AllAvail);
        p.async_sync_to(0, 0.0);
        let cands = p.async_candidates(0.0, 100.0);
        assert_eq!(cands.len(), n);
        for (i, c) in cands.iter().enumerate() {
            assert_eq!(c.id, i);
            assert_eq!(c.avail_prob, 1.0);
            assert!(c.expected_duration > 0.0);
        }
    }

    #[test]
    fn stale_cooldown_buckets_are_harmless() {
        let n = 4;
        let mut p = mk_population(n, Availability::All, AvailMode::AllAvail);
        p.async_sync_to(0, 0.0);
        // cooldown set to round 2, then re-set (longer) before expiring
        p.begin_cooldown(1, 2);
        p.begin_cooldown(1, 5);
        p.async_sync_to(2, 10.0); // drains the stale round-2 bucket
        assert!(!p.eligible_set().contains(1), "stale bucket must not resurrect");
        p.async_sync_to(5, 20.0);
        assert!(p.eligible_set().contains(1));
    }
}
