//! The sharded learner registry: *who exists*. Owns every per-learner fact
//! the server tracks outside the data plane — the device profile, the local
//! dataset size, and the two pieces of selection-relevant dynamic state
//! (cooldown round, busy-until time) — split into contiguous id-range
//! shards so population-scale operations (construction, bulk state resets,
//! future cross-thread partitioning) work shard-by-shard.
//!
//! Profiles come in two flavors:
//!
//! * **eager** — wraps a pre-generated [`ProfilePool`] (the sequential-RNG
//!   generator every existing experiment uses; values are untouched by this
//!   refactor, which is what keeps `tests/kernel_equivalence.rs` honest);
//! * **lazy** — per-learner RNG streams sampled at first touch, for
//!   synthetic mega-populations where nothing should be materialized up
//!   front. Lazy profiles draw from the same cluster mixture but a
//!   different RNG threading, so they are a *different* (equally valid)
//!   population, deterministic per (seed, id) and independent of shard
//!   count — never mix the two flavors within one comparison.

use crate::learners::{profiles::sample_profile, DeviceProfile, ProfilePool};
use crate::util::lazy::LazySlots;
use crate::util::rng::Rng;

/// Default number of contiguous id-range shards.
pub const DEFAULT_SHARDS: usize = 8;

enum ShardProfiles {
    Eager(Vec<DeviceProfile>),
    Lazy { root: Rng, base: usize, slots: LazySlots<DeviceProfile> },
}

struct RegistryShard {
    profiles: ShardProfiles,
    n_samples: Vec<u32>,
    cooldown_until: Vec<usize>,
    busy_until: Vec<f64>,
}

impl RegistryShard {
    fn profile(&self, off: usize) -> &DeviceProfile {
        match &self.profiles {
            ShardProfiles::Eager(p) => &p[off],
            ShardProfiles::Lazy { root, base, slots } => slots.get_or_init(off, || {
                let mut rng = root.stream((base + off) as u64);
                sample_profile(&mut rng)
            }),
        }
    }
}

/// Sharded per-learner registry (see the module docs).
pub struct Registry {
    shards: Vec<RegistryShard>,
    shard_size: usize,
    n: usize,
}

impl Registry {
    /// Wrap an eagerly-generated [`ProfilePool`] (the compatibility path:
    /// profile values are bit-identical to the pre-registry coordinator).
    pub fn eager(pool: ProfilePool, n_samples: Vec<u32>, num_shards: usize) -> Registry {
        let n = pool.profiles.len();
        assert_eq!(n, n_samples.len(), "one sample count per profile");
        let shard_size = shard_size_for(n, num_shards);
        let mut profiles = pool.profiles;
        let mut samples = n_samples;
        let mut shards = Vec::new();
        while !profiles.is_empty() || shards.is_empty() {
            let take = shard_size.min(profiles.len());
            let rest_p = profiles.split_off(take);
            let rest_s = samples.split_off(take);
            shards.push(RegistryShard {
                cooldown_until: vec![0; take],
                busy_until: vec![0.0; take],
                profiles: ShardProfiles::Eager(profiles),
                n_samples: samples,
            });
            profiles = rest_p;
            samples = rest_s;
            if take == 0 {
                break; // n == 0: one empty shard
            }
        }
        Registry { shards, shard_size, n }
    }

    /// Per-learner-stream lazy profiles (Hs1-distribution only; no global
    /// top-X% speedup pass is possible without materializing everyone).
    /// Construction is O(n) empty slots; each profile is sampled at first
    /// touch, deterministic per (seed, id) and independent of shard count.
    pub fn lazy(n: usize, seed: u64, mean_samples: u32, num_shards: usize) -> Registry {
        let root = Rng::new(seed ^ 0xDE71CE);
        let shard_size = shard_size_for(n, num_shards);
        let mut shards = Vec::new();
        let mut base = 0usize;
        while base < n || shards.is_empty() {
            let take = shard_size.min(n - base);
            shards.push(RegistryShard {
                profiles: ShardProfiles::Lazy {
                    root: root.clone(),
                    base,
                    slots: LazySlots::new(take),
                },
                n_samples: vec![mean_samples; take],
                cooldown_until: vec![0; take],
                busy_until: vec![0.0; take],
            });
            base += take;
            if take == 0 {
                break;
            }
        }
        Registry { shards, shard_size, n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn at(&self, id: usize) -> (&RegistryShard, usize) {
        (&self.shards[id / self.shard_size], id % self.shard_size)
    }

    pub fn profile(&self, id: usize) -> &DeviceProfile {
        let (s, off) = self.at(id);
        s.profile(off)
    }

    pub fn n_samples(&self, id: usize) -> usize {
        let (s, off) = self.at(id);
        s.n_samples[off] as usize
    }

    pub fn cooldown_until(&self, id: usize) -> usize {
        let (s, off) = self.at(id);
        s.cooldown_until[off]
    }

    pub fn set_cooldown_until(&mut self, id: usize, round: usize) {
        let shard = &mut self.shards[id / self.shard_size];
        shard.cooldown_until[id % self.shard_size] = round;
    }

    pub fn busy_until(&self, id: usize) -> f64 {
        let (s, off) = self.at(id);
        s.busy_until[off]
    }

    pub fn set_busy_until(&mut self, id: usize, t: f64) {
        let shard = &mut self.shards[id / self.shard_size];
        shard.busy_until[id % self.shard_size] = t;
    }
}

fn shard_size_for(n: usize, num_shards: usize) -> usize {
    n.div_ceil(num_shards.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learners::HardwareScenario;

    #[test]
    fn eager_registry_preserves_pool_values_across_shard_counts() {
        let pool = || ProfilePool::generate(50, 9, HardwareScenario::Hs1);
        let flat = pool();
        let samples: Vec<u32> = (0..50).map(|i| 10 + i as u32).collect();
        for shards in [1usize, 4, 8, 13] {
            let reg = Registry::eager(pool(), samples.clone(), shards);
            assert_eq!(reg.len(), 50);
            for id in 0..50 {
                assert_eq!(reg.profile(id), &flat.profiles[id], "{shards} shards, id {id}");
                assert_eq!(reg.n_samples(id), 10 + id);
            }
        }
    }

    #[test]
    fn dynamic_state_round_trips() {
        let reg_pool = ProfilePool::generate(20, 1, HardwareScenario::Hs1);
        let mut reg = Registry::eager(reg_pool, vec![5; 20], 4);
        assert_eq!(reg.cooldown_until(13), 0);
        assert_eq!(reg.busy_until(13), 0.0);
        reg.set_cooldown_until(13, 7);
        reg.set_busy_until(13, 42.5);
        assert_eq!(reg.cooldown_until(13), 7);
        assert_eq!(reg.busy_until(13), 42.5);
        // neighbours untouched
        assert_eq!(reg.cooldown_until(12), 0);
        assert_eq!(reg.busy_until(14), 0.0);
    }

    #[test]
    fn lazy_registry_is_shard_count_independent_and_deterministic() {
        let a = Registry::lazy(100, 77, 8, 1);
        let b = Registry::lazy(100, 77, 8, 8);
        let c = Registry::lazy(100, 77, 8, 7);
        for id in (0..100).rev() {
            let p = a.profile(id);
            assert_eq!(p, b.profile(id), "id {id}: 1 vs 8 shards");
            assert_eq!(p, c.profile(id), "id {id}: 1 vs 7 shards");
            assert!(p.sec_per_sample > 0.0 && p.upload_bps >= 100e3);
            assert_eq!(a.n_samples(id), 8);
        }
    }

    #[test]
    fn empty_population() {
        let reg = Registry::eager(
            ProfilePool::generate(0, 1, HardwareScenario::Hs1),
            Vec::new(),
            8,
        );
        assert!(reg.is_empty());
        assert_eq!(reg.num_shards(), 1);
        let lz = Registry::lazy(0, 1, 4, 8);
        assert!(lz.is_empty());
    }
}
