//! Data-to-learner mappings (paper §5.1 "Data Partitioning"):
//!
//! * **D1 UniformIid** — every learner draws labels uniformly from all
//!   classes, equal-ish sample counts.
//! * **D2 FedScale** — long-tail sample counts (lognormal) with label
//!   locality weak enough that most labels appear on ≳40% of learners
//!   (the paper's §E.1 observation that FedScale maps are near-IID).
//! * **D3 LabelLimited** — each learner holds a random subset of
//!   `labels_per_learner` labels; samples-per-label follow L1 balanced /
//!   L2 uniform / L3 Zipf(α=1.95).

use crate::util::rng::{Rng, ZipfSampler};

/// Per-label skew inside a label-limited learner (paper L1/L2/L3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LabelSkew {
    Balanced,
    Uniform,
    Zipf,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PartitionScheme {
    /// D1: uniform random (IID).
    UniformIid,
    /// D2: FedScale-like real-data mapping (near-IID, long-tail counts).
    FedScale,
    /// D3: label-limited; each learner sees only `labels` classes.
    LabelLimited { labels: usize, skew: LabelSkew },
}

impl PartitionScheme {
    pub fn parse(s: &str) -> Option<PartitionScheme> {
        match s {
            "iid" => Some(PartitionScheme::UniformIid),
            "fedscale" => Some(PartitionScheme::FedScale),
            "label-balanced" => Some(PartitionScheme::LabelLimited {
                labels: 0, // 0 = default per variant, resolved by partitioner
                skew: LabelSkew::Balanced,
            }),
            "label-uniform" => Some(PartitionScheme::LabelLimited {
                labels: 0,
                skew: LabelSkew::Uniform,
            }),
            "label-zipf" => Some(PartitionScheme::LabelLimited {
                labels: 0,
                skew: LabelSkew::Zipf,
            }),
            _ => None,
        }
    }

    pub fn label(&self) -> String {
        match self {
            PartitionScheme::UniformIid => "iid".into(),
            PartitionScheme::FedScale => "fedscale".into(),
            PartitionScheme::LabelLimited { skew, .. } => match skew {
                LabelSkew::Balanced => "label-balanced".into(),
                LabelSkew::Uniform => "label-uniform".into(),
                LabelSkew::Zipf => "label-zipf".into(),
            },
        }
    }
}

/// The label sequence held by one learner (features are generated lazily by
/// `synth::Dataset::features`).
#[derive(Clone, Debug, Default)]
pub struct LearnerShard {
    pub labels: Vec<u16>,
}

impl LearnerShard {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

pub struct Partitioner {
    pub scheme: PartitionScheme,
    pub num_classes: usize,
    /// Mean samples per learner (long-tail around this for FedScale).
    pub mean_samples: usize,
}

impl Partitioner {
    pub fn new(scheme: PartitionScheme, num_classes: usize, mean_samples: usize) -> Self {
        Partitioner { scheme, num_classes, mean_samples }
    }

    /// Default label-limited subset size: ~10% of labels (paper §3.3), at
    /// least 2. Matches Table 1's 4-of-35 for the speech benchmark.
    fn default_labels(&self) -> usize {
        (self.num_classes / 10).max(2)
    }

    /// Assign shards to `n` learners, deterministic per seed.
    pub fn assign(&self, n: usize, seed: u64) -> Vec<LearnerShard> {
        let mut rng = Rng::new(seed ^ 0x9A27_17A0);
        let mut out = Vec::with_capacity(n);
        match self.scheme {
            PartitionScheme::UniformIid => {
                for _ in 0..n {
                    let count = self.jitter_count(&mut rng, 0.2);
                    let labels = (0..count)
                        .map(|_| rng.below(self.num_classes) as u16)
                        .collect();
                    out.push(LearnerShard { labels });
                }
            }
            PartitionScheme::FedScale => {
                for _ in 0..n {
                    // long-tail sample counts: lognormal, mean ~ mean_samples
                    let count = (rng.lognormal(
                        (self.mean_samples as f64).ln() - 0.5,
                        1.0,
                    ) as usize)
                        .clamp(4, self.mean_samples * 20);
                    // weak label locality: a learner-specific preferred
                    // subset gets 50% of the mass, the rest is uniform —
                    // yields "every label on >=40% of learners" (§E.1).
                    let pref: Vec<usize> = rng
                        .choose_k(self.num_classes, (self.num_classes / 2).max(1));
                    let labels = (0..count)
                        .map(|_| {
                            if rng.bool(0.5) {
                                pref[rng.below(pref.len())] as u16
                            } else {
                                rng.below(self.num_classes) as u16
                            }
                        })
                        .collect();
                    out.push(LearnerShard { labels });
                }
            }
            PartitionScheme::LabelLimited { labels, skew } => {
                let l = if labels == 0 { self.default_labels() } else { labels };
                let l = l.min(self.num_classes);
                let zipf = ZipfSampler::new(l, 1.95);
                for _ in 0..n {
                    let subset = rng.choose_k(self.num_classes, l);
                    let count = self.jitter_count(&mut rng, 0.2);
                    let shard_labels: Vec<u16> = match skew {
                        LabelSkew::Balanced => (0..count)
                            .map(|i| subset[i % l] as u16)
                            .collect(),
                        LabelSkew::Uniform => (0..count)
                            .map(|_| subset[rng.below(l)] as u16)
                            .collect(),
                        LabelSkew::Zipf => (0..count)
                            .map(|_| subset[zipf.sample(&mut rng)] as u16)
                            .collect(),
                    };
                    out.push(LearnerShard { labels: shard_labels });
                }
            }
        }
        out
    }

    fn jitter_count(&self, rng: &mut Rng, rel: f64) -> usize {
        let m = self.mean_samples as f64;
        ((m * (1.0 + rel * (rng.f64() * 2.0 - 1.0))) as usize).max(2)
    }
}

/// Fig. 21 analytics: for each label, on what fraction of learners does it
/// appear (any count)?
pub fn label_coverage(shards: &[LearnerShard], num_classes: usize) -> Vec<f64> {
    let mut counts = vec![0usize; num_classes];
    for s in shards {
        let mut seen = vec![false; num_classes];
        for &l in &s.labels {
            seen[l as usize] = true;
        }
        for (c, s) in seen.iter().enumerate() {
            if *s {
                counts[c] += 1;
            }
        }
    }
    counts
        .into_iter()
        .map(|c| c as f64 / shards.len().max(1) as f64)
        .collect()
}

/// Mean number of distinct labels per learner.
pub fn mean_distinct_labels(shards: &[LearnerShard], num_classes: usize) -> f64 {
    let total: usize = shards
        .iter()
        .map(|s| {
            let mut seen = vec![false; num_classes];
            for &l in &s.labels {
                seen[l as usize] = true;
            }
            seen.iter().filter(|&&x| x).count()
        })
        .sum();
    total as f64 / shards.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(scheme: PartitionScheme) -> Vec<LearnerShard> {
        Partitioner::new(scheme, 35, 100).assign(200, 7)
    }

    #[test]
    fn deterministic_per_seed() {
        let p = Partitioner::new(PartitionScheme::UniformIid, 10, 50);
        let a = p.assign(20, 1);
        let b = p.assign(20, 1);
        assert_eq!(
            a.iter().map(|s| &s.labels).collect::<Vec<_>>(),
            b.iter().map(|s| &s.labels).collect::<Vec<_>>()
        );
    }

    #[test]
    fn iid_covers_all_labels_per_learner() {
        let shards = part(PartitionScheme::UniformIid);
        let mean = mean_distinct_labels(&shards, 35);
        assert!(mean > 30.0, "IID should see nearly all labels, got {mean}");
    }

    #[test]
    fn label_limited_restricts_labels() {
        let shards = part(PartitionScheme::LabelLimited {
            labels: 4,
            skew: LabelSkew::Uniform,
        });
        for s in &shards {
            let mut distinct: Vec<u16> = s.labels.clone();
            distinct.sort_unstable();
            distinct.dedup();
            assert!(distinct.len() <= 4);
        }
    }

    #[test]
    fn balanced_skew_is_balanced() {
        let shards = part(PartitionScheme::LabelLimited {
            labels: 4,
            skew: LabelSkew::Balanced,
        });
        for s in shards.iter().take(10) {
            let mut counts = std::collections::HashMap::new();
            for &l in &s.labels {
                *counts.entry(l).or_insert(0usize) += 1;
            }
            let max = counts.values().max().unwrap();
            let min = counts.values().min().unwrap();
            assert!(max - min <= 1, "balanced should differ by <=1");
        }
    }

    #[test]
    fn zipf_skew_is_skewed() {
        let shards = part(PartitionScheme::LabelLimited {
            labels: 4,
            skew: LabelSkew::Zipf,
        });
        // aggregate over learners: rank-0 label within each learner's subset
        // should dominate
        let mut top_frac = 0.0;
        for s in &shards {
            let mut counts = std::collections::HashMap::new();
            for &l in &s.labels {
                *counts.entry(l).or_insert(0usize) += 1;
            }
            let max = *counts.values().max().unwrap();
            top_frac += max as f64 / s.labels.len() as f64;
        }
        top_frac /= shards.len() as f64;
        assert!(top_frac > 0.55, "zipf(1.95) top label share {top_frac}");
    }

    #[test]
    fn fedscale_near_iid_coverage() {
        let shards = part(PartitionScheme::FedScale);
        let cov = label_coverage(&shards, 35);
        // paper §E.1: most labels appear on >= 40% of learners
        let frac_covered = cov.iter().filter(|&&c| c >= 0.4).count() as f64 / 35.0;
        assert!(frac_covered > 0.8, "coverage {frac_covered}");
    }

    #[test]
    fn fedscale_long_tail_counts() {
        let shards = part(PartitionScheme::FedScale);
        let counts: Vec<f64> = shards.iter().map(|s| s.len() as f64).collect();
        let p90 = crate::util::stats::percentile(&counts, 90.0);
        let p50 = crate::util::stats::percentile(&counts, 50.0);
        assert!(p90 > 2.0 * p50, "long tail expected: p90={p90} p50={p50}");
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in ["iid", "fedscale", "label-balanced", "label-uniform", "label-zipf"] {
            let scheme = PartitionScheme::parse(s).unwrap();
            assert_eq!(scheme.label(), s);
        }
        assert!(PartitionScheme::parse("bogus").is_none());
    }
}
