//! Synthetic classification corpus: per-class Gaussian prototype mixtures.
//!
//! Substitutes the paper's Google Speech / CIFAR10 / OpenImage / Reddit /
//! StackOverflow datasets (DESIGN.md §2). The learnable structure —
//! class-conditional feature distributions — is what the selection and
//! aggregation experiments exercise: under label-limited mappings a learner
//! only sees a subset of prototypes, so its local updates drift exactly the
//! way non-IID FL updates drift.
//!
//! Features are generated *lazily and deterministically* from
//! (dataset seed, learner id, sample index), so thousand-learner populations
//! cost no storage.

use crate::runtime::VariantInfo;
use crate::util::rng::Rng;

/// A synthetic dataset: class prototypes + noise model.
pub struct Dataset {
    pub seed: u64,
    pub num_classes: usize,
    pub input_dim: usize,
    /// prototypes[c * input_dim + d]
    prototypes: Vec<f32>,
    /// Within-class noise stddev. 1.0 gives a learnable-but-not-trivial
    /// task for the default dims (Bayes accuracy well below 100%).
    pub noise: f32,
    /// Second "hard direction": a fraction of within-class variance aligned
    /// with other prototypes, so classes overlap and local SGD can overfit.
    pub confusion: f32,
}

impl Dataset {
    pub fn new(v: &VariantInfo, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0xDA7A_5EED);
        let n = v.num_classes * v.input_dim;
        let scale = 1.0f64;
        let prototypes: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
        Dataset {
            seed,
            num_classes: v.num_classes,
            input_dim: v.input_dim,
            prototypes,
            // Per-dim noise scaled so class separability (which grows with
            // sqrt(input_dim)) is comparable across variants; calibrated so
            // the speech stand-in's semi-centralized ceiling lands near the
            // paper's (~75%), leaving headroom for non-IID degradation.
            noise: 2.2 * (v.input_dim as f32 / 256.0).sqrt(),
            confusion: 0.5,
        }
    }

    /// Deterministic feature vector for (owner, sample index, label).
    pub fn features(&self, owner: u64, sample_idx: u64, label: usize) -> Vec<f32> {
        debug_assert!(label < self.num_classes);
        let mut rng = Rng::new(self.seed)
            .stream(owner.wrapping_mul(0x9E37_79B9).wrapping_add(sample_idx));
        let proto = &self.prototypes[label * self.input_dim..(label + 1) * self.input_dim];
        // confusion: blend in a second random prototype
        let other = rng.below(self.num_classes);
        let oproto = &self.prototypes[other * self.input_dim..(other + 1) * self.input_dim];
        let mix = self.confusion * rng.f64() as f32;
        (0..self.input_dim)
            .map(|d| {
                proto[d] * (1.0 - mix)
                    + oproto[d] * mix
                    + (rng.normal() as f32) * self.noise
            })
            .collect()
    }

    /// Build a held-out test set with `per_class` samples per class.
    /// Owner id u64::MAX is reserved for test data (never a learner id).
    pub fn test_set(&self, per_class: usize) -> TestSet {
        let mut xs = Vec::with_capacity(per_class * self.num_classes * self.input_dim);
        let mut ys = Vec::with_capacity(per_class * self.num_classes);
        for c in 0..self.num_classes {
            for i in 0..per_class {
                let f = self.features(u64::MAX, (c * per_class + i) as u64, c);
                xs.extend_from_slice(&f);
                ys.push(c as i32);
            }
        }
        TestSet { x: xs, y: ys, input_dim: self.input_dim }
    }
}

/// Held-out evaluation data (global, never on any learner).
pub struct TestSet {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub input_dim: usize,
}

impl TestSet {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Iterate fixed-size batches (padded + masked) for the executor.
    pub fn batches(&self, batch: usize) -> Vec<(Vec<f32>, Vec<i32>, Vec<f32>)> {
        let d = self.input_dim;
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.len() {
            let n = (self.len() - i).min(batch);
            let mut x = vec![0f32; batch * d];
            let mut y = vec![0i32; batch];
            let mut m = vec![0f32; batch];
            x[..n * d].copy_from_slice(&self.x[i * d..(i + n) * d]);
            y[..n].copy_from_slice(&self.y[i..i + n]);
            for mm in m.iter_mut().take(n) {
                *mm = 1.0;
            }
            out.push((x, y, m));
            i += n;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin_variant;

    fn ds() -> Dataset {
        Dataset::new(&builtin_variant("tiny"), 42)
    }

    #[test]
    fn features_deterministic() {
        let d = ds();
        assert_eq!(d.features(3, 7, 1), d.features(3, 7, 1));
        assert_ne!(d.features(3, 7, 1), d.features(3, 8, 1));
        assert_ne!(d.features(3, 7, 1), d.features(4, 7, 1));
    }

    #[test]
    fn features_cluster_around_prototypes() {
        let d = ds();
        // mean of many samples of one class should be closer to that class
        // prototype than to others
        let n = 400;
        let dim = d.input_dim;
        let mut mean = vec![0f64; dim];
        for i in 0..n {
            let f = d.features(1, i as u64, 2);
            for j in 0..dim {
                mean[j] += f[j] as f64 / n as f64;
            }
        }
        let dist = |c: usize| -> f64 {
            let proto = &d.prototypes[c * dim..(c + 1) * dim];
            mean.iter()
                .zip(proto)
                .map(|(m, p)| (m - *p as f64).powi(2))
                .sum()
        };
        let own = dist(2);
        for c in 0..d.num_classes {
            if c != 2 {
                assert!(own < dist(c), "class 2 mean closer to {c}");
            }
        }
    }

    #[test]
    fn test_set_shapes_and_balance() {
        let d = ds();
        let t = d.test_set(5);
        assert_eq!(t.len(), 20);
        assert_eq!(t.x.len(), 20 * d.input_dim);
        for c in 0..4 {
            assert_eq!(t.y.iter().filter(|&&y| y == c).count(), 5);
        }
    }

    #[test]
    fn batches_pad_and_mask() {
        let d = ds();
        let t = d.test_set(5); // 20 samples
        let batches = t.batches(8); // 8+8+4
        assert_eq!(batches.len(), 3);
        let (x, _, m) = &batches[2];
        assert_eq!(m.iter().sum::<f32>(), 4.0);
        assert_eq!(x.len(), 8 * d.input_dim);
        // padding features are zero
        assert!(x[4 * d.input_dim..].iter().all(|&v| v == 0.0));
    }
}
