//! Data substrate: synthetic benchmark corpora + the paper's data-to-learner
//! mappings (D1 uniform IID, D2 FedScale-like, D3 label-limited with
//! balanced / uniform / Zipf per-label skew), plus label analytics (Fig. 21).

pub mod partition;
pub mod synth;

pub use partition::{LearnerShard, PartitionScheme, Partitioner};
pub use synth::{Dataset, TestSet};
