//! Device-capability profiles, substituting the AI-Benchmark + MobiPerf
//! measurements the paper samples from (§5.1 "System Performance of
//! Learners", §C).
//!
//! The paper's analysis shows (Fig. 13): a long-tail distribution of
//! per-sample inference/training time that clusters into **6 device
//! classes**, and WiFi-grade network speeds. We generate profiles from a
//! 6-component lognormal mixture whose centers span ~20x (matching the
//! published CDF's dynamic range) and network speeds from a lognormal
//! around 20 Mbps.

use crate::util::rng::Rng;
use crate::util::stats;

/// Compute/communication capability of one learner device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Seconds of on-device compute per (sample, epoch) of local training.
    pub sec_per_sample: f64,
    /// Upload bandwidth in bytes/second (model update upload).
    pub upload_bps: f64,
    /// Download bandwidth in bytes/second (global model fetch).
    pub download_bps: f64,
    /// Which of the 6 clusters this device was drawn from (0 = fastest).
    pub cluster: usize,
}

impl DeviceProfile {
    /// Wall-clock seconds for one full local-training task.
    pub fn completion_time(&self, samples: usize, epochs: usize, model_bytes: usize) -> f64 {
        let compute = self.sec_per_sample * samples as f64 * epochs as f64;
        let comm = model_bytes as f64 / self.download_bps + model_bytes as f64 / self.upload_bps;
        compute + comm
    }

    /// Compute-only portion (used for straggler remaining-time probes).
    pub fn compute_time(&self, samples: usize, epochs: usize) -> f64 {
        self.sec_per_sample * samples as f64 * epochs as f64
    }
}

/// Hardware-advancement scenarios of §5.4: completion times of the top X%
/// of devices are halved ("completion times doubled" in speed terms).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HardwareScenario {
    /// HS1: current device configurations.
    Hs1,
    /// HS2: top 25% of devices 2x faster.
    Hs2,
    /// HS3: top 75% of devices 2x faster.
    Hs3,
    /// HS4: all devices 2x faster.
    Hs4,
}

impl HardwareScenario {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "hs1" => Some(Self::Hs1),
            "hs2" => Some(Self::Hs2),
            "hs3" => Some(Self::Hs3),
            "hs4" => Some(Self::Hs4),
            _ => None,
        }
    }

    /// Fraction of (fastest-first) devices that get the 2x speedup.
    fn top_fraction(&self) -> f64 {
        match self {
            Self::Hs1 => 0.0,
            Self::Hs2 => 0.25,
            Self::Hs3 => 0.75,
            Self::Hs4 => 1.0,
        }
    }
}

/// Cluster centers: seconds of compute per sample-epoch. Spans ~24x like the
/// paper's device CDF; cluster populations are tail-heavier toward slow
/// devices (weights below).
// Calibrated so the bulk of 100-sample local tasks complete within the
// paper's 100 s reporting deadline while the slow tail still straggles
// (matching the paper's setting where deadlines are mostly met).
const CLUSTER_SEC_PER_SAMPLE: [f64; 6] = [0.02, 0.036, 0.065, 0.12, 0.22, 0.48];
const CLUSTER_WEIGHTS: [f64; 6] = [0.22, 0.26, 0.20, 0.16, 0.10, 0.06];

/// Draw one device profile from the 6-cluster mixture. The sequential
/// [`ProfilePool::generate`] loop and the per-learner-stream lazy registry
/// path (`population::Registry::lazy`) both come through here, so the
/// *distribution* is shared even though the two paths thread RNG state
/// differently (one stream vs one stream per learner).
pub fn sample_profile(rng: &mut Rng) -> DeviceProfile {
    let cluster = rng.weighted(&CLUSTER_WEIGHTS);
    let center = CLUSTER_SEC_PER_SAMPLE[cluster];
    let sec_per_sample = rng.lognormal(center.ln(), 0.25);
    // WiFi-grade network: ~20 Mbps median upload, long-tailed.
    let upload_bps = rng.lognormal((20e6f64 / 8.0).ln(), 0.6).max(100e3);
    let download_bps = upload_bps * rng.uniform(1.2, 2.5);
    DeviceProfile { sec_per_sample, upload_bps, download_bps, cluster }
}

/// A population of device profiles.
pub struct ProfilePool {
    pub profiles: Vec<DeviceProfile>,
}

impl ProfilePool {
    /// Sample `n` device profiles, deterministic per seed.
    pub fn generate(n: usize, seed: u64, scenario: HardwareScenario) -> ProfilePool {
        let mut rng = Rng::new(seed ^ 0xDE71CE);
        let mut profiles = Vec::with_capacity(n);
        for _ in 0..n {
            profiles.push(sample_profile(&mut rng));
        }
        // Apply the hardware-advancement scenario to the top X% fastest.
        let frac = scenario.top_fraction();
        if frac > 0.0 {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| {
                profiles[a].sec_per_sample.total_cmp(&profiles[b].sec_per_sample)
            });
            let k = ((n as f64) * frac).round() as usize;
            for &i in order.iter().take(k) {
                profiles[i].sec_per_sample /= 2.0;
                profiles[i].upload_bps *= 2.0;
                profiles[i].download_bps *= 2.0;
            }
        }
        ProfilePool { profiles }
    }

    pub fn get(&self, learner: usize) -> &DeviceProfile {
        &self.profiles[learner]
    }

    /// Fig. 13a: CDF of per-sample times at the given evaluation points.
    pub fn speed_cdf(&self, points: &[f64]) -> Vec<f64> {
        let xs: Vec<f64> = self.profiles.iter().map(|p| p.sec_per_sample).collect();
        stats::ecdf(&xs, points)
    }

    /// Fig. 13b: cluster the speed distribution with k-means (k=6) and
    /// return (centroids, cluster populations).
    pub fn speed_clusters(&self, seed: u64) -> (Vec<f64>, Vec<usize>) {
        let xs: Vec<f64> = self.profiles.iter().map(|p| p.sec_per_sample.ln()).collect();
        let (centroids, assign) = stats::kmeans_1d(&xs, 6, 30, seed);
        let mut pops = vec![0usize; 6];
        for a in assign {
            pops[a] += 1;
        }
        (centroids.into_iter().map(f64::exp).collect(), pops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> ProfilePool {
        ProfilePool::generate(2000, 3, HardwareScenario::Hs1)
    }

    #[test]
    fn deterministic() {
        let a = ProfilePool::generate(50, 9, HardwareScenario::Hs1);
        let b = ProfilePool::generate(50, 9, HardwareScenario::Hs1);
        assert_eq!(a.profiles, b.profiles);
    }

    #[test]
    fn long_tail_speeds() {
        let p = pool();
        let xs: Vec<f64> = p.profiles.iter().map(|d| d.sec_per_sample).collect();
        let p95 = stats::percentile(&xs, 95.0);
        let p50 = stats::percentile(&xs, 50.0);
        assert!(p95 / p50 > 3.0, "tail ratio {}", p95 / p50);
    }

    #[test]
    fn six_clusters_recoverable() {
        let p = pool();
        let (centroids, pops) = p.speed_clusters(1);
        assert_eq!(centroids.len(), 6);
        assert!(centroids.windows(2).all(|w| w[0] < w[1]));
        assert!(pops.iter().all(|&c| c > 0), "{pops:?}");
        // total span ~20x like the paper's CDF
        assert!(centroids[5] / centroids[0] > 8.0);
    }

    #[test]
    fn completion_time_components() {
        let d = DeviceProfile {
            sec_per_sample: 0.1,
            upload_bps: 1e6,
            download_bps: 2e6,
            cluster: 0,
        };
        let t = d.completion_time(100, 2, 1_000_000);
        // compute 0.1*100*2 = 20s; comm 1/2 + 1 = 1.5s
        assert!((t - 21.5).abs() < 1e-9);
        assert!((d.compute_time(100, 2) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn hs4_speeds_everyone_up_2x() {
        let base = ProfilePool::generate(300, 5, HardwareScenario::Hs1);
        let fast = ProfilePool::generate(300, 5, HardwareScenario::Hs4);
        for (a, b) in base.profiles.iter().zip(&fast.profiles) {
            assert!((a.sec_per_sample / b.sec_per_sample - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn hs2_speeds_up_only_top_quartile() {
        let base = ProfilePool::generate(400, 5, HardwareScenario::Hs1);
        let fast = ProfilePool::generate(400, 5, HardwareScenario::Hs2);
        let changed = base
            .profiles
            .iter()
            .zip(&fast.profiles)
            .filter(|(a, b)| a.sec_per_sample != b.sec_per_sample)
            .count();
        assert_eq!(changed, 100);
        // and the changed ones are the fastest of the base population
        let mut base_sorted: Vec<f64> =
            base.profiles.iter().map(|p| p.sec_per_sample).collect();
        base_sorted.sort_by(|a, b| a.total_cmp(b));
        let threshold = base_sorted[99];
        for (a, b) in base.profiles.iter().zip(&fast.profiles) {
            if a.sec_per_sample != b.sec_per_sample {
                assert!(a.sec_per_sample <= threshold * 1.0000001);
            }
        }
    }

    #[test]
    fn cdf_is_monotone() {
        let p = pool();
        let cdf = p.speed_cdf(&[0.01, 0.1, 0.5, 1.0, 5.0]);
        assert!(cdf.windows(2).all(|w| w[0] <= w[1]));
        assert!(*cdf.last().unwrap() > 0.95);
    }
}
