//! Learner substrate: device-capability profiles (compute + network speeds,
//! 6-cluster long-tail per paper §C / Fig. 13) and per-learner state used by
//! the coordinator.

pub mod profiles;

pub use profiles::{DeviceProfile, HardwareScenario, ProfilePool};
