//! `relay` — the CLI for the RELAY resource-efficient FL reproduction.
//!
//! Subcommands:
//!   run           one experiment from a JSON config (--config) or flags
//!   sweep         a parallel experiment grid (selectors x modes x avails x
//!                 partitions x seeds) with one aggregated JSON report
//!   figure <id>   regenerate a paper figure/table (2..21, t1, t2, forecast, all)
//!   bench         population-scale benchmarks: --suite population
//!                 (construct + select + async merges at 100k/1M learners
//!                 -> BENCH_population.json), --suite selection
//!                 (per-selector indexed vs materializing selection cost
//!                 -> BENCH_selection.json), --suite train (intra-round
//!                 training-pool width 1-vs-8 wall-clock with byte-identity
//!                 asserted -> BENCH_train.json, gated in CI via --gate),
//!                 and --suite coord (steady-state sync_to + selection at
//!                 K=1 vs K=cores coordinator shards, byte-identity
//!                 asserted -> BENCH_coord.json, gated via --gate)
//!   scenario      list the registered scenario presets (run with
//!                 `relay run --scenario <name>`)
//!   fuzz          differential fuzz runner: random scenario+seed tuples ->
//!                 engine-vs-reference + workers-1-vs-N + accounting/JSON
//!                 invariants; failures shrink into tests/corpus/
//!   replay <arg>  re-derive an ExperimentResult from an event-sourced run
//!                 log (a --runlog directory), or — given a config / fuzz
//!                 corpus entry — run the engine with logging and check the
//!                 replay oracle reproduces the result byte-for-byte
//!   watch <dir>   live observability: tail a --runlog directory while it's
//!                 being written, streaming a plain-terminal dashboard
//!                 (default), JSONL snapshots (--jsonl), or a one-shot
//!                 render (--once); --out exports the final result, which
//!                 byte-matches `relay replay <dir> --out`
//!   trace-stats   availability-trace statistics (Fig. 14 numbers)
//!   forecast-eval availability-prediction quality (5.2)
//!   validate      check artifacts + backends and exit

use std::sync::Arc;

use anyhow::{anyhow, Result};

use relay::config::{preset, AvailMode, ExpConfig, RoundMode};
use relay::coordinator::run_experiment;
use relay::data::partition::PartitionScheme;
use relay::figures::{self, runner::FigureOpts};
use relay::runtime::{self, Backend};
use relay::scenario::faults::FaultConfig;
use relay::util::cli::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn backend(args: &Args) -> Result<Backend> {
    Backend::parse(&args.str_or("backend", "pjrt"))
        .ok_or_else(|| anyhow!("--backend must be pjrt|native"))
}

fn figure_opts(args: &Args) -> Result<FigureOpts> {
    Ok(FigureOpts {
        artifacts_dir: args.str_or("artifacts", "artifacts"),
        backend: backend(args)?,
        scale: args.f64_or("scale", 0.3),
        out_dir: args.str_or("out", "results"),
        seeds: args.usize_or("seeds", 1),
        verbose: args.bool("verbose"),
        workers: args.usize_or("workers", 1),
    })
}

fn real_main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("figure") => {
            let id = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: relay figure <id> [--scale 0.3] [--seeds 1]"))?;
            figures::run(id, &figure_opts(&args)?)
        }
        Some("trace-stats") => figures::run("14", &figure_opts(&args)?),
        Some("forecast-eval") => figures::run("forecast", &figure_opts(&args)?),
        Some("bench") => cmd_bench(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("fuzz") => cmd_fuzz(&args),
        Some("replay") => cmd_replay(&args),
        Some("watch") => cmd_watch(&args),
        Some("validate") => cmd_validate(&args),
        Some(other) => Err(anyhow!(
            "unknown command '{other}' (run|sweep|figure|bench|scenario|fuzz|replay|watch|trace-stats|forecast-eval|validate)"
        )),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg: ExpConfig = if let Some(name) = args.str_opt("scenario") {
        relay::scenario::by_name(name)
            .ok_or_else(|| anyhow!("unknown scenario '{name}' (list them with `relay scenario`)"))?
            .cfg
    } else if let Some(path) = args.str_opt("config") {
        ExpConfig::load(path)?
    } else {
        preset(&args.str_or("benchmark", "speech"))?
    };
    // flag overrides
    if let Some(sel) = args.str_opt("selector") {
        if sel == "relay" {
            cfg = cfg.relay();
        } else {
            cfg.selector = sel.into();
        }
    }
    cfg.total_learners = args.usize_or("learners", cfg.total_learners);
    cfg.rounds = args.usize_or("rounds", cfg.rounds);
    cfg.target_participants = args.usize_or("participants", cfg.target_participants);
    cfg.seed = args.u64_or("seed", cfg.seed);
    // width of the intra-round training pool; results are byte-identical at
    // any width (0 = inherit --workers / autodetect, 1 = strictly serial)
    cfg.train_workers = args.usize_or("train-workers", cfg.train_workers);
    // coordinator shard count; results are byte-identical for any K
    // (0 = autodetect from the core count, 1 = the flat path)
    cfg.coord_shards = args.usize_or("coord-shards", cfg.coord_shards);
    // multi-job: N concurrent jobs over one shared device fleet (1 = the
    // classic single-job engines)
    cfg.jobs = args.usize_or("jobs", cfg.jobs);
    if let Some(p) = args.str_opt("job-policy") {
        cfg.job_policy = p.into();
    }
    if let Some(sels) = args.str_opt("job-selectors") {
        cfg.job_selectors = sels.split(',').map(|x| x.trim().to_string()).collect();
    }
    if let Some(modes) = args.str_opt("job-modes") {
        cfg.job_modes = modes.split(',').map(|x| x.trim().to_string()).collect();
    }
    if let Some(t) = args.str_opt("job-targets") {
        cfg.job_targets = t
            .split(',')
            .map(|x| x.trim().parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|_| anyhow!("--job-targets expects comma-separated integers"))?;
    }
    if let Some(p) = args.str_opt("job-priorities") {
        cfg.job_priorities = p
            .split(',')
            .map(|x| x.trim().parse::<u64>())
            .collect::<Result<_, _>>()
            .map_err(|_| anyhow!("--job-priorities expects comma-separated integers"))?;
    }
    if let Some(p) = args.str_opt("partition") {
        cfg.partition = PartitionScheme::parse(p).ok_or_else(|| anyhow!("bad --partition"))?;
    }
    if let Some(a) = args.str_opt("avail") {
        cfg.avail = match a {
            "all" => AvailMode::AllAvail,
            "dyn" => AvailMode::DynAvail,
            _ => return Err(anyhow!("--avail must be all|dyn")),
        };
    }
    if let Some(d) = args.str_opt("deadline") {
        if args.str_opt("buffer-k").is_some() {
            return Err(anyhow!("--deadline and --buffer-k select conflicting round modes"));
        }
        cfg.mode = RoundMode::Deadline { deadline: d.parse()? };
    }
    if let Some(k) = args.str_opt("buffer-k") {
        // buffered-async regime: merge every K arrivals (FedBuff-style).
        // A staleness bound already loaded via --config is preserved unless
        // --max-staleness overrides it.
        let prior = match cfg.mode {
            RoundMode::Async { max_staleness, .. } => max_staleness,
            _ => None,
        };
        let max_staleness = match args.str_opt("max-staleness") {
            Some(s) => Some(s.parse::<usize>()?),
            None => prior,
        };
        cfg.mode = RoundMode::Async { buffer_k: k.parse()?, max_staleness };
    } else if let Some(s) = args.str_opt("max-staleness") {
        match cfg.mode {
            RoundMode::Async { buffer_k, .. } => {
                cfg.mode = RoundMode::Async {
                    buffer_k,
                    max_staleness: Some(s.parse::<usize>()?),
                };
            }
            _ => {
                return Err(anyhow!(
                    "--max-staleness requires an async mode (--buffer-k or an async --config)"
                ))
            }
        }
    }
    if let Some(spec) = args.str_opt("faults") {
        cfg.faults = FaultConfig::parse_spec(spec)?;
    }
    if cfg.label.is_empty() {
        cfg.label = format!("{}-{}", cfg.selector, cfg.partition.label());
    }
    cfg.validate()?;

    let exec = match backend(args)? {
        Backend::Pjrt => runtime::load_executor(
            &args.str_or("artifacts", "artifacts"),
            &cfg.variant,
            Backend::Pjrt,
        )?,
        Backend::Native => Arc::new(runtime::NativeExecutor::new(
            runtime::builtin_variant(&cfg.variant),
        )),
    };
    let sink: Option<Box<dyn relay::runlog::LogSink>> = match args.str_opt("runlog") {
        Some(dir) => Some(Box::new(relay::runlog::DirSink::create(dir)?)),
        None => None,
    };
    if cfg.jobs > 1 {
        // N concurrent jobs over one shared fleet, arbitrated per
        // eligibility delta; seed-deterministic and byte-identical at any
        // --workers / --train-workers / --coord-shards
        if args.bool("live") {
            return Err(anyhow!(
                "--live is not wired for multi-job runs; pass --runlog DIR and tail it \
                 with `relay watch DIR`"
            ));
        }
        let result = match sink {
            Some(sink) => relay::jobs::run_jobset_logged(cfg, exec, sink)?,
            None => relay::jobs::run_jobset(cfg, exec)?,
        };
        println!("{}", result.summary());
        if let Some(out) = args.str_opt("out") {
            std::fs::write(out, result.to_json().to_string())?;
            println!("wrote {out}");
        }
        return Ok(());
    }
    let result = if args.bool("live") {
        // opt-in live telemetry: the run feeds an in-process observer and a
        // side thread prints one status line per interval to stderr. The
        // result path is untouched — byte-identical to the same run without
        // --live (tests/telemetry_props.rs pins this).
        use std::sync::atomic::{AtomicBool, Ordering};
        let shared = relay::telemetry::SharedStream::new();
        let logger = match sink {
            Some(sink) => {
                relay::runlog::RunLogger::new(sink).with_observer(shared.observer())
            }
            None => relay::runlog::RunLogger::observing(shared.observer()),
        };
        let stop = Arc::new(AtomicBool::new(false));
        let ticker = {
            let stop = Arc::clone(&stop);
            let shared = shared.clone();
            let interval = args.u64_or("interval-ms", 1000).max(1);
            std::thread::spawn(move || loop {
                std::thread::sleep(std::time::Duration::from_millis(interval));
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let line = shared.with(|s| {
                    let lv = s.live();
                    let acc = s.reducer().records().iter().rev().find_map(|r| r.test_accuracy);
                    format!(
                        "[live] rounds {:>4}/{}  sim {:.0}s  spent {:.0}s  wasted {:.0}s  acc {}",
                        lv.rounds_done,
                        lv.rounds_total,
                        lv.sim_time,
                        lv.spent,
                        lv.wasted,
                        acc.map(|a| format!("{:.1}%", 100.0 * a))
                            .unwrap_or_else(|| "-".into()),
                    )
                });
                eprintln!("{line}");
            })
        };
        let r = relay::coordinator::run_experiment_instrumented(cfg, exec, logger);
        stop.store(true, Ordering::Relaxed);
        let _ = ticker.join();
        r?
    } else if let Some(sink) = sink {
        relay::coordinator::run_experiment_logged(cfg, exec, sink)?
    } else {
        run_experiment(cfg, exec)?
    };
    for r in &result.rounds {
        if let Some(acc) = r.test_accuracy {
            println!(
                "round {:>5}  time {:>8.0}s  res {:>8.2}h  waste {:>5.1}%  acc {:>5.1}%",
                r.round,
                r.sim_time,
                r.cum_resource_secs / 3600.0,
                100.0 * r.cum_waste_secs / r.cum_resource_secs.max(1e-9),
                100.0 * acc
            );
        }
    }
    println!("{}", result.summary());
    if let Some(out) = args.str_opt("out") {
        std::fs::write(out, result.to_json().to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `relay sweep`: expand a declarative grid (selectors x modes x avails x
/// partitions x seeds) and execute it concurrently on the sweep engine.
fn cmd_sweep(args: &Args) -> Result<()> {
    use relay::sweep::{run_grid, GridSpec, SweepOpts};

    let mut base = preset(&args.str_or("variant", "tiny"))?;
    base.total_learners = args.usize_or("learners", 60);
    base.rounds = args.usize_or("rounds", 15);
    base.target_participants = args.usize_or("participants", 8);
    base.eval_every = args.usize_or("eval-every", base.eval_every);
    base.seed = args.u64_or("seed", 1);

    let selectors = args.list_or("selectors", "random,oort,priority,safa");
    let mut modes = Vec::new();
    for m in args.list_or("modes", "oc,dl") {
        modes.push(match m.as_str() {
            "oc" => RoundMode::OverCommit { factor: args.f64_or("oc-factor", 1.3) },
            "dl" => RoundMode::Deadline { deadline: args.f64_or("deadline", 100.0) },
            "async" => RoundMode::Async {
                buffer_k: args.usize_or("buffer-k", 10),
                max_staleness: args
                    .str_opt("max-staleness")
                    .map(|s| s.parse::<usize>())
                    .transpose()?,
            },
            other => {
                return Err(anyhow!("--modes entries must be oc|dl|async, got '{other}'"))
            }
        });
    }
    let mut avails = Vec::new();
    for a in args.list_or("avails", "dyn") {
        avails.push(match a.as_str() {
            "all" => AvailMode::AllAvail,
            "dyn" => AvailMode::DynAvail,
            other => return Err(anyhow!("--avails entries must be all|dyn, got '{other}'")),
        });
    }
    let mut partitions = Vec::new();
    for p in args.list_or("partitions", "iid") {
        partitions
            .push(PartitionScheme::parse(&p).ok_or_else(|| anyhow!("bad partition '{p}'"))?);
    }
    if let Some(spec) = args.str_opt("faults") {
        base.faults = FaultConfig::parse_spec(spec)?;
    }
    let n_seeds = args.usize_or("seeds", 3).max(1);
    let seeds: Vec<u64> = (0..n_seeds as u64).map(|s| base.seed + s * 1000).collect();
    // coordination-perf axis: results are byte-identical for any K, so a
    // multi-K sweep compares wall-clock, never accuracy
    let mut coord_shards = Vec::new();
    for k in args.list_or("coord-shards", &base.coord_shards.to_string()) {
        coord_shards
            .push(k.parse::<usize>().map_err(|_| anyhow!("bad --coord-shards entry '{k}'"))?);
    }
    // multi-job axis: cells with jobs > 1 run through the jobset engine
    let mut jobs = Vec::new();
    for j in args.list_or("jobs", &base.jobs.to_string()) {
        jobs.push(j.parse::<usize>().map_err(|_| anyhow!("bad --jobs entry '{j}'"))?);
    }

    let spec = GridSpec {
        label: args.str_or("label", "sweep"),
        selectors,
        modes,
        avails,
        partitions,
        coord_shards,
        jobs,
        seeds,
        base,
    };
    let exec = figure_opts(args)?.executor(&spec.base.variant)?;
    let opts = SweepOpts {
        workers: args.usize_or("workers", 0),
        progress: !args.bool("quiet"),
    };
    let t0 = std::time::Instant::now();
    let report = run_grid(&spec, exec, &opts)?;
    println!(
        "sweep '{}': {} cells, {} runs, {:.1}s wall-clock",
        report.label,
        report.cells.len(),
        report.runs,
        t0.elapsed().as_secs_f64()
    );
    report.print_table();
    let out = args.str_or("report", "results/sweep.json");
    report.save(&out)?;
    println!("  -> report saved to {out}");
    Ok(())
}

/// `relay bench`: population-scale benchmarks. `--suite population`
/// (default) measures substrate construction + a full lazy DynAvail
/// buffered-async cell (`BENCH_population.json`); `--suite selection`
/// measures per-selector per-selection cost on the indexed vs the
/// materializing path at 100k/1M pools, appending a run to
/// `BENCH_selection.json`; `--suite train` measures intra-round training
/// wall-clock at pool widths 1 vs 8 on a mega-async-shaped cell (byte-
/// identity asserted, run appended to `BENCH_train.json`, `--gate` fails
/// on regression vs the last committed point); `--suite coord` measures
/// the sharded coordination hot path (steady-state `sync_to` + selection
/// at K=1 vs K=cores, byte-identity asserted, run appended to
/// `BENCH_coord.json`, gated like train via `--gate`); `--suite all` runs
/// all four. Per-event / per-selection cost staying flat as the
/// population grows 10x is the acceptance signal for the sub-linear
/// selection pipeline; the workers-8 / K-cores speedups are the signals
/// for the train pool and the coordinator shards.
fn cmd_bench(args: &Args) -> Result<()> {
    match args.str_or("suite", "population").as_str() {
        "population" => cmd_bench_population(args),
        "selection" => cmd_bench_selection(args),
        "train" => cmd_bench_train(args),
        "coord" => cmd_bench_coord(args),
        "all" => {
            cmd_bench_population(args)?;
            cmd_bench_selection(args)?;
            cmd_bench_train(args)?;
            cmd_bench_coord(args)
        }
        other => {
            Err(anyhow!("--suite must be population|selection|train|coord|all, got '{other}'"))
        }
    }
}

fn cmd_bench_population(args: &Args) -> Result<()> {
    use relay::config::RoundMode;
    use relay::coordinator::Coordinator;
    use relay::population::{AvailabilityIndex, Registry};
    use relay::sim::Availability;
    use relay::trace::{LazyTraceSet, TraceConfig};
    use relay::util::json::{arr, num, obj, Json};
    use std::time::Instant;

    let mut populations = Vec::new();
    for p in args.list_or("populations", "100000,1000000") {
        let n: usize = p
            .parse()
            .map_err(|_| anyhow!("--populations expects integers, got '{p}'"))?;
        if n == 0 {
            return Err(anyhow!("--populations entries must be >= 1"));
        }
        populations.push(n);
    }
    let merges = args.usize_or("merges", 50);
    let target = args.usize_or("participants", 100);
    let workers = args.usize_or("workers", 0);
    let out = args.str_or("out", "BENCH_population.json");
    let mut cells = Vec::new();

    for &n in &populations {
        println!("== population {n} ==");
        // (a) substrate-level lazy construction: a standalone lazy registry
        // + index pair (per-learner profile streams; the coordinator cell in
        // (c) uses the eager, value-compatible registry path)
        let t0 = Instant::now();
        let registry = Registry::lazy(n, 7, 4, relay::population::DEFAULT_SHARDS);
        let mut index = AvailabilityIndex::new(
            Availability::Lazy(LazyTraceSet::new(n, 7, TraceConfig::default())),
            n,
            relay::population::DEFAULT_SHARDS,
        );
        let construct_secs = t0.elapsed().as_secs_f64();
        println!("  lazy construct (registry+index):   {construct_secs:>9.4}s");

        // (b) one-time index build (materializes every trace) + sampling
        let build_workers = if workers == 0 {
            relay::util::threadpool::default_workers()
        } else {
            workers
        };
        let t0 = Instant::now();
        index.advance_to(0.0, build_workers);
        let build_secs = t0.elapsed().as_secs_f64();
        let available0 = index.available_count();
        println!(
            "  index build (all traces, avail={available0}): {build_secs:>9.3}s"
        );
        let mut select_rng = relay::util::rng::Rng::new(3);
        let mut avail_set = relay::population::CandidateSet::new(n);
        index.for_each_available(|id| {
            avail_set.insert(id);
        });
        let t0 = Instant::now();
        let select_rounds = 1000usize;
        for _ in 0..select_rounds {
            std::hint::black_box(avail_set.sample_k(&mut select_rng, target));
        }
        let select_us = t0.elapsed().as_secs_f64() * 1e6 / select_rounds as f64;
        println!("  sample {target} of {available0}:        {select_us:>9.2}us/selection");
        let _ = registry.profile(n / 2); // touch the lazy profile path

        // (c) full lazy DynAvail async cell on the coordinator
        let cfg = relay::config::ExpConfig {
            variant: "tiny".into(),
            total_learners: n,
            rounds: merges,
            target_participants: target,
            mode: RoundMode::Async { buffer_k: (target / 5).max(1), max_staleness: None },
            avail: relay::config::AvailMode::DynAvail,
            selector: "random".into(),
            mean_samples: 4,
            test_per_class: 2,
            eval_every: 1_000_000,
            cooldown_rounds: 1,
            lr: 0.1,
            workers,
            ..Default::default()
        };
        let exec: Arc<dyn runtime::Executor> = Arc::new(runtime::NativeExecutor::new(
            runtime::builtin_variant("tiny"),
        ));
        let t0 = Instant::now();
        let mut coord = Coordinator::new(cfg, exec)?;
        let cell_construct_secs = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let result = coord.run()?;
        let run_secs = t0.elapsed().as_secs_f64();
        let events: usize = result.rounds.iter().filter_map(|r| r.kernel_events).sum();
        let per_event_us = if events > 0 {
            run_secs * 1e6 / events as f64
        } else {
            0.0
        };
        println!(
            "  async cell: construct {cell_construct_secs:.3}s, {merges} merges in \
             {run_secs:.3}s ({events} kernel events, {per_event_us:.1}us/event)"
        );
        let trajectory = arr(result.rounds.iter().map(|r| {
            obj(vec![
                ("round", num(r.round as f64)),
                ("sim_time", num(r.sim_time)),
                ("selected", num(r.selected as f64)),
                ("kernel_events", num(r.kernel_events.unwrap_or(0) as f64)),
                ("failed", Json::Bool(r.failed)),
            ])
        }));
        cells.push(obj(vec![
            ("population", num(n as f64)),
            ("construct_secs", num(construct_secs)),
            ("index_build_secs", num(build_secs)),
            ("available_at_t0", num(available0 as f64)),
            ("select_us", num(select_us)),
            ("cell_construct_secs", num(cell_construct_secs)),
            ("merges", num(result.rounds.len() as f64)),
            ("run_secs", num(run_secs)),
            ("kernel_events", num(events as f64)),
            ("per_event_us", num(per_event_us)),
            ("trajectory", trajectory),
        ]));
    }

    // append this run so the file keeps a trajectory across commits,
    // stamped with the environment that measured it (same metadata shape
    // as the train suite)
    let mut runs: Vec<Json> = match std::fs::read_to_string(&out) {
        Ok(prev) => match Json::parse(&prev) {
            Ok(j) => j
                .get("runs")
                .and_then(|r| r.as_arr())
                .map(|r| r.to_vec())
                .unwrap_or_default(),
            Err(_) => Vec::new(),
        },
        Err(_) => Vec::new(),
    };
    let git = relay::util::bench::git_describe()
        .map(Json::Str)
        .unwrap_or(Json::Null);
    runs.push(obj(vec![
        ("cores", num(relay::util::threadpool::default_workers() as f64)),
        ("git", git),
        ("merges", num(merges as f64)),
        ("target_participants", num(target as f64)),
        ("cells", arr(cells)),
    ]));
    let report = obj(vec![
        ("format", Json::Str("relay-bench-population-v1".into())),
        ("runs", arr(runs)),
    ]);
    std::fs::write(&out, report.to_string())?;
    println!("appended run to {out}");
    Ok(())
}

/// The selection benchmark: per-selector per-selection cost over a lazy
/// DynAvail population, indexed (`select_from` on the maintained eligible
/// set + score trees) vs materializing (candidate vector + `select`), at
/// each `--populations` size. The sub-linear acceptance signal: indexed
/// oort/priority per-selection time at 1M learners stays within ~2x of
/// 100k, where the materializing path scales ~10x. Appends one run to
/// `--selection-out` (default BENCH_selection.json) so trajectories
/// accumulate across commits.
fn cmd_bench_selection(args: &Args) -> Result<()> {
    use relay::config::AvailMode;
    use relay::population::{Population, Registry, DEFAULT_SHARDS};
    use relay::selection::{by_name, RoundFeedback, SelectPool, SelectionCtx};
    use relay::sim::Availability;
    use relay::trace::{LazyTraceSet, TraceConfig};
    use relay::util::json::{arr, num, obj, Json};
    use relay::util::rng::Rng;
    use std::time::Instant;

    let mut populations = Vec::new();
    for p in args.list_or("populations", "100000,1000000") {
        let n: usize = p
            .parse()
            .map_err(|_| anyhow!("--populations expects integers, got '{p}'"))?;
        if n == 0 {
            return Err(anyhow!("--populations entries must be >= 1"));
        }
        populations.push(n);
    }
    let selections = args.usize_or("selections", 200).max(1);
    let target = args.usize_or("participants", 100);
    let workers = args.usize_or("workers", 0);
    let out = args.str_or("selection-out", "BENCH_selection.json");
    let mu = 100.0;
    let mut cells = Vec::new();

    for &n in &populations {
        println!("== selection @ population {n} ==");
        let registry = Registry::lazy(n, 7, 4, DEFAULT_SHARDS);
        let avail = Availability::Lazy(LazyTraceSet::new(n, 7, TraceConfig::default()));
        let mut pop = Population::new(registry, avail, AvailMode::DynAvail, 1, 1000, workers);
        // shared monotone clocks: the availability index only moves forward
        let mut now = 0.0f64;
        let mut round = 0usize;
        let mut selector_cells = Vec::new();
        for name in ["random", "oort", "priority", "safa"] {
            let mut sel = by_name(name).ok_or_else(|| anyhow!("unknown selector"))?;
            let mut rng = Rng::new(5);
            pop.sync_to(round, now, sel.as_mut());
            if name == "oort" {
                // seed an explored pool (~2k learners) so the utility tree
                // ranks something real
                let stride = (n / 2000).max(1);
                let completed: Vec<(usize, f64, f64)> = (0..n)
                    .step_by(stride)
                    .map(|id| (id, rng.uniform(1.0, 100.0), rng.uniform(5.0, 300.0)))
                    .collect();
                sel.feedback(&RoundFeedback {
                    round,
                    completed: &completed,
                    missed: &[],
                    round_duration: mu,
                });
            }
            let eligible0 = pop.eligible_set().len();
            // warm-up: pays the one-time index build / probe materialization
            {
                let pool =
                    SelectPool { set: pop.eligible_set(), probes: &pop, mu };
                let _ = sel.select_from(&pool, round, now, target, &mut rng);
            }
            // indexed path, steady state
            let t0 = Instant::now();
            for _ in 0..selections {
                now += 0.05;
                round += 1;
                pop.sync_to(round, now, sel.as_mut());
                let pool =
                    SelectPool { set: pop.eligible_set(), probes: &pop, mu };
                let picked = sel
                    .select_from(&pool, round, now, target, &mut rng)
                    .expect("built-in selectors are indexed");
                std::hint::black_box(picked);
            }
            let indexed_us = t0.elapsed().as_secs_f64() * 1e6 / selections as f64;
            // materializing path (capped iterations: it is the slow one)
            let mat_iters = (20_000_000 / n.max(1)).clamp(2, selections);
            let t0 = Instant::now();
            for _ in 0..mat_iters {
                now += 0.05;
                round += 1;
                pop.sync_to(round, now, sel.as_mut());
                let candidates = pop.pool_candidates(now, mu);
                if !candidates.is_empty() {
                    let mut ctx = SelectionCtx {
                        round,
                        now,
                        target,
                        candidates: &candidates,
                        rng: &mut rng,
                    };
                    std::hint::black_box(sel.select(&mut ctx));
                }
            }
            let materialized_us = t0.elapsed().as_secs_f64() * 1e6 / mat_iters as f64;
            println!(
                "  {name:<9} eligible={eligible0:>8}  indexed {indexed_us:>10.1}us/sel  \
                 materialized {materialized_us:>10.1}us/sel  ({:.1}x)",
                materialized_us / indexed_us.max(1e-9)
            );
            selector_cells.push(obj(vec![
                ("selector", Json::Str(name.into())),
                ("eligible", num(eligible0 as f64)),
                ("indexed_us", num(indexed_us)),
                ("materialized_us", num(materialized_us)),
                ("materialized_iters", num(mat_iters as f64)),
            ]));
        }
        cells.push(obj(vec![
            ("population", num(n as f64)),
            ("selections", num(selections as f64)),
            ("target_participants", num(target as f64)),
            ("selectors", arr(selector_cells)),
        ]));
    }

    // append this run so the file keeps a trajectory across commits,
    // stamped like the train suite's points
    let git = relay::util::bench::git_describe()
        .map(Json::Str)
        .unwrap_or(Json::Null);
    let run = obj(vec![
        ("cores", num(relay::util::threadpool::default_workers() as f64)),
        ("git", git),
        ("cells", arr(cells)),
    ]);
    let mut runs: Vec<Json> = match std::fs::read_to_string(&out) {
        Ok(prev) => match Json::parse(&prev) {
            Ok(j) => j
                .get("runs")
                .and_then(|r| r.as_arr())
                .map(|r| r.to_vec())
                .unwrap_or_default(),
            Err(_) => Vec::new(),
        },
        Err(_) => Vec::new(),
    };
    runs.push(run);
    let report = obj(vec![
        ("format", Json::Str("relay-bench-selection-v1".into())),
        ("runs", arr(runs)),
    ]);
    std::fs::write(&out, report.to_string())?;
    println!("appended run to {out}");
    Ok(())
}

/// The intra-round training benchmark: one mega-async-shaped cell (speech
/// variant, so real SGD dominates the wall-clock) run twice — train pool
/// width 1 (the serial path) vs 8 — at each `--populations` size. The two
/// results must be **byte-identical** (the pool's fixed reduction order);
/// the workers-8 speedup is the payoff metric. Appends one run to
/// `--train-out` (default BENCH_train.json) so the trajectory accumulates
/// across commits; `--gate` fails on a >25% regression of the
/// cores-normalized speedup vs the last committed run for the same
/// population, and on an absolute floor (speedup < 1.5 with >= 4 cores).
fn cmd_bench_train(args: &Args) -> Result<()> {
    use relay::config::RoundMode;
    use relay::coordinator::Coordinator;
    use relay::util::json::{arr, num, obj, Json};
    use std::time::Instant;

    let mut populations = Vec::new();
    for p in args.list_or("populations", "1000000") {
        let n: usize = p
            .parse()
            .map_err(|_| anyhow!("--populations expects integers, got '{p}'"))?;
        if n == 0 {
            return Err(anyhow!("--populations entries must be >= 1"));
        }
        populations.push(n);
    }
    let merges = args.usize_or("merges", 5);
    let target = args.usize_or("participants", 50);
    let buffer_k = args.usize_or("buffer-k", 10);
    let out = args.str_or("train-out", "BENCH_train.json");
    let gate = args.bool("gate");
    let cores = relay::util::threadpool::default_workers();

    // the committed trajectory this run gates against (read before append)
    let prev = std::fs::read_to_string(&out)
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    let prev_runs: Vec<Json> = prev
        .as_ref()
        .and_then(|j| j.get("runs"))
        .and_then(|r| r.as_arr())
        .map(|r| r.to_vec())
        .unwrap_or_default();
    // last committed (speedup, cores) for a population, scanning newest-first
    let last_point = |population: usize| -> Option<(f64, f64)> {
        prev_runs.iter().rev().find_map(|run| {
            let run_cores = run.get("cores").and_then(|c| c.as_f64())?;
            run.get("cells").and_then(|c| c.as_arr())?.iter().find_map(|cell| {
                if cell.get("population").and_then(|p| p.as_usize()) != Some(population) {
                    return None;
                }
                cell.get("speedup").and_then(|s| s.as_f64()).map(|s| (s, run_cores))
            })
        })
    };

    let mut cells = Vec::new();
    let mut gate_errors: Vec<String> = Vec::new();
    for &n in &populations {
        println!("== train pool @ population {n} ==");
        let cfg = relay::config::ExpConfig {
            variant: "speech".into(),
            total_learners: n,
            rounds: merges,
            target_participants: target,
            mode: RoundMode::Async { buffer_k, max_staleness: None },
            avail: relay::config::AvailMode::DynAvail,
            selector: "random".into(),
            mean_samples: 40,
            test_per_class: 2,
            eval_every: 1_000_000,
            cooldown_rounds: 1,
            lr: 0.05,
            ..Default::default()
        };
        let timed = |train_workers: usize| -> Result<(String, f64)> {
            let mut cfg = cfg.clone();
            cfg.train_workers = train_workers;
            let exec: Arc<dyn runtime::Executor> = Arc::new(runtime::NativeExecutor::new(
                runtime::builtin_variant("speech"),
            ));
            let mut coord = Coordinator::new(cfg, exec)?;
            // pay the one-off availability-index build outside the timed
            // window: this suite measures the training fan-out, not the
            // index build the population suite already tracks
            coord.warm();
            let t0 = Instant::now();
            let result = coord.run()?;
            Ok((result.to_json().to_string(), t0.elapsed().as_secs_f64()))
        };
        let (json1, secs1) = timed(1)?;
        let (json8, secs8) = timed(8)?;
        if json1 != json8 {
            return Err(anyhow!(
                "train pool broke determinism: workers-8 result differs from workers-1 \
                 at population {n}"
            ));
        }
        let speedup = secs1 / secs8.max(1e-9);
        println!(
            "  {merges} merges: workers-1 {secs1:.3}s, workers-8 {secs8:.3}s \
             ({speedup:.2}x, {cores} cores, byte-identical)"
        );
        if gate {
            // normalize by the parallelism actually available so a point
            // recorded on a big machine doesn't fail the gate on a small CI
            // runner: ideal speedup is min(8, cores) on both sides
            let norm = speedup / (cores as f64).min(8.0);
            if let Some((prev_speedup, prev_cores)) = last_point(n) {
                let prev_norm = prev_speedup / prev_cores.min(8.0);
                if norm < 0.75 * prev_norm {
                    gate_errors.push(format!(
                        "population {n}: normalized speedup {norm:.3} regressed >25% vs \
                         the last committed point {prev_norm:.3}"
                    ));
                }
            } else {
                // a freshly seeded trajectory has no committed point yet:
                // the relative check passes vacuously (this run becomes the
                // baseline); only the absolute floor below still applies
                println!(
                    "  gate: no committed baseline for population {n} yet — \
                     relative check skipped, this run becomes the baseline"
                );
            }
            if cores >= 4 && speedup < 1.5 {
                gate_errors.push(format!(
                    "population {n}: speedup {speedup:.2}x below the 1.5x floor on \
                     {cores} cores"
                ));
            }
        }
        cells.push(obj(vec![
            ("population", num(n as f64)),
            ("variant", Json::Str("speech".into())),
            ("merges", num(merges as f64)),
            ("target_participants", num(target as f64)),
            ("buffer_k", num(buffer_k as f64)),
            ("secs_workers1", num(secs1)),
            ("secs_workers8", num(secs8)),
            ("speedup", num(speedup)),
            ("byte_identical", Json::Bool(true)),
        ]));
    }

    let mut runs = prev_runs;
    // stamp each appended point with the environment that measured it, so
    // future gates can tell a code regression from a machine change
    let git = relay::util::bench::git_describe()
        .map(Json::Str)
        .unwrap_or(Json::Null);
    runs.push(obj(vec![
        ("cores", num(cores as f64)),
        ("git", git),
        ("cells", arr(cells)),
    ]));
    let report = obj(vec![
        ("format", Json::Str("relay-bench-train-v1".into())),
        ("runs", arr(runs)),
    ]);
    std::fs::write(&out, report.to_string())?;
    println!("appended run to {out}");
    if let Some(err) = gate_errors.first() {
        return Err(anyhow!("train bench gate failed: {err}"));
    }
    Ok(())
}

/// The sharded-coordination benchmark: the steady-state coordination hot
/// path — availability advance + eligibility delta (`sync_to`) + selection
/// + busy churn — run twice at each `--populations` size: K=1 coordinator
/// shards (the flat path) vs K=cores, both on the full worker pool. The
/// two runs' picked-id streams must be **byte-identical** (the sharded
/// coordination contract); the K=cores speedup is the payoff metric.
/// Appends one run to `--coord-out` (default BENCH_coord.json); `--gate`
/// fails on a >25% regression of the cores-normalized speedup vs the last
/// committed point for the same population, and on an absolute floor
/// (speedup < 1.5 with >= 4 cores).
fn cmd_bench_coord(args: &Args) -> Result<()> {
    use relay::config::AvailMode;
    use relay::population::{Population, Registry};
    use relay::selection::by_name;
    use relay::sim::Availability;
    use relay::trace::{LazyTraceSet, TraceConfig};
    use relay::util::json::{arr, num, obj, Json};
    use relay::util::rng::Rng;
    use std::time::Instant;

    let mut populations = Vec::new();
    for p in args.list_or("populations", "100000,1000000") {
        let n: usize = p
            .parse()
            .map_err(|_| anyhow!("--populations expects integers, got '{p}'"))?;
        if n == 0 {
            return Err(anyhow!("--populations entries must be >= 1"));
        }
        populations.push(n);
    }
    let iters = args.usize_or("iters", 60).max(1);
    let target = args.usize_or("participants", 100);
    let out = args.str_or("coord-out", "BENCH_coord.json");
    let gate = args.bool("gate");
    let cores = relay::util::threadpool::default_workers();
    // one advance step per iteration: big enough that each step drains a
    // real batch of availability transitions at 1M learners
    let dt = 1800.0f64;

    // the committed trajectory this run gates against (read before append)
    let prev = std::fs::read_to_string(&out)
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    let prev_runs: Vec<Json> = prev
        .as_ref()
        .and_then(|j| j.get("runs"))
        .and_then(|r| r.as_arr())
        .map(|r| r.to_vec())
        .unwrap_or_default();
    // last committed (speedup, cores) for a population, scanning newest-first
    let last_point = |population: usize| -> Option<(f64, f64)> {
        prev_runs.iter().rev().find_map(|run| {
            let run_cores = run.get("cores").and_then(|c| c.as_f64())?;
            run.get("cells").and_then(|c| c.as_arr())?.iter().find_map(|cell| {
                if cell.get("population").and_then(|p| p.as_usize()) != Some(population) {
                    return None;
                }
                cell.get("speedup").and_then(|s| s.as_f64()).map(|s| (s, run_cores))
            })
        })
    };

    let mut cells = Vec::new();
    let mut gate_errors: Vec<String> = Vec::new();
    for &n in &populations {
        println!("== coord shards @ population {n} ==");
        // one steady-state coordination loop at K shards: advance the
        // availability kernels by dt, drain the eligibility deltas, sample
        // the round's participants, and mark them busy (so busy buckets
        // churn the way a real engine's do)
        let run_k = |k: usize| -> Result<(Vec<Vec<usize>>, f64)> {
            let registry = Registry::lazy(n, 7, 4, k);
            let avail = Availability::Lazy(LazyTraceSet::new(n, 7, TraceConfig::default()));
            let mut pop = Population::new(registry, avail, AvailMode::DynAvail, 1, 1000, cores);
            let mut sel = by_name("random").ok_or_else(|| anyhow!("unknown selector"))?;
            let mut rng = Rng::new(9);
            // warm-up: the one-time index build + O(n) eligible-set build
            pop.sync_to(0, 0.0, sel.as_mut());
            let mut picked_log = Vec::with_capacity(iters);
            let mut now = 0.0f64;
            let t0 = Instant::now();
            for round in 1..=iters {
                now += dt;
                pop.sync_to(round, now, sel.as_mut());
                let picked = pop.eligible_set().sample_k(&mut rng, target);
                for &id in &picked {
                    pop.mark_busy(id, now + 2.5 * dt, sel.as_mut());
                }
                picked_log.push(picked);
            }
            Ok((picked_log, t0.elapsed().as_secs_f64()))
        };
        let (picked_flat, secs_flat) = run_k(1)?;
        let (picked_sharded, secs_sharded) = run_k(cores)?;
        if picked_flat != picked_sharded {
            return Err(anyhow!(
                "sharded coordination broke K-invariance: K={cores} picked different \
                 learners than K=1 at population {n}"
            ));
        }
        let speedup = secs_flat / secs_sharded.max(1e-9);
        println!(
            "  {iters} syncs: K=1 {secs_flat:.3}s, K={cores} {secs_sharded:.3}s \
             ({speedup:.2}x, {cores} cores, byte-identical)"
        );
        if gate {
            // normalize by the parallelism actually available so a point
            // recorded on a big machine doesn't fail the gate on a small CI
            // runner: ideal speedup is min(8, cores) on both sides
            let norm = speedup / (cores as f64).min(8.0);
            if let Some((prev_speedup, prev_cores)) = last_point(n) {
                let prev_norm = prev_speedup / prev_cores.min(8.0);
                if norm < 0.75 * prev_norm {
                    gate_errors.push(format!(
                        "population {n}: normalized speedup {norm:.3} regressed >25% vs \
                         the last committed point {prev_norm:.3}"
                    ));
                }
            } else {
                // a freshly seeded trajectory has no committed point yet:
                // the relative check passes vacuously (this run becomes the
                // baseline); only the absolute floor below still applies
                println!(
                    "  gate: no committed baseline for population {n} yet — \
                     relative check skipped, this run becomes the baseline"
                );
            }
            if cores >= 4 && speedup < 1.5 {
                gate_errors.push(format!(
                    "population {n}: speedup {speedup:.2}x below the 1.5x floor on \
                     {cores} cores"
                ));
            }
        }
        cells.push(obj(vec![
            ("population", num(n as f64)),
            ("iters", num(iters as f64)),
            ("target_participants", num(target as f64)),
            ("dt_secs", num(dt)),
            ("shards", num(cores as f64)),
            ("secs_k1", num(secs_flat)),
            ("secs_sharded", num(secs_sharded)),
            ("speedup", num(speedup)),
            ("byte_identical", Json::Bool(true)),
        ]));
    }

    let mut runs = prev_runs;
    // stamp each appended point with the environment that measured it, so
    // future gates can tell a code regression from a machine change
    let git = relay::util::bench::git_describe()
        .map(Json::Str)
        .unwrap_or(Json::Null);
    runs.push(obj(vec![
        ("cores", num(cores as f64)),
        ("git", git),
        ("cells", arr(cells)),
    ]));
    let report = obj(vec![
        ("format", Json::Str("relay-bench-coord-v1".into())),
        ("runs", arr(runs)),
    ]);
    std::fs::write(&out, report.to_string())?;
    println!("appended run to {out}");
    if let Some(err) = gate_errors.first() {
        return Err(anyhow!("coord bench gate failed: {err}"));
    }
    Ok(())
}

/// `relay scenario`: list the registered scenario presets.
fn cmd_scenario(_args: &Args) -> Result<()> {
    println!("{:<18} {:<34} {}", "name", "cell", "summary");
    for p in relay::scenario::all() {
        let avail = match p.cfg.avail {
            AvailMode::AllAvail => "all",
            AvailMode::DynAvail => "dyn",
        };
        let mut cell = format!(
            "{}-{}-{}-{} n={}",
            p.cfg.selector,
            p.cfg.mode.label(),
            avail,
            p.cfg.partition.label(),
            p.cfg.total_learners
        );
        if p.cfg.faults.is_active() {
            cell = format!("{cell} +{}", p.cfg.faults.label());
        }
        println!("{:<18} {:<34} {}", p.name, cell, p.summary);
    }
    println!("\nrun one with: relay run --scenario <name> [--learners N] [--rounds N] ...");
    Ok(())
}

/// `relay fuzz`: the differential fuzz runner (see `scenario::fuzz`).
fn cmd_fuzz(args: &Args) -> Result<()> {
    use relay::scenario::fuzz::{run_fuzz, FuzzOpts};
    // resolve the corpus dir at runtime (a compile-time manifest path would
    // bake the build machine's tree into shipped binaries): workspace root,
    // crate root, or a local fallback
    let corpus_default = if std::path::Path::new("rust/tests/corpus").is_dir() {
        "rust/tests/corpus"
    } else if std::path::Path::new("tests/corpus").is_dir() {
        "tests/corpus"
    } else {
        "fuzz-corpus"
    };
    let opts = FuzzOpts {
        iters: args.usize_or("iters", 100),
        seed: args.u64_or("seed", 0x5EED),
        smoke: args.bool("smoke"),
        corpus_dir: std::path::PathBuf::from(args.str_or("corpus", corpus_default)),
        sabotage: args.bool("sabotage"),
        max_failures: args.usize_or("max-failures", 5),
        verbose: args.bool("verbose"),
    };
    let t0 = std::time::Instant::now();
    let out = run_fuzz(&opts)?;
    println!(
        "fuzz: {} scenario+seed tuples checked in {:.1}s, {} failure(s)",
        out.iters,
        t0.elapsed().as_secs_f64(),
        out.failures.len()
    );
    for f in &out.failures {
        println!("  iter {:>4}: {}", f.iter, f.failure);
        if let Some(p) = &f.corpus_path {
            println!("    shrunk repro: {}", p.display());
        }
    }
    if out.failures.is_empty() {
        Ok(())
    } else if opts.sabotage {
        println!("(sabotage mode: the planted invariant only — not a real bug)");
        Ok(())
    } else {
        Err(anyhow!(
            "{} invariant violation(s) found — shrunk repros persisted to {}",
            out.failures.len(),
            opts.corpus_dir.display()
        ))
    }
}

/// `relay replay`: the replay oracle. Given a `--runlog` directory, decode
/// its segments and re-derive the `ExperimentResult` from the event stream
/// alone (no engine involved). Given a JSON config or a fuzz corpus entry,
/// run the engine with an in-memory log and check the replayed result is
/// byte-identical to the engine's — a one-shot differential check.
fn cmd_replay(args: &Args) -> Result<()> {
    use relay::runlog::{decode_segments, read_dir_segments, replay, MemSink};

    let target = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: relay replay <log-dir | config.json> [--out r.json]"))?;
    let path = std::path::Path::new(target);
    if !path.exists() {
        // a nonexistent path used to fall into the JSON-config branch and
        // die on an opaque read error; name the real problem instead
        return Err(anyhow!(
            "'{target}' does not exist — pass a --runlog directory or a JSON config \
             (if the run has not started yet, there is nothing to replay; \
             `relay watch {target}` waits for the log instead)"
        ));
    }
    if path.is_dir() {
        let segments = read_dir_segments(path)?;
        if segments.is_empty() {
            return Err(anyhow!(
                "run log directory '{target}' has no segments yet — the run has not \
                 written anything to replay (tail it live with `relay watch {target}`)"
            ));
        }
        let (events, stats) = decode_segments(&segments);
        println!("decoded {} event(s) from {} segment(s)", stats.frames, stats.segments);
        if !stats.clean {
            return Err(anyhow!(
                "run log is corrupt, refusing to replay a partial stream: {}",
                stats.note.unwrap_or_default()
            ));
        }
        // a JobSetStart header routes to the multi-job reducer; everything
        // else is a single-job log
        if matches!(events.first(), Some(relay::runlog::RunEvent::JobSetStart { .. })) {
            let result = relay::jobs::replay_multijob(&events)?;
            println!("{}", result.summary());
            if let Some(out) = args.str_opt("out") {
                std::fs::write(out, result.to_json().to_string())?;
                println!("wrote {out}");
            }
            return Ok(());
        }
        let result = replay(&events)?;
        println!("{}", result.summary());
        if let Some(out) = args.str_opt("out") {
            std::fs::write(out, result.to_json().to_string())?;
            println!("wrote {out}");
        }
        return Ok(());
    }
    let text = std::fs::read_to_string(path)?;
    let json = relay::util::json::Json::parse(&text)?;
    // a fuzz corpus entry wraps the config under "config"; a bare config is
    // the object itself
    let cfg_json = json.get("config").unwrap_or(&json);
    let cfg = relay::config::ExpConfig::from_json(cfg_json)?;
    cfg.validate()?;
    let exec: Arc<dyn runtime::Executor> = Arc::new(runtime::NativeExecutor::new(
        runtime::builtin_variant(&cfg.variant),
    ));
    let sink = MemSink::default();
    let result = relay::coordinator::run_experiment_logged(cfg, exec, Box::new(sink.clone()))?;
    let engine_bytes = result.to_json().to_string();
    let (events, stats) = decode_segments(&sink.segments());
    if !stats.clean {
        return Err(anyhow!("run log did not decode cleanly: {}", stats.note.unwrap_or_default()));
    }
    let replayed = replay(&events)?;
    if replayed.to_json().to_string() == engine_bytes {
        println!(
            "PASS: replay of {} event(s) is byte-identical to the engine result",
            events.len()
        );
        Ok(())
    } else {
        Err(anyhow!("FAIL: replay diverged from the engine result"))
    }
}

/// `relay watch`: live observability over a `--runlog` directory. Tails
/// segments as the writer appends (never blocking it), derives metrics
/// through the same reducer `relay replay` uses, and renders a dashboard,
/// JSONL snapshots, or a one-shot summary. `--out` exports the final
/// `ExperimentResult`, byte-identical to `relay replay <dir> --out`.
fn cmd_watch(args: &Args) -> Result<()> {
    use relay::telemetry::{watch_dir, WatchOpts};
    use std::io::IsTerminal;

    let target = args.positional.first().ok_or_else(|| {
        anyhow!(
            "usage: relay watch <log-dir> [--once | --follow] [--jsonl] \
             [--interval-ms 500] [--max-polls N] [--out r.json]"
        )
    })?;
    let once = args.bool("once");
    let jsonl = args.bool("jsonl");
    let opts = WatchOpts {
        once,
        jsonl,
        interval_ms: args.u64_or("interval-ms", 500),
        // only repaint in place on a real terminal; piped output stays an
        // append-only record
        clear_screen: !once && !jsonl && std::io::stdout().is_terminal(),
        max_polls: args
            .str_opt("max-polls")
            .map(|s| s.parse::<u64>())
            .transpose()
            .map_err(|_| anyhow!("--max-polls expects an integer"))?,
    };
    let mut stdout = std::io::stdout();
    let stream = watch_dir(std::path::Path::new(target), &opts, &mut stdout)?;
    if let Some(out) = args.str_opt("out") {
        // multi-job logs export the full per-job result (byte-matching
        // `relay replay <dir> --out`); single-job logs the ExperimentResult
        let text = match stream.multi_result() {
            Some(m) if stream.complete() && stream.error().is_none() => {
                m.to_json().to_string()
            }
            Some(_) => {
                return Err(anyhow!(
                    "multi-job run is incomplete or the stream degraded ({}); cannot \
                     export a final result",
                    stream.error().unwrap_or("still in flight")
                ))
            }
            None => stream.result()?.to_json().to_string(),
        };
        std::fs::write(out, text)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let manifest = runtime::Manifest::load(&dir)?;
    manifest.validate()?;
    println!("manifest OK: {} variants, {} computations", manifest.variants.len(), manifest.computations.len());
    let exec = runtime::load_executor(&dir, "tiny", Backend::Pjrt)?;
    let p = exec.init_params(1)?;
    println!("pjrt OK: tiny init -> {} params", p.len());
    Ok(())
}

fn print_help() {
    println!(
        "relay — RELAY: resource-efficient federated learning (paper reproduction)

USAGE:
  relay run   [--benchmark speech|cifar|openimage|nlp] [--scenario NAME] [--selector random|oort|priority|safa|relay]
              [--learners N] [--rounds N] [--participants N] [--partition iid|fedscale|label-*]
              [--avail all|dyn] [--deadline SECS] [--buffer-k K [--max-staleness T]]
              [--faults flap=P,crash=P,delay=P,delay-secs=S,corrupt=P,dup=P,seed=N]
              [--backend pjrt|native] [--config cfg.json] [--out r.json] [--runlog DIR]
              [--live [--interval-ms 1000]]   (stream one telemetry status line
               per interval to stderr; the result is byte-identical either way)
              [--train-workers N]   (intra-round training pool width; results
               are byte-identical at any width — 1 = strictly serial)
              [--coord-shards K]   (coordinator shard count; results are
               byte-identical for any K — 0 = autodetect, 1 = the flat path)
              [--jobs N [--job-policy fair|priority] [--job-selectors a,b,..]
               [--job-modes oc,dl40,async3,..] [--job-targets 8,4,..]
               [--job-priorities 9,1,..]]   (N concurrent jobs over one shared
               device fleet; a device busy on job A is ineligible for job B;
               per-job overrides are comma lists with one entry per job)
  relay sweep [--variant tiny|speech|...] [--selectors random,oort,priority,safa] [--modes oc,dl,async]
              [--avails dyn|all|dyn,all] [--partitions iid,...] [--seeds 3] [--learners N] [--rounds N]
              [--workers N] [--deadline SECS] [--oc-factor F] [--buffer-k K] [--max-staleness T]
              [--jobs 1,4] [--faults spec] [--report results/sweep.json] [--quiet]
  relay scenario                (list the registered scenario presets)
  relay fuzz  [--iters 100] [--seed N] [--smoke] [--corpus DIR] [--max-failures 5] [--sabotage] [--verbose]
  relay replay <log-dir | config.json | corpus-entry.json> [--out r.json]
              (log dir: re-derive the result from events alone — multi-job
               logs replay through the per-job reducer; config/corpus
               entry: run the engine with logging + byte-compare the replay)
  relay watch <log-dir> [--once | --follow] [--jsonl] [--interval-ms 500]
              [--max-polls N] [--out r.json]
              (tail a --runlog directory live: dashboard by default, --jsonl
               for machine-readable snapshots, --once for scripted/CI use;
               --out byte-matches `relay replay <log-dir> --out`)
  relay figure <2..21|t1|t2|forecast|all> [--scale 0.3] [--seeds 1] [--workers N] [--backend pjrt|native] [--verbose]
  relay bench [--suite population|selection|train|coord|all] [--populations 100000,1000000]
              [--merges 50] [--participants 100] [--selections 200] [--iters 60] [--workers N]
              [--out BENCH_population.json] [--selection-out BENCH_selection.json]
              [--train-out BENCH_train.json] [--coord-out BENCH_coord.json] [--buffer-k K] [--gate]
              (train suite: pool width 1-vs-8 wall-clock + byte-identity on a
               mega-async cell; coord suite: sync_to+select at K=1 vs K=cores
               shards, byte-identity asserted; --gate fails on >25% speedup
               regression vs the last committed point)
  relay trace-stats | forecast-eval | validate

Artifacts: run `make artifacts` first (AOT-compiles the JAX/Pallas model to
HLO), or pass --backend native for the pure-rust mirror."
    );
}
