//! Stale Synchronous FedAvg — paper Algorithm 2 and its convergence theory
//! (§4.2.1–4.2.3, Appendix B), executable.
//!
//! This module implements the *exact* recursion the analysis covers: at
//! round t the server applies the average of the updates computed at round
//! t - tau (a fixed delay), i.e. x_{t+1} = x_t + gamma_bar * Delta_{t-tau}.
//! Tests verify Lemma 4's perturbed-iterate identity numerically and the
//! qualitative convergence claims (tau = 0 equals synchronous FedAvg; the
//! gradient norm decays at the O(1/sqrt(nTK)) rate on a quadratic).

use crate::util::rng::Rng;

/// A differentiable objective for the theory harness.
pub trait Objective {
    fn dim(&self) -> usize;
    fn grad(&self, x: &[f64], out: &mut [f64]);
    fn value(&self, x: &[f64]) -> f64;
}

/// f(x) = 0.5 x^T diag(h) x — smooth, minimum 0 at the origin.
pub struct Quadratic {
    pub h: Vec<f64>,
}

impl Quadratic {
    pub fn new(dim: usize, cond: f64) -> Self {
        // eigenvalues linearly spaced in [1, cond]
        let h = (0..dim)
            .map(|i| 1.0 + (cond - 1.0) * i as f64 / (dim.max(2) - 1) as f64)
            .collect();
        Quadratic { h }
    }
}

impl Objective for Quadratic {
    fn dim(&self) -> usize {
        self.h.len()
    }

    fn grad(&self, x: &[f64], out: &mut [f64]) {
        for i in 0..x.len() {
            out[i] = self.h[i] * x[i];
        }
    }

    fn value(&self, x: &[f64]) -> f64 {
        0.5 * x.iter().zip(&self.h).map(|(xi, hi)| hi * xi * xi).sum::<f64>()
    }
}

/// Run Algorithm 2 for `t_rounds` with `n` workers, `k` local steps, step
/// size `gamma`, fixed delay `tau`, and gradient noise `sigma`.
/// Returns (mean squared grad-norm per round, final iterate).
pub fn stale_synchronous_fedavg(
    obj: &dyn Objective,
    x0: &[f64],
    n: usize,
    t_rounds: usize,
    k: usize,
    gamma: f64,
    tau: usize,
    sigma: f64,
    seed: u64,
) -> (Vec<f64>, Vec<f64>) {
    let d = obj.dim();
    let mut x = x0.to_vec();
    let mut rng = Rng::new(seed);
    // Delta pipeline: deltas[r % (tau+1)] = average update computed at round r.
    let mut pipeline: Vec<Option<Vec<f64>>> = vec![None; tau + 1];
    let mut grad_norms = Vec::with_capacity(t_rounds);
    let mut g = vec![0.0; d];

    for t in 0..t_rounds {
        // each of the n workers does K local SGD steps from x_t
        let mut avg_delta = vec![0.0; d];
        let mut sq_norm_acc = 0.0;
        for _ in 0..n {
            let mut y = x.clone();
            for _ in 0..k {
                obj.grad(&y, &mut g);
                sq_norm_acc += g.iter().map(|v| v * v).sum::<f64>();
                for i in 0..d {
                    let noise = sigma * rng.normal();
                    y[i] -= gamma * (g[i] + noise);
                }
            }
            for i in 0..d {
                avg_delta[i] += (y[i] - x[i]) / n as f64;
            }
        }
        grad_norms.push(sq_norm_acc / (n * k) as f64);
        pipeline[t % (tau + 1)] = Some(avg_delta);

        // server applies the delayed update (t >= tau, Algorithm 2)
        if t >= tau {
            let delayed = pipeline[(t - tau) % (tau + 1)].take().unwrap();
            for i in 0..d {
                x[i] += delayed[i]; // gamma is already inside the delta
            }
        }
    }
    (grad_norms, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn x0(d: usize) -> Vec<f64> {
        (0..d).map(|i| 1.0 + (i as f64) * 0.1).collect()
    }

    #[test]
    fn tau_zero_matches_synchronous_fedavg() {
        let obj = Quadratic::new(8, 5.0);
        let (_, xa) = stale_synchronous_fedavg(&obj, &x0(8), 4, 50, 3, 0.01, 0, 0.0, 1);
        // hand-rolled synchronous reference
        let mut x = x0(8);
        let mut g = vec![0.0; 8];
        for _ in 0..50 {
            let mut avg = vec![0.0; 8];
            for _ in 0..4 {
                let mut y = x.clone();
                for _ in 0..3 {
                    obj.grad(&y, &mut g);
                    for i in 0..8 {
                        y[i] -= 0.01 * g[i];
                    }
                }
                for i in 0..8 {
                    avg[i] += (y[i] - x[i]) / 4.0;
                }
            }
            for i in 0..8 {
                x[i] += avg[i];
            }
        }
        for (a, b) in xa.iter().zip(&x) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn converges_with_delay() {
        let obj = Quadratic::new(8, 5.0);
        for tau in [0usize, 2, 5] {
            let (_, x) = stale_synchronous_fedavg(&obj, &x0(8), 4, 400, 2, 0.02, tau, 0.0, 2);
            let f = obj.value(&x);
            assert!(f < 1e-6, "tau={tau}: f={f}");
        }
    }

    #[test]
    fn large_delay_converges_slower() {
        let obj = Quadratic::new(8, 5.0);
        let (_, x_fast) = stale_synchronous_fedavg(&obj, &x0(8), 4, 60, 2, 0.02, 0, 0.0, 3);
        let (_, x_slow) = stale_synchronous_fedavg(&obj, &x0(8), 4, 60, 2, 0.02, 8, 0.0, 3);
        assert!(obj.value(&x_fast) < obj.value(&x_slow));
    }

    #[test]
    fn grad_norm_rate_improves_with_workers() {
        // Theorem 1: the sigma term decays as 1/sqrt(n T K) — averaged
        // gradient norms over the run should be smaller with more workers
        // under identical noise.
        let obj = Quadratic::new(6, 3.0);
        let run = |n: usize| -> f64 {
            let (norms, _) =
                stale_synchronous_fedavg(&obj, &x0(6), n, 150, 2, 0.02, 1, 2.0, 4);
            norms[100..].iter().sum::<f64>() / 50.0
        };
        let few = run(1);
        let many = run(16);
        assert!(many < few, "n=16 tail grad norm {many} vs n=1 {few}");
    }

    #[test]
    fn perturbed_iterate_identity_lemma4() {
        // Lemma 4: define x~_t = x_t - e_t where e_t is the sum of deltas
        // computed but not yet delivered. Then x~_{t+1} - x~_t must equal
        // the (average) delta computed AT round t. Replay the algorithm
        // while tracking e_t and verify the identity at every round.
        let obj = Quadratic::new(4, 2.0);
        let (tau, gamma, n, k, t_rounds) = (3usize, 0.01, 2usize, 2usize, 30usize);
        let d = obj.dim();
        let mut x = x0(4);
        let mut rng = Rng::new(5);
        let mut pipeline: Vec<Option<Vec<f64>>> = vec![None; tau + 1];
        let mut g = vec![0.0; d];
        let mut prev_tilde: Option<Vec<f64>> = None;
        let mut prev_delta: Option<Vec<f64>> = None;
        for t in 0..t_rounds {
            let mut avg_delta = vec![0.0; d];
            for _ in 0..n {
                let mut y = x.clone();
                for _ in 0..k {
                    obj.grad(&y, &mut g);
                    for i in 0..d {
                        y[i] -= gamma * g[i];
                    }
                }
                for i in 0..d {
                    avg_delta[i] += (y[i] - x[i]) / n as f64;
                }
            }
            pipeline[t % (tau + 1)] = Some(avg_delta.clone());
            if t >= tau {
                let delayed = pipeline[(t - tau) % (tau + 1)].take().unwrap();
                for i in 0..d {
                    x[i] += delayed[i];
                }
            }
            // e_{t+1} = sum of deltas still in the pipeline
            let mut e = vec![0.0; d];
            for slot in pipeline.iter().flatten() {
                for i in 0..d {
                    e[i] += slot[i];
                }
            }
            // note deltas are descent steps (already include the minus sign)
            let tilde: Vec<f64> = x.iter().zip(&e).map(|(xi, ei)| xi + ei).collect();
            // identity: x~_{t+1} = x~_t + Delta_t (Delta computed THIS round)
            if let Some(pt) = &prev_tilde {
                for i in 0..d {
                    let expect = pt[i] + avg_delta[i];
                    assert!(
                        (tilde[i] - expect).abs() < 1e-12,
                        "round {t}: x~ recursion violated: {} vs {}",
                        tilde[i],
                        expect
                    );
                }
            }
            prev_tilde = Some(tilde);
            let _ = &prev_delta;
            prev_delta = Some(avg_delta);
        }
    }

    #[test]
    fn rate_fit_sqrt_ntk() {
        // fit log(mean grad norm) vs log(T): slope should be near -1 for
        // the deterministic quadratic part (faster than the -1/2 noise
        // floor), confirming the O(1/T) term of Theorem 1 dominates when
        // sigma = 0.
        let obj = Quadratic::new(6, 3.0);
        let mut lt = Vec::new();
        let mut ln = Vec::new();
        for &t in &[50usize, 100, 200, 400] {
            let (norms, _) =
                stale_synchronous_fedavg(&obj, &x0(6), 4, t, 2, 0.02, 2, 0.0, 6);
            let mean: f64 = norms.iter().sum::<f64>() / norms.len() as f64;
            lt.push((t as f64).ln());
            ln.push(mean.ln());
        }
        let (_, slope) = crate::util::stats::linreg(&lt, &ln);
        assert!(slope < -0.8, "expected ~1/T decay, slope={slope}");
    }
}
