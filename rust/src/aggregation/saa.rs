//! Staleness-Aware Aggregation (paper §4.2, Appendix A "reporting phase"):
//! collects fresh and stale updates, computes deviation-based weights via
//! the L1 `dev` kernel, and merges everything with the L1 `agg` kernel.
//!
//! The merge follows the paper exactly: fresh updates get w_f = 1, stale
//! update s gets w_s from the configured scaling rule, and the final
//! coefficients are the normalized weights w_i / sum(w).

use anyhow::{anyhow, Result};

use super::scaling::{lambda_from_distance, ScalingRule};
use crate::runtime::Executor;

/// One model update awaiting aggregation.
#[derive(Clone, Debug)]
pub struct UpdateEntry {
    pub learner: usize,
    /// Parameter delta w.r.t. the global model of `origin_round`.
    pub delta: Vec<f32>,
    pub origin_round: usize,
}

/// Result of one staleness-aware merge.
#[derive(Clone, Debug)]
pub struct MergeOutcome {
    /// The weighted-average delta to hand to the server optimizer.
    pub delta: Vec<f32>,
    /// (learner, final normalized coefficient) — for logging/tests.
    pub coefficients: Vec<(usize, f64)>,
    /// Deviations Lambda_s per stale entry (empty unless rule needs them).
    pub lambdas: Vec<f64>,
}

/// Merge `fresh` (produced this round) and `stale` (delivered late) updates.
///
/// `round` is the current round index; staleness tau_s = round - origin.
/// The executor's `agg`/`dev` computations are chunked to its static
/// `max_updates` row capacity.
pub fn merge(
    exec: &dyn Executor,
    fresh: &[UpdateEntry],
    stale: &[UpdateEntry],
    rule: ScalingRule,
    round: usize,
) -> Result<MergeOutcome> {
    if fresh.is_empty() && stale.is_empty() {
        return Err(anyhow!("nothing to aggregate"));
    }

    // Fresh average u_F — only needed for the deviation terms, so rules
    // that don't use Lambda skip this kernel call entirely (perf:
    // EXPERIMENTS.md §Perf iteration 1).
    let fresh_refs: Vec<&[f32]> = fresh.iter().map(|u| u.delta.as_slice()).collect();
    let fresh_avg: Option<Vec<f32>> =
        if fresh.is_empty() || !(rule.needs_deviation() && !stale.is_empty()) {
            None
        } else {
            let w = vec![1.0f32 / fresh.len() as f32; fresh.len()];
            Some(chunked_combine(exec, &fresh_refs, &w)?)
        };

    // Deviations Lambda_s (only if the rule uses them and fresh exist).
    let mut lambdas = vec![0.0f64; stale.len()];
    if rule.needs_deviation() && !stale.is_empty() {
        if let Some(avg) = &fresh_avg {
            let stale_refs: Vec<&[f32]> = stale.iter().map(|u| u.delta.as_slice()).collect();
            let dev = chunked_dev(exec, avg, &stale_refs)?;
            let fresh_norm = dev.1;
            for (i, d) in dev.0.iter().enumerate() {
                lambdas[i] = lambda_from_distance(*d as f64, fresh_norm as f64, fresh.len());
            }
        }
        // With zero fresh updates the deviation is undefined; leave Lambda=0
        // (the staleness term alone drives the weight).
    }
    let lambda_max = lambdas.iter().cloned().fold(0.0f64, f64::max);

    // Weights: fresh 1.0, stale per rule; normalize.
    let mut ids = Vec::with_capacity(fresh.len() + stale.len());
    let mut weights = Vec::with_capacity(fresh.len() + stale.len());
    for u in fresh {
        ids.push(u.learner);
        weights.push(1.0f64);
    }
    for (i, u) in stale.iter().enumerate() {
        let tau = round.saturating_sub(u.origin_round) as f64;
        ids.push(u.learner);
        weights.push(rule.weight(tau, lambdas[i], lambda_max));
    }
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return Err(anyhow!("all aggregation weights are zero"));
    }
    let coeffs: Vec<f64> = weights.iter().map(|w| w / total).collect();

    // Final weighted merge through the L1 kernel.
    let all_refs: Vec<&[f32]> = fresh
        .iter()
        .chain(stale.iter())
        .map(|u| u.delta.as_slice())
        .collect();
    let w32: Vec<f32> = coeffs.iter().map(|&c| c as f32).collect();
    let delta = chunked_combine(exec, &all_refs, &w32)?;

    Ok(MergeOutcome {
        delta,
        coefficients: ids.into_iter().zip(coeffs).collect(),
        lambdas,
    })
}

/// FedBuff-style buffered merge (the async regime's server step): partition
/// one buffer of updates by staleness relative to the current model
/// `version` — entries trained against the current version are "fresh"
/// (w = 1), older entries get the configured Eq.-2 staleness weight — and
/// run the same deviation-aware [`merge`] the synchronous regimes use.
pub fn merge_buffer(
    exec: &dyn Executor,
    updates: Vec<UpdateEntry>,
    rule: ScalingRule,
    version: usize,
) -> Result<MergeOutcome> {
    let (fresh, stale): (Vec<UpdateEntry>, Vec<UpdateEntry>) =
        updates.into_iter().partition(|u| u.origin_round == version);
    merge(exec, &fresh, &stale, rule, version)
}

/// agg_combine in row-chunks of the executor's static max_updates capacity.
fn chunked_combine(exec: &dyn Executor, rows: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
    let cap = exec.variant().max_updates;
    if rows.len() <= cap {
        return exec.agg_combine(rows, weights);
    }
    let p = exec.variant().num_params;
    let mut acc = vec![0f32; p];
    for (rchunk, wchunk) in rows.chunks(cap).zip(weights.chunks(cap)) {
        let part = exec.agg_combine(rchunk, wchunk)?;
        for i in 0..p {
            acc[i] += part[i];
        }
    }
    Ok(acc)
}

/// agg_dev in row-chunks; returns (distances per stale row, fresh norm).
fn chunked_dev(exec: &dyn Executor, fresh: &[f32], rows: &[&[f32]]) -> Result<(Vec<f32>, f32)> {
    let cap = exec.variant().max_updates;
    let mut dists = Vec::with_capacity(rows.len());
    let mut fresh_norm = 0f32;
    for chunk in rows.chunks(cap) {
        let out = exec.agg_dev(fresh, chunk)?;
        let (d, n) = out.split_at(out.len() - 1);
        dists.extend_from_slice(d);
        fresh_norm = n[0];
    }
    Ok((dists, fresh_norm))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{builtin_variant, NativeExecutor};

    fn exec() -> NativeExecutor {
        NativeExecutor::new(builtin_variant("tiny"))
    }

    fn entry(learner: usize, val: f32, origin: usize) -> UpdateEntry {
        UpdateEntry { learner, delta: vec![val; 172], origin_round: origin }
    }

    #[test]
    fn fresh_only_is_plain_mean() {
        let e = exec();
        let out = merge(
            &e,
            &[entry(0, 1.0, 5), entry(1, 3.0, 5)],
            &[],
            ScalingRule::Relay { beta: 0.35 },
            5,
        )
        .unwrap();
        assert!(out.delta.iter().all(|&v| (v - 2.0).abs() < 1e-5));
        assert_eq!(out.coefficients.len(), 2);
        for (_, c) in &out.coefficients {
            assert!((c - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn equal_rule_matches_global_mean() {
        let e = exec();
        let out = merge(
            &e,
            &[entry(0, 0.0, 9)],
            &[entry(1, 3.0, 7)],
            ScalingRule::Equal,
            9,
        )
        .unwrap();
        assert!(out.delta.iter().all(|&v| (v - 1.5).abs() < 1e-5));
    }

    #[test]
    fn dynsgd_downweights_stale() {
        let e = exec();
        // stale from 2 rounds ago: w_s = 1/3; fresh w=1 -> coeffs 0.75/0.25
        let out = merge(
            &e,
            &[entry(0, 0.0, 10)],
            &[entry(1, 4.0, 8)],
            ScalingRule::DynSgd,
            10,
        )
        .unwrap();
        assert!(out.delta.iter().all(|&v| (v - 1.0).abs() < 1e-5), "{}", out.delta[0]);
    }

    #[test]
    fn relay_rule_boosts_most_deviant_stale() {
        let e = exec();
        let fresh = vec![entry(0, 1.0, 10), entry(1, 1.0, 10)];
        // stale 2 is conformist (same as fresh), stale 3 deviates strongly
        let mut conform = entry(2, 1.0, 9);
        conform.delta[0] = 1.01;
        let deviant = entry(3, -5.0, 9);
        let out = merge(&e, &fresh, &[conform, deviant], ScalingRule::Relay { beta: 0.35 }, 10)
            .unwrap();
        let c_conform = out.coefficients[2].1;
        let c_deviant = out.coefficients[3].1;
        assert!(c_deviant > c_conform, "deviant {c_deviant} <= conformist {c_conform}");
        assert_eq!(out.lambdas.len(), 2);
        assert!(out.lambdas[1] > out.lambdas[0]);
    }

    #[test]
    fn coefficients_sum_to_one() {
        let e = exec();
        let out = merge(
            &e,
            &[entry(0, 0.5, 4)],
            &[entry(1, 1.0, 3), entry(2, 2.0, 1)],
            ScalingRule::Relay { beta: 0.35 },
            4,
        )
        .unwrap();
        let total: f64 = out.coefficients.iter().map(|(_, c)| c).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn stale_only_rounds_work() {
        let e = exec();
        let out = merge(&e, &[], &[entry(1, 2.0, 3)], ScalingRule::DynSgd, 5).unwrap();
        assert!(out.delta.iter().all(|&v| (v - 2.0).abs() < 1e-5));
    }

    #[test]
    fn empty_merge_errors() {
        let e = exec();
        assert!(merge(&e, &[], &[], ScalingRule::Equal, 0).is_err());
    }

    #[test]
    fn merge_buffer_partitions_by_version() {
        let e = exec();
        // two current-version entries, one from two versions back: the
        // buffered merge must weight them exactly like a fresh/stale merge
        let buffer = vec![entry(0, 1.0, 10), entry(1, 1.0, 10), entry(2, 4.0, 8)];
        let buffered = merge_buffer(&e, buffer, ScalingRule::DynSgd, 10).unwrap();
        let split = merge(
            &e,
            &[entry(0, 1.0, 10), entry(1, 1.0, 10)],
            &[entry(2, 4.0, 8)],
            ScalingRule::DynSgd,
            10,
        )
        .unwrap();
        assert_eq!(buffered.delta, split.delta);
        assert_eq!(buffered.coefficients, split.coefficients);
        // stale weight 1/(tau+1) = 1/3; coefficients (1, 1, 1/3)/sum
        let c_stale = buffered.coefficients[2].1;
        assert!((c_stale - (1.0 / 3.0) / (7.0 / 3.0)).abs() < 1e-9, "{c_stale}");
    }

    #[test]
    fn merge_buffer_all_fresh_is_plain_mean() {
        let e = exec();
        let buffer = vec![entry(0, 2.0, 4), entry(1, 4.0, 4)];
        let out = merge_buffer(&e, buffer, ScalingRule::Relay { beta: 0.35 }, 4).unwrap();
        assert!(out.delta.iter().all(|&v| (v - 3.0).abs() < 1e-5));
    }

    #[test]
    fn merge_buffer_empty_errors() {
        let e = exec();
        assert!(merge_buffer(&e, Vec::new(), ScalingRule::Equal, 0).is_err());
    }

    #[test]
    fn chunking_exceeding_max_updates() {
        let e = exec(); // tiny: max_updates = 8
        let fresh: Vec<UpdateEntry> = (0..20).map(|i| entry(i, 1.0, 2)).collect();
        let out = merge(&e, &fresh, &[], ScalingRule::Equal, 2).unwrap();
        assert!(out.delta.iter().all(|&v| (v - 1.0).abs() < 1e-5));
    }
}
