//! Stale-update weight-scaling rules (paper §4.2.4):
//!
//! * **Equal** — w_s = 1 (stale treated like fresh);
//! * **DynSGD** (Jiang et al.) — w_s = 1 / (tau_s + 1);
//! * **AdaSGD** (Damaskinos et al., FLeet) — w_s = e^{-(tau_s + 1)};
//! * **Relay** — Eq. 2: the privacy-preserving deviation-boosted damping
//!   w_s = (1-beta)/(tau_s+1) + beta * (1 - e^{-Lambda_s / Lambda_max}),
//!   where Lambda_s = ||u_F - (u_s + n_F u_F)/(n_F + 1)||^2 / ||u_F||^2
//!   measures how much the stale update deviates from the fresh average —
//!   computed from updates only, never from learner data.

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScalingRule {
    Equal,
    DynSgd,
    AdaSgd,
    Relay { beta: f64 },
}

impl ScalingRule {
    pub fn parse(s: &str) -> Option<ScalingRule> {
        match s {
            "equal" => Some(ScalingRule::Equal),
            "dynsgd" => Some(ScalingRule::DynSgd),
            "adasgd" => Some(ScalingRule::AdaSgd),
            "relay" => Some(ScalingRule::Relay { beta: 0.35 }), // paper default
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ScalingRule::Equal => "equal",
            ScalingRule::DynSgd => "dynsgd",
            ScalingRule::AdaSgd => "adasgd",
            ScalingRule::Relay { .. } => "relay",
        }
    }

    /// Whether this rule needs the deviation terms Lambda (only RELAY does —
    /// the others can skip the `dev` kernel call entirely).
    pub fn needs_deviation(&self) -> bool {
        matches!(self, ScalingRule::Relay { .. })
    }

    /// Weight of one stale update. `tau` = staleness in rounds,
    /// `lambda`/`lambda_max` = deviation terms (ignored except by Relay).
    pub fn weight(&self, tau: f64, lambda: f64, lambda_max: f64) -> f64 {
        match *self {
            ScalingRule::Equal => 1.0,
            ScalingRule::DynSgd => 1.0 / (tau + 1.0),
            ScalingRule::AdaSgd => (-(tau + 1.0)).exp(),
            ScalingRule::Relay { beta } => {
                let lam_max = lambda_max.max(1e-12);
                (1.0 - beta) / (tau + 1.0) + beta * (1.0 - (-lambda / lam_max).exp())
            }
        }
    }
}

/// Lambda_s from the raw squared distance ||u_F - u_s||^2, the fresh-average
/// norm ||u_F||^2 and n_F (paper 4.2.4, simplified algebraically — see
/// `python/compile/kernels/ref.py::lambda_ref`).
pub fn lambda_from_distance(dist_sq: f64, fresh_norm_sq: f64, n_fresh: usize) -> f64 {
    let nf = n_fresh as f64;
    dist_sq / ((nf + 1.0).powi(2) * fresh_norm_sq.max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["equal", "dynsgd", "adasgd", "relay"] {
            assert_eq!(ScalingRule::parse(s).unwrap().label(), s);
        }
        assert!(ScalingRule::parse("x").is_none());
    }

    #[test]
    fn equal_is_one() {
        assert_eq!(ScalingRule::Equal.weight(5.0, 0.3, 1.0), 1.0);
    }

    #[test]
    fn dynsgd_inverse_linear() {
        assert_eq!(ScalingRule::DynSgd.weight(0.0, 0.0, 1.0), 1.0);
        assert_eq!(ScalingRule::DynSgd.weight(4.0, 0.0, 1.0), 0.2);
    }

    #[test]
    fn adasgd_exponential() {
        let w1 = ScalingRule::AdaSgd.weight(0.0, 0.0, 1.0);
        let w2 = ScalingRule::AdaSgd.weight(1.0, 0.0, 1.0);
        assert!((w1 - (-1.0f64).exp()).abs() < 1e-12);
        assert!((w2 / w1 - (-1.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn relay_eq2_components() {
        let r = ScalingRule::Relay { beta: 0.35 };
        // max-deviation stale: boost term = 1 - e^{-1}
        let w = r.weight(1.0, 1.0, 1.0);
        let expect = 0.65 / 2.0 + 0.35 * (1.0 - (-1.0f64).exp());
        assert!((w - expect).abs() < 1e-12);
        // beta=0 reduces to DynSGD
        let r0 = ScalingRule::Relay { beta: 0.0 };
        assert!((r0.weight(3.0, 0.5, 1.0) - 0.25).abs() < 1e-12);
        // beta=1 is pure deviation boosting
        let r1 = ScalingRule::Relay { beta: 1.0 };
        assert!((r1.weight(9.0, 1.0, 1.0) - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
    }

    #[test]
    fn relay_boosts_deviant_updates() {
        let r = ScalingRule::Relay { beta: 0.35 };
        let conformist = r.weight(2.0, 0.01, 1.0);
        let deviant = r.weight(2.0, 1.0, 1.0);
        assert!(deviant > conformist);
    }

    #[test]
    fn only_relay_needs_deviation() {
        assert!(ScalingRule::Relay { beta: 0.35 }.needs_deviation());
        assert!(!ScalingRule::Equal.needs_deviation());
        assert!(!ScalingRule::DynSgd.needs_deviation());
        assert!(!ScalingRule::AdaSgd.needs_deviation());
    }

    #[test]
    fn lambda_matches_paper_algebra() {
        // Lambda = ||f - u||^2 / ((nF+1)^2 ||f||^2)
        let lam = lambda_from_distance(8.0, 2.0, 3);
        assert!((lam - 8.0 / (16.0 * 2.0)).abs() < 1e-12);
        // degenerate fresh norm guarded
        assert!(lambda_from_distance(1.0, 0.0, 1).is_finite());
    }
}
