//! FedAvg server update (McMahan et al.): x_{t+1} = x_t + eta * delta, with
//! eta = 1 by default (the delta is already an lr-scaled local step average,
//! Algorithm 2's "Server Update: x_{t+1} = x_t + gamma * Delta_{t-tau}").

use anyhow::{anyhow, Result};

use super::ServerOptimizer;

pub struct FedAvg {
    pub server_lr: f32,
}

impl Default for FedAvg {
    fn default() -> Self {
        FedAvg { server_lr: 1.0 }
    }
}

impl ServerOptimizer for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn apply(&mut self, global: &mut [f32], delta: &[f32]) -> Result<()> {
        if global.len() != delta.len() {
            return Err(anyhow!("delta len {} != params {}", delta.len(), global.len()));
        }
        for (g, d) in global.iter_mut().zip(delta) {
            *g += self.server_lr * d;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn applies_delta() {
        let mut opt = FedAvg::default();
        let mut x = vec![1.0, 2.0];
        opt.apply(&mut x, &[0.5, -1.0]).unwrap();
        assert_eq!(x, vec![1.5, 1.0]);
    }

    #[test]
    fn server_lr_scales() {
        let mut opt = FedAvg { server_lr: 0.5 };
        let mut x = vec![0.0];
        opt.apply(&mut x, &[2.0]).unwrap();
        assert_eq!(x, vec![1.0]);
    }

    #[test]
    fn rejects_len_mismatch() {
        let mut opt = FedAvg::default();
        let mut x = vec![0.0];
        assert!(opt.apply(&mut x, &[1.0, 2.0]).is_err());
    }
}
