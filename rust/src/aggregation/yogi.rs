//! YoGi adaptive server optimizer (Zaheer et al.; used for FL by Reddi et
//! al. "Adaptive Federated Optimization" and by the paper for every
//! benchmark except CIFAR10, following Oort/FedScale practice).
//!
//! m_t = beta1 m_{t-1} + (1 - beta1) d_t
//! v_t = v_{t-1} - (1 - beta2) d_t^2 sign(v_{t-1} - d_t^2)
//! x_t = x_{t-1} + eta * m_t / (sqrt(v_t) + tau)

use anyhow::{anyhow, Result};

use super::ServerOptimizer;

pub struct Yogi {
    pub eta: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub tau: f32,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Default for Yogi {
    fn default() -> Self {
        // eta tuned for deltas that are already lr-scaled local steps
        // (FedScale's yogi defaults: beta1=0.9, beta2=0.99, tau=1e-3).
        Yogi { eta: 5e-3, beta1: 0.9, beta2: 0.99, tau: 1e-3, m: Vec::new(), v: Vec::new() }
    }
}

impl ServerOptimizer for Yogi {
    fn name(&self) -> &'static str {
        "yogi"
    }

    fn apply(&mut self, global: &mut [f32], delta: &[f32]) -> Result<()> {
        if global.len() != delta.len() {
            return Err(anyhow!("delta len {} != params {}", delta.len(), global.len()));
        }
        if self.m.is_empty() {
            self.m = vec![0.0; global.len()];
            self.v = vec![1e-6; global.len()];
        }
        if self.m.len() != global.len() {
            return Err(anyhow!("yogi state len {} != params {}", self.m.len(), global.len()));
        }
        for i in 0..global.len() {
            let d = delta[i];
            let d2 = d * d;
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * d;
            let sign = if self.v[i] > d2 { 1.0 } else { -1.0 };
            self.v[i] -= (1.0 - self.beta2) * d2 * sign;
            if self.v[i] < 0.0 {
                self.v[i] = 0.0;
            }
            global[i] += self.eta * self.m[i] / (self.v[i].sqrt() + self.tau);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_lazily_initialized() {
        let mut y = Yogi::default();
        let mut x = vec![0.0f32; 4];
        y.apply(&mut x, &[0.1, 0.1, 0.1, 0.1]).unwrap();
        assert_eq!(y.m.len(), 4);
        assert!(x.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn v_controls_step_size() {
        // larger historical variance -> smaller steps for same delta
        let mut quiet = Yogi::default();
        let mut noisy = Yogi::default();
        let mut xq = vec![0.0f32];
        let mut xn = vec![0.0f32];
        for i in 0..50 {
            quiet.apply(&mut xq, &[0.01]).unwrap();
            let d = if i % 2 == 0 { 0.5 } else { -0.5 };
            noisy.apply(&mut xn, &[d]).unwrap();
        }
        // step magnitude per unit delta
        let mut xq2 = xq.clone();
        quiet.apply(&mut xq2, &[0.01]).unwrap();
        let quiet_step = (xq2[0] - xq[0]).abs() / 0.01;
        let mut xn2 = xn.clone();
        noisy.apply(&mut xn2, &[0.01]).unwrap();
        let noisy_step = (xn2[0] - xn[0]).abs() / 0.01;
        assert!(quiet_step > 2.0 * noisy_step, "{quiet_step} vs {noisy_step}");
    }

    #[test]
    fn rejects_len_mismatch_after_init() {
        let mut y = Yogi::default();
        let mut x = vec![0.0f32; 2];
        y.apply(&mut x, &[0.1, 0.1]).unwrap();
        let mut x3 = vec![0.0f32; 3];
        assert!(y.apply(&mut x3, &[0.1, 0.1, 0.1]).is_err());
    }
}
