//! Aggregation layer (paper §4.2): server optimizers (FedAvg, YoGi), the
//! stale-update weight-scaling rules (Equal / DynSGD / AdaSGD / RELAY's
//! Eq. 2), the staleness-aware merge that drives the L1 `saa` kernels, and
//! the Stale Synchronous FedAvg recursion used by the convergence-theory
//! tests (Algorithm 2).

pub mod fedavg;
pub mod saa;
pub mod scaling;
pub mod theory;
pub mod yogi;

use anyhow::Result;

/// Applies the round's aggregated update direction to the global model.
/// `delta` is the (weighted-mean) parameter delta reported by participants.
pub trait ServerOptimizer: Send {
    fn name(&self) -> &'static str;
    fn apply(&mut self, global: &mut [f32], delta: &[f32]) -> Result<()>;
}

/// Construct by name ("fedavg" | "yogi").
pub fn by_name(name: &str) -> Option<Box<dyn ServerOptimizer>> {
    match name {
        "fedavg" => Some(Box::new(fedavg::FedAvg::default())),
        "yogi" => Some(Box::new(yogi::Yogi::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_constructs() {
        assert_eq!(by_name("fedavg").unwrap().name(), "fedavg");
        assert_eq!(by_name("yogi").unwrap().name(), "yogi");
        assert!(by_name("adam").is_none());
    }

    /// Both optimizers must make progress on a quadratic when fed exact
    /// gradient-descent deltas.
    #[test]
    fn optimizers_descend_quadratic() {
        for name in ["fedavg", "yogi"] {
            let mut opt = by_name(name).unwrap();
            // f(x) = 0.5 ||x||^2, local delta = -lr * x
            let mut x = vec![1.0f32; 8];
            let norm0: f32 = x.iter().map(|v| v * v).sum();
            for _ in 0..200 {
                let delta: Vec<f32> = x.iter().map(|v| -0.1 * v).collect();
                opt.apply(&mut x, &delta).unwrap();
            }
            let norm: f32 = x.iter().map(|v| v * v).sum();
            assert!(norm < norm0 * 0.05, "{name} did not descend: {norm0} -> {norm}");
        }
    }
}
