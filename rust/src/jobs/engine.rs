//! The multi-job engine: one discrete-event kernel driving N jobs over one
//! shared [`Population`]. Arbitration points (`Arbitrate` events) order the
//! demanding jobs by policy and let each claim devices from the live
//! eligible pool in that order — a claim is `Population::mark_busy_for`, so
//! a device working for job A is invisible to job B until the task's busy
//! interval expires.
//!
//! Determinism: everything time-ordered flows through the kernel (FIFO
//! tie-breaking per event class), selection always uses the materialized
//! candidate path with per-job RNG streams, and training runs inline at
//! spawn — so results are byte-identical at any `--workers`,
//! `--train-workers`, or `--coord-shards`, the same guarantee the
//! single-job engines carry.
//!
//! Scope notes (documented simplifications vs the single-job engines):
//! cross-round staleness-aware aggregation is not modeled — a sync job's
//! stragglers are always wasted ([`FATE_DOOMED`]) — and only the crash and
//! corrupt fault classes are injected (flap/delay/duplicate are
//! selection-window and transit effects of the single-job round protocol).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::aggregation::saa::{merge, UpdateEntry};
use crate::aggregation::ServerOptimizer;
use crate::config::{AvailMode, ExpConfig, RoundMode};
use crate::coordinator::engine::{evaluate_params, local_train, resolve_coord_shards};
use crate::data::partition::{LearnerShard, Partitioner};
use crate::data::synth::{Dataset, TestSet};
use crate::learners::ProfilePool;
use crate::population::{Population, Registry};
use crate::runlog::{
    LogSink, RunEvent, RunLogger, FATE_CORRUPT, FATE_DOOMED, FATE_TRAINED,
};
use crate::runtime::Executor;
use crate::selection::{SelectionCtx, Selector};
use crate::sim::{Availability, EventClass, EventKernel};
use crate::trace::{LazyTraceSet, TraceConfig};
use crate::util::rng::Rng;
use crate::util::threadpool;

use super::{
    mode_label, policy_by_name, resolve_jobs, ArbitrationPolicy, JobClaim, JobMeta, JobSpec,
    MultiJobBook, MultiJobResult,
};

/// What a task carries between spawn and delivery.
enum TaskBody {
    /// Fault injection: corrupted at source — rejected on arrival.
    Corrupt,
    /// Trained update in flight (training ran inline at spawn against the
    /// then-current job model; the model only mutates at merges, so this
    /// equals what training at delivery time would have seen).
    Fresh { delta: Vec<f32>, mean_loss: f64 },
    /// Known-dead on schedule (sync straggler): multi-job rounds never
    /// aggregate cross-round, so the SGD is skipped — the spend is still
    /// real and is wasted at delivery.
    Untrained,
}

struct TaskDelivery {
    job: u32,
    learner: usize,
    /// Round (sync) or model version (async) the task was spawned in.
    origin: usize,
    duration: f64,
    body: TaskBody,
}

/// Payloads on the multi-job event kernel.
enum JobEvent {
    /// A task completing and reporting to its job.
    Delivery(TaskDelivery),
    /// A sync job's round window expiring.
    RoundClose { job: u32, round: usize, duration: f64 },
    /// A freed slot (dropout) or an idle retry: re-arm arbitration.
    Nudge { job: u32 },
    /// Order the demanding jobs and let them claim devices.
    Arbitrate,
}

/// No-op selector handed to population mutation calls. Multi-job selection
/// always goes through the materialized candidate path (each job has its
/// own selector and RNG stream), so the shared eligible set carries no
/// per-selector index hooks — one index cannot serve N selectors with
/// independent state.
struct NullSelector;

impl Selector for NullSelector {
    fn name(&self) -> &'static str {
        "null"
    }

    fn select(&mut self, _ctx: &mut SelectionCtx) -> Vec<usize> {
        Vec::new()
    }
}

/// One job's live state.
struct JobState {
    spec: JobSpec,
    selector: Box<dyn Selector>,
    server_opt: Box<dyn ServerOptimizer>,
    global: Vec<f32>,
    rng: Rng,
    /// Next round to close (sync) / current model version (async).
    round: usize,
    /// Sync: a round window is open (selected, waiting on `RoundClose`).
    cohort_open: bool,
    /// Tasks currently in flight (count; the book tracks seconds).
    in_flight: usize,
    /// Updates awaiting the next merge.
    buffer: Vec<UpdateEntry>,
    /// Async: when the current merge interval began.
    round_started_at: f64,
    /// Async: round 0 has been opened.
    started: bool,
    done: bool,
    /// Async: monotone per-spawn counter keying fault decisions (a
    /// version-keyed decision could crash the same device forever at a
    /// stuck version).
    fault_seq: usize,
}

/// N concurrent jobs over one shared fleet. Construct with
/// [`JobSetEngine::new`], then [`JobSetEngine::run`].
pub struct JobSetEngine {
    pub cfg: ExpConfig,
    exec: Arc<dyn Executor>,
    dataset: Arc<Dataset>,
    shards: Arc<Vec<LearnerShard>>,
    population: Population,
    kernel: EventKernel<JobEvent>,
    jobs: Vec<JobState>,
    book: MultiJobBook,
    policy: Box<dyn ArbitrationPolicy>,
    null_sel: Box<dyn Selector>,
    test: TestSet,
    model_bytes: usize,
    runlog: RunLogger,
    /// An `Arbitrate` event is already scheduled at the current time.
    armed: bool,
    /// Monotone arbitration counter (the population's round axis; multi-job
    /// runs use no cooldowns, so it only orders the incremental syncs).
    epoch: usize,
}

impl JobSetEngine {
    pub fn new(cfg: ExpConfig, exec: Arc<dyn Executor>) -> Result<JobSetEngine> {
        cfg.validate()?;
        let info = exec.variant().clone();
        if info.name != cfg.variant {
            return Err(anyhow!(
                "executor variant '{}' != config variant '{}'",
                info.name,
                cfg.variant
            ));
        }
        if cfg.oracle || cfg.apt {
            bail!("multi-job runs support neither the SAFA+O oracle nor APT");
        }
        let dataset = Dataset::new(&info, cfg.seed ^ 0xD5);
        let partitioner = Partitioner::new(cfg.partition, info.num_classes, cfg.mean_samples);
        let shards = partitioner.assign(cfg.total_learners, cfg.seed ^ 0x9A);
        let profiles = ProfilePool::generate(cfg.total_learners, cfg.seed ^ 0x0F, cfg.hardware);
        let avail = match cfg.avail {
            AvailMode::AllAvail => Availability::All,
            AvailMode::DynAvail => Availability::Lazy(LazyTraceSet::new(
                cfg.total_learners,
                cfg.seed ^ 0x7A,
                TraceConfig::default(),
            )),
        };
        let n_samples: Vec<u32> = shards.iter().map(|s| s.len() as u32).collect();
        let build_workers = if cfg.workers == 0 {
            threadpool::default_workers().min(8)
        } else {
            cfg.workers
        };
        let model_bytes = info.num_params * 4;
        let population = Population::new(
            Registry::eager(profiles, n_samples, resolve_coord_shards(&cfg)),
            avail,
            cfg.avail,
            cfg.local_epochs,
            model_bytes,
            build_workers,
        );
        let specs = resolve_jobs(&cfg)?;
        let mut jobs = Vec::with_capacity(specs.len());
        for spec in specs {
            let selector = crate::selection::by_name(&spec.selector)
                .ok_or_else(|| anyhow!("unknown selector '{}'", spec.selector))?;
            let server_opt = crate::aggregation::by_name(&cfg.server_opt)
                .ok_or_else(|| anyhow!("unknown server optimizer"))?;
            // per-job model stream: job j trains its own parameters
            let global = exec.init_params((cfg.seed as i32).wrapping_add(spec.job as i32))?;
            let rng = Rng::new(cfg.seed ^ 0x10B5E7).stream(spec.job as u64);
            jobs.push(JobState {
                spec,
                selector,
                server_opt,
                global,
                rng,
                round: 0,
                cohort_open: false,
                in_flight: 0,
                buffer: Vec::new(),
                round_started_at: 0.0,
                started: false,
                done: false,
                fault_seq: 0,
            });
        }
        let policy = policy_by_name(&cfg.job_policy)
            .ok_or_else(|| anyhow!("unknown arbitration policy '{}'", cfg.job_policy))?;
        let test = dataset.test_set(cfg.test_per_class);
        let book = MultiJobBook::new(jobs.len());
        Ok(JobSetEngine {
            book,
            policy,
            jobs,
            population,
            kernel: EventKernel::default(),
            dataset: Arc::new(dataset),
            shards: Arc::new(shards),
            test,
            model_bytes,
            exec,
            cfg,
            runlog: RunLogger::disabled(),
            null_sel: Box::new(NullSelector),
            armed: false,
            epoch: 0,
        })
    }

    /// Attach a run logger; call before [`JobSetEngine::run`].
    pub fn set_runlog(&mut self, logger: RunLogger) {
        self.runlog = logger;
    }

    /// Run every job to completion and return the per-job results.
    pub fn run(&mut self) -> Result<MultiJobResult> {
        if self.runlog.enabled() {
            let label = self.cfg.label.clone();
            let policy = self.cfg.job_policy.clone();
            let jobs = self.jobs.len() as u64;
            let rounds = self.cfg.rounds as u64;
            let eval_every = self.cfg.eval_every as u64;
            self.runlog.emit(move || RunEvent::JobSetStart {
                label,
                jobs,
                policy,
                rounds,
                eval_every,
            });
            for j in 0..self.jobs.len() {
                let spec = &self.jobs[j].spec;
                let (job, priority) = (j as u64, spec.priority);
                let (selector, mode) = (spec.selector.clone(), mode_label(&spec.mode));
                let target = spec.target as u64;
                self.runlog.emit(move || RunEvent::JobStart {
                    job,
                    selector,
                    mode,
                    target,
                    priority,
                });
            }
        }
        self.kernel.schedule(0.0, EventClass::CheckIn, JobEvent::Arbitrate);
        self.armed = true;
        while let Some(ev) = self.kernel.pop_next() {
            let now = self.kernel.now();
            match ev.payload {
                JobEvent::Arbitrate => {
                    self.armed = false;
                    self.arbitrate(now)?;
                }
                JobEvent::Nudge { .. } => self.arm_if_demand(now),
                JobEvent::RoundClose { job, round, duration } => {
                    self.close_round(job as usize, round, duration, now)?;
                }
                JobEvent::Delivery(d) => self.on_delivery(d, now)?,
            }
        }
        // Terminal sweep: per-job in-flight seconds (zero here — every
        // spawn either dropped or delivered — but logged so the replay
        // reducer closes the identity the same way the engines do).
        for j in 0..self.jobs.len() {
            let secs = self.book.sweep(j)?;
            let job = j as u64;
            self.runlog.emit(|| RunEvent::JobSweep { job, secs });
        }
        self.runlog.emit(|| RunEvent::JobSetEnd);
        Ok(self.result())
    }

    /// The current books as a result (final after [`JobSetEngine::run`]).
    pub fn result(&self) -> MultiJobResult {
        let meta: Vec<JobMeta> = self
            .jobs
            .iter()
            .map(|job| JobMeta {
                selector: job.spec.selector.clone(),
                mode: mode_label(&job.spec.mode),
                target: job.spec.target,
                priority: job.spec.priority,
            })
            .collect();
        self.book.finish(&meta, &self.cfg.label, &self.cfg.job_policy)
    }

    fn demanding(&self, j: usize) -> bool {
        let job = &self.jobs[j];
        if job.done {
            return false;
        }
        match job.spec.mode {
            RoundMode::Async { .. } => job.in_flight < job.spec.target,
            _ => !job.cohort_open,
        }
    }

    /// Schedule an `Arbitrate` at `now` if any job wants devices and none
    /// is pending (CheckIn class: pops after every same-time delivery and
    /// round close, so arbitration always sees the settled state).
    fn arm_if_demand(&mut self, now: f64) {
        if self.armed {
            return;
        }
        if (0..self.jobs.len()).any(|j| self.demanding(j)) {
            self.kernel.schedule(now, EventClass::CheckIn, JobEvent::Arbitrate);
            self.armed = true;
        }
    }

    /// One arbitration point: sync the shared population to `now`, order
    /// the demanding jobs by policy, and let each take its selection turn
    /// (earlier turns claim devices, shrinking the pool for later ones).
    fn arbitrate(&mut self, now: f64) -> Result<()> {
        self.epoch += 1;
        let epoch = self.epoch;
        self.population.sync_to(epoch, now, self.null_sel.as_mut());
        let mut claims: Vec<JobClaim> = Vec::new();
        for j in 0..self.jobs.len() {
            if self.demanding(j) {
                let spent = self.book.job(j).map(|b| b.spent_secs).unwrap_or(0.0);
                claims.push(JobClaim {
                    job: j as u32,
                    priority: self.jobs[j].spec.priority,
                    spent,
                });
            }
        }
        self.policy.order(&mut claims);
        for c in claims {
            self.job_turn(c.job as usize, now)?;
        }
        Ok(())
    }

    fn job_turn(&mut self, j: usize, now: f64) -> Result<()> {
        match self.jobs[j].spec.mode {
            RoundMode::Async { .. } => self.async_turn(j, now),
            _ => self.sync_turn(j, now),
        }
    }

    /// Dropout point for `id` on a task of length `t` starting at `now`:
    /// `None` if it stays available throughout, else the (binary-searched)
    /// end of its current availability session — same 20-iteration search
    /// as the single-job engines.
    fn dropout_time(&self, id: usize, now: f64, t: f64) -> Option<f64> {
        let avail = self.population.availability();
        if avail.available_through(id, now, t) {
            return None;
        }
        let (mut lo, mut hi) = (0.0f64, t);
        for _ in 0..20 {
            let mid = 0.5 * (lo + hi);
            if avail.available_through(id, now, mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(lo)
    }

    /// One sync (OC/DL) job's selection turn: open the round, claim a
    /// cohort, spawn its tasks, and schedule the round-close sweep.
    fn sync_turn(&mut self, j: usize, now: f64) -> Result<()> {
        let spec_mode = self.jobs[j].spec.mode;
        let target = self.jobs[j].spec.target;
        let round = self.jobs[j].round;
        let mu = match spec_mode {
            RoundMode::Deadline { deadline } => deadline,
            _ => 100.0,
        };
        let (job_u, round_u) = (j as u64, round as u64);
        self.runlog.emit(|| RunEvent::JobRoundStart { job: job_u, round: round_u, now });
        self.book.round_start(j, round_u, now)?;

        let n_select = match spec_mode {
            RoundMode::OverCommit { factor } => ((target as f64) * factor).ceil() as usize,
            _ => target,
        };
        let candidates = self.population.pool_candidates(now, mu);
        let picked = if candidates.is_empty() {
            Vec::new()
        } else {
            let job = &mut self.jobs[j];
            let mut ctx = SelectionCtx {
                round,
                now,
                target: n_select,
                candidates: &candidates,
                rng: &mut job.rng,
            };
            job.selector.select(&mut ctx)
        };

        if picked.is_empty() {
            // Nothing claimable: burn a round slot (cohort closes empty).
            let dur = mu.max(1.0);
            self.jobs[j].cohort_open = true;
            self.kernel.schedule(
                now + dur,
                EventClass::Eval,
                JobEvent::RoundClose { job: j as u32, round, duration: dur },
            );
            return Ok(());
        }

        // ---- task timing + fault decisions ------------------------------
        let faults = self.cfg.faults;
        // decorrelate fault decisions across jobs sharing a round index
        let fault_round = round * self.jobs.len() + j;
        let mut tasks: Vec<(usize, f64, Option<f64>, bool)> = Vec::with_capacity(picked.len());
        for &id in &picked {
            let t = self.population.profile(id).completion_time(
                self.shards[id].len(),
                self.cfg.local_epochs,
                self.model_bytes,
            );
            let mut dropped = self.dropout_time(id, now, t);
            if dropped.is_none() {
                if let Some(frac) = faults.crashes(id, fault_round) {
                    dropped = Some(frac * t);
                }
            }
            let corrupt = dropped.is_none() && faults.corrupts(id, fault_round);
            tasks.push((id, t, dropped, corrupt));
        }

        // ---- round window ------------------------------------------------
        let mut completions: Vec<f64> = tasks
            .iter()
            .filter(|(_, _, d, _)| d.is_none())
            .map(|(_, t, _, _)| *t)
            .collect();
        completions.sort_by(|a, b| a.total_cmp(b));
        let dur = match spec_mode {
            RoundMode::Deadline { deadline } => deadline,
            RoundMode::OverCommit { .. } => {
                if completions.is_empty() {
                    mu.max(1.0)
                } else {
                    completions[target.min(completions.len()) - 1]
                }
            }
            RoundMode::Async { .. } => unreachable!("async jobs use async_turn"),
        };
        let floor = match spec_mode {
            RoundMode::Deadline { deadline } => self.cfg.min_round_duration.min(deadline),
            _ => self.cfg.min_round_duration,
        };
        let dur = dur.max(floor);

        // ---- spawn -------------------------------------------------------
        for &(id, t, dropped, corrupt) in &tasks {
            self.book.spawn(j, id as u64, t, dropped)?;
            let learner = id as u64;
            self.runlog.emit(|| RunEvent::JobSpawn {
                job: job_u,
                learner,
                now,
                duration: t,
                dropped_after: dropped,
                corrupt,
            });
            let cost = dropped.unwrap_or(t);
            self.population.mark_busy_for(id, now + cost, j as u32, self.null_sel.as_mut());
            if dropped.is_some() {
                continue; // partial spend already wasted by the book
            }
            self.jobs[j].in_flight += 1;
            let body = if corrupt {
                TaskBody::Corrupt
            } else if t <= dur {
                let o = local_train(
                    self.exec.as_ref(),
                    &self.dataset,
                    &self.shards[id],
                    id,
                    &self.jobs[j].global,
                    self.cfg.lr,
                    self.cfg.local_epochs,
                    self.cfg.seed,
                )?;
                TaskBody::Fresh { delta: o.delta, mean_loss: o.mean_loss }
            } else {
                TaskBody::Untrained
            };
            self.kernel.schedule(
                now + t,
                EventClass::Delivery,
                JobEvent::Delivery(TaskDelivery {
                    job: j as u32,
                    learner: id,
                    origin: round,
                    duration: t,
                    body,
                }),
            );
        }
        self.jobs[j].cohort_open = true;
        self.kernel.schedule(
            now + dur,
            EventClass::Eval,
            JobEvent::RoundClose { job: j as u32, round, duration: dur },
        );
        Ok(())
    }

    /// One async job's selection turn: top the in-flight set back up to the
    /// target (FedBuff-style; merges happen on the delivery path).
    fn async_turn(&mut self, j: usize, now: f64) -> Result<()> {
        let target = self.jobs[j].spec.target;
        let job_u = j as u64;
        if !self.jobs[j].started {
            self.jobs[j].started = true;
            self.jobs[j].round_started_at = now;
            self.runlog.emit(|| RunEvent::JobRoundStart { job: job_u, round: 0, now });
            self.book.round_start(j, 0, now)?;
        }
        let demand = target.saturating_sub(self.jobs[j].in_flight);
        if demand == 0 {
            return Ok(());
        }
        let candidates = self.population.pool_candidates(now, 100.0);
        let picked = if candidates.is_empty() {
            Vec::new()
        } else {
            let round = self.jobs[j].round;
            let job = &mut self.jobs[j];
            let mut ctx = SelectionCtx {
                round,
                now,
                target: demand,
                candidates: &candidates,
                rng: &mut job.rng,
            };
            job.selector.select(&mut ctx)
        };
        if picked.is_empty() {
            if self.jobs[j].in_flight == 0 {
                // Fully idle with nothing eligible: retry later. (Devices
                // freed by other jobs re-arm arbitration on their own.)
                self.kernel
                    .schedule(now + 100.0, EventClass::Departure, JobEvent::Nudge { job: j as u32 });
            }
            return Ok(());
        }
        let faults = self.cfg.faults;
        let njobs = self.jobs.len();
        for &id in &picked {
            let seq = self.jobs[j].fault_seq;
            self.jobs[j].fault_seq += 1;
            let key = seq * njobs + j;
            let t = self.population.profile(id).completion_time(
                self.shards[id].len(),
                self.cfg.local_epochs,
                self.model_bytes,
            );
            let mut dropped = self.dropout_time(id, now, t);
            if dropped.is_none() {
                if let Some(frac) = faults.crashes(id, key) {
                    dropped = Some(frac * t);
                }
            }
            let corrupt = dropped.is_none() && faults.corrupts(id, key);
            self.book.spawn(j, id as u64, t, dropped)?;
            let learner = id as u64;
            self.runlog.emit(|| RunEvent::JobSpawn {
                job: job_u,
                learner,
                now,
                duration: t,
                dropped_after: dropped,
                corrupt,
            });
            let cost = dropped.unwrap_or(t);
            self.population.mark_busy_for(id, now + cost, j as u32, self.null_sel.as_mut());
            if let Some(dt) = dropped {
                // the slot frees at the drop point — re-arm demand there
                self.kernel
                    .schedule(now + dt, EventClass::Departure, JobEvent::Nudge { job: j as u32 });
                continue;
            }
            self.jobs[j].in_flight += 1;
            let origin = self.jobs[j].round;
            let body = if corrupt {
                TaskBody::Corrupt
            } else {
                let o = local_train(
                    self.exec.as_ref(),
                    &self.dataset,
                    &self.shards[id],
                    id,
                    &self.jobs[j].global,
                    self.cfg.lr,
                    self.cfg.local_epochs,
                    self.cfg.seed,
                )?;
                TaskBody::Fresh { delta: o.delta, mean_loss: o.mean_loss }
            };
            self.kernel.schedule(
                now + t,
                EventClass::Delivery,
                JobEvent::Delivery(TaskDelivery {
                    job: j as u32,
                    learner: id,
                    origin,
                    duration: t,
                    body,
                }),
            );
        }
        Ok(())
    }

    /// A task delivered: decide its fate, settle the books, and (async)
    /// merge when the buffer fills.
    fn on_delivery(&mut self, d: TaskDelivery, now: f64) -> Result<()> {
        let j = d.job as usize;
        self.jobs[j].in_flight -= 1;
        let mode = self.jobs[j].spec.mode;
        let (fate, mean_loss) = match (&d.body, mode) {
            (TaskBody::Corrupt, _) => (FATE_CORRUPT, 0.0),
            (TaskBody::Untrained, _) => (FATE_DOOMED, 0.0),
            (TaskBody::Fresh { mean_loss, .. }, RoundMode::Async { max_staleness, .. }) => {
                let job = &self.jobs[j];
                let stale = max_staleness
                    .map(|s| job.round - d.origin > s)
                    .unwrap_or(false);
                if job.done || stale {
                    (FATE_DOOMED, 0.0)
                } else {
                    (FATE_TRAINED, *mean_loss)
                }
            }
            (TaskBody::Fresh { mean_loss, .. }, _) => {
                let job = &self.jobs[j];
                if job.cohort_open && job.round == d.origin {
                    (FATE_TRAINED, *mean_loss)
                } else {
                    (FATE_DOOMED, 0.0) // landed after its cohort closed
                }
            }
        };
        self.book.delivery(j, d.learner as u64, d.duration, mean_loss, fate)?;
        let (job_u, learner_u, duration) = (d.job as u64, d.learner as u64, d.duration);
        self.runlog.emit(|| RunEvent::JobDelivery {
            job: job_u,
            learner: learner_u,
            duration,
            mean_loss,
            fate,
        });
        if fate == FATE_TRAINED {
            if let TaskBody::Fresh { delta, .. } = d.body {
                self.jobs[j]
                    .buffer
                    .push(UpdateEntry { learner: d.learner, delta, origin_round: d.origin });
            }
            if let RoundMode::Async { buffer_k, .. } = mode {
                if self.jobs[j].buffer.len() >= buffer_k {
                    self.merge_async(j, now)?;
                }
            }
        }
        // the reporting device is free again — let demanding jobs claim it
        self.arm_if_demand(now);
        Ok(())
    }

    /// Async merge: fold the buffered updates into the job's model, close
    /// the merge interval as a round, and open the next one.
    fn merge_async(&mut self, j: usize, now: f64) -> Result<()> {
        let entries = std::mem::take(&mut self.jobs[j].buffer);
        let round = self.jobs[j].round;
        let outcome = merge(self.exec.as_ref(), &entries, &[], self.cfg.scaling, round)?;
        {
            let job = &mut self.jobs[j];
            job.server_opt.apply(&mut job.global, &outcome.delta)?;
        }
        let dur = now - self.jobs[j].round_started_at;
        self.finish_round(j, round, dur, now)?;
        if !self.jobs[j].done {
            let job_u = j as u64;
            let round_u = self.jobs[j].round as u64;
            self.runlog.emit(|| RunEvent::JobRoundStart { job: job_u, round: round_u, now });
            self.book.round_start(j, round_u, now)?;
            self.jobs[j].round_started_at = now;
            self.arm_if_demand(now);
        }
        Ok(())
    }

    /// A sync job's round window expired: merge whatever reported in time.
    fn close_round(&mut self, j: usize, round: usize, duration: f64, now: f64) -> Result<()> {
        self.jobs[j].cohort_open = false;
        let entries = std::mem::take(&mut self.jobs[j].buffer);
        if !entries.is_empty() {
            let outcome = merge(self.exec.as_ref(), &entries, &[], self.cfg.scaling, round)?;
            let job = &mut self.jobs[j];
            job.server_opt.apply(&mut job.global, &outcome.delta)?;
        }
        self.finish_round(j, round, duration, now)?;
        if !self.jobs[j].done {
            self.arm_if_demand(now);
        }
        Ok(())
    }

    /// Shared round epilogue: eval cadence, books, log, advance.
    fn finish_round(&mut self, j: usize, round: usize, duration: f64, now: f64) -> Result<()> {
        let (eval_loss, eval_acc) =
            if (round + 1) % self.cfg.eval_every == 0 || round + 1 == self.cfg.rounds {
                let (l, a) = evaluate_params(self.exec.as_ref(), &self.test, &self.jobs[j].global)?;
                (Some(l), Some(a))
            } else {
                (None, None)
            };
        let (fresh, failed, train_loss) =
            self.book.round_end(j, round as u64, now, duration, eval_loss, eval_acc)?;
        let (job_u, round_u) = (j as u64, round as u64);
        self.runlog.emit(|| RunEvent::JobRoundEnd {
            job: job_u,
            round: round_u,
            now,
            round_duration: duration,
            fresh,
            failed,
            train_loss,
            eval_loss,
            eval_acc,
        });
        self.jobs[j].round += 1;
        if self.jobs[j].round >= self.cfg.rounds {
            self.jobs[j].done = true;
        }
        Ok(())
    }
}

/// Build a jobset engine and run it to completion.
pub fn run_jobset(cfg: ExpConfig, exec: Arc<dyn Executor>) -> Result<MultiJobResult> {
    run_jobset_instrumented(cfg, exec, RunLogger::disabled())
}

/// [`run_jobset`], with every event appended to `sink` as an event-sourced
/// run log. The result is byte-identical to the unlogged run (logging
/// observes, never perturbs), and the log alone is enough for
/// [`super::replay_multijob`] to re-derive it.
pub fn run_jobset_logged(
    cfg: ExpConfig,
    exec: Arc<dyn Executor>,
    sink: Box<dyn LogSink>,
) -> Result<MultiJobResult> {
    run_jobset_instrumented(cfg, exec, RunLogger::new(sink))
}

/// The general form: run with an arbitrary pre-built [`RunLogger`].
pub fn run_jobset_instrumented(
    cfg: ExpConfig,
    exec: Arc<dyn Executor>,
    logger: RunLogger,
) -> Result<MultiJobResult> {
    let mut eng = JobSetEngine::new(cfg, exec)?;
    eng.set_runlog(logger);
    let result = eng.run()?;
    eng.runlog.finish()?;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{builtin_variant, NativeExecutor};

    fn exec() -> Arc<dyn Executor> {
        Arc::new(NativeExecutor::new(builtin_variant("tiny")))
    }

    fn base_cfg() -> ExpConfig {
        ExpConfig {
            variant: "tiny".into(),
            total_learners: 30,
            rounds: 3,
            target_participants: 4,
            mean_samples: 8,
            test_per_class: 4,
            eval_every: 2,
            lr: 0.1,
            label: "jobset".into(),
            ..Default::default()
        }
    }

    fn multi_cfg() -> ExpConfig {
        let mut cfg = base_cfg();
        cfg.jobs = 3;
        cfg.job_modes = vec!["oc".into(), "dl40".into(), "async3".into()];
        cfg.job_selectors = vec!["random".into(), "oort".into(), "random".into()];
        cfg.job_targets = vec![4, 3, 3];
        cfg
    }

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn jobset_runs_and_every_job_closes_the_identity() {
        let r = run_jobset(multi_cfg(), exec()).unwrap();
        assert_eq!(r.jobs.len(), 3);
        let mut fleet_spent = 0.0;
        for j in &r.jobs {
            assert!(j.spent_secs > 0.0, "job {} never spent", j.job);
            assert!(!j.rounds.is_empty(), "job {} closed no rounds", j.job);
            assert_eq!(j.in_flight_secs, 0.0);
            assert!(
                close(j.spent_secs, j.aggregated_secs + j.wasted_secs),
                "job {}: {} != {} + {}",
                j.job,
                j.spent_secs,
                j.aggregated_secs,
                j.wasted_secs
            );
            for rec in &j.rounds {
                assert!(
                    close(
                        rec.cum_spent_secs,
                        rec.cum_aggregated_secs + rec.cum_wasted_secs + rec.in_flight_secs
                    ),
                    "job {} round {} identity open",
                    j.job,
                    rec.round
                );
            }
            fleet_spent += j.spent_secs;
        }
        assert_eq!(fleet_spent, r.fleet_spent_secs);
        // sync jobs ran exactly cfg.rounds rounds
        assert_eq!(r.jobs[0].rounds.len(), 3);
        assert_eq!(r.jobs[1].rounds.len(), 3);
    }

    #[test]
    fn jobset_is_deterministic_and_worker_invariant() {
        let r1 = run_jobset(multi_cfg(), exec()).unwrap();
        let mut cfg = multi_cfg();
        cfg.workers = 8;
        cfg.train_workers = 8;
        cfg.coord_shards = 7;
        let r2 = run_jobset(cfg, exec()).unwrap();
        assert_eq!(r1.to_json().to_string(), r2.to_json().to_string());
    }

    #[test]
    fn devices_are_never_shared_while_busy() {
        // strict check lives in tests/multijob_props.rs over the run log;
        // here: a tiny pool with greedy targets still never double-claims,
        // which shows as every job making progress without panics and the
        // fleet identity closing.
        let mut cfg = multi_cfg();
        cfg.total_learners = 8;
        cfg.job_targets = vec![6, 6, 6];
        let r = run_jobset(cfg, exec()).unwrap();
        let agg_plus_waste = r.fleet_aggregated_secs + r.fleet_wasted_secs;
        assert!(close(r.fleet_spent_secs, agg_plus_waste));
    }

    #[test]
    fn strict_priority_gives_the_high_job_first_claim() {
        let mut cfg = base_cfg();
        cfg.jobs = 2;
        cfg.job_policy = "priority".into();
        cfg.job_priorities = vec![1, 9];
        cfg.total_learners = 6;
        cfg.job_targets = vec![5, 5];
        cfg.rounds = 4;
        let r = run_jobset(cfg, exec()).unwrap();
        assert!(
            r.jobs[1].spent_secs >= r.jobs[0].spent_secs,
            "high-priority job should out-claim the low one: {} vs {}",
            r.jobs[1].spent_secs,
            r.jobs[0].spent_secs
        );
    }

    #[test]
    fn single_job_jobset_matches_itself_and_learns() {
        // jobs=1 through the jobset path: a sanity anchor for the fuzzer's
        // 1-vs-N differential axis
        let mut cfg = base_cfg();
        cfg.jobs = 1;
        cfg.rounds = 6;
        let r = run_jobset(cfg, exec()).unwrap();
        assert_eq!(r.jobs.len(), 1);
        let acc = r.jobs[0].rounds.iter().rev().find_map(|x| x.eval_acc);
        assert!(acc.is_some());
        assert!(r.jobs[0].rounds.iter().filter(|x| !x.failed).count() > 0);
    }

    #[test]
    fn dyn_availability_multi_job_accounts_dropouts() {
        let mut cfg = multi_cfg();
        cfg.avail = crate::config::AvailMode::DynAvail;
        cfg.rounds = 4;
        let r = run_jobset(cfg, exec()).unwrap();
        let agg_plus_waste = r.fleet_aggregated_secs + r.fleet_wasted_secs;
        assert!(close(r.fleet_spent_secs, agg_plus_waste));
    }
}
