//! Multi-job coordination (L4): N concurrent training jobs — each with its
//! own model, selector, round mode, and target — drawing participants from
//! ONE shared device fleet (`population::Population`). A device busy on job
//! A is ineligible for job B: claims go through
//! `Population::mark_busy_for`, which tags the busy interval with the
//! owning job id, so job ownership is exactly the busy dimension the
//! eligible set already maintains.
//!
//! Cross-job arbitration is pluggable ([`ArbitrationPolicy`]): whenever the
//! fleet's eligibility changes, the demanding jobs are ordered — fair-share
//! (least cumulative spend first) or strict-priority — and claim devices in
//! that order. Everything is driven through the same discrete-event kernel
//! as the single-job engines, so multi-job runs are seed-deterministic and
//! byte-identical at any `--workers`/`--train-workers`/`--coord-shards`.
//!
//! Accounting is the tentpole invariant: every device-second lands in
//! exactly one job's spent bucket, and per job
//! `spent == aggregated + wasted + in_flight` at every instant (in_flight
//! drains to zero by the end of the run). Both the engine
//! ([`engine::JobSetEngine`]) and the replay reducer
//! ([`replay::MultiJobReducer`]) drive the SAME bookkeeping type
//! ([`MultiJobBook`]) — identical methods called in identical event order —
//! so engine-vs-replay byte-identity holds by construction.

// The replay oracle re-derives per-job results from the event stream, so a
// panic here is a replay divergence waiting to happen: fallible paths must
// return errors, not unwrap.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod engine;
pub mod replay;

pub use engine::{run_jobset, run_jobset_instrumented, run_jobset_logged, JobSetEngine};
pub use replay::{replay_multijob, MultiJobReducer};

use std::collections::HashSet;

use anyhow::{bail, Result};

use crate::config::{ExpConfig, RoundMode};
use crate::metrics::{ExperimentResult, RoundRecord};
use crate::runlog::{FATE_CORRUPT, FATE_DOOMED, FATE_TRAINED};
use crate::util::json::{arr, num, obj, Json};

/// Parse one per-job round-mode spec: `oc[FACTOR]`, `dl[SECS]`, or
/// `async[K]`. A bare kind (`"oc"`, `"dl"`, `"async"`) inherits the base
/// config's parameters when the base mode is the same kind, and falls back
/// to the stock defaults (OC factor 1.3, DL deadline 100 s, async buffer
/// 10) otherwise. `async` jobs inherit the base `max_staleness` when the
/// base mode is async.
pub fn parse_job_mode(spec: &str, base: &RoundMode) -> Result<RoundMode> {
    if let Some(rest) = spec.strip_prefix("async") {
        let (base_k, base_stale) = match *base {
            RoundMode::Async { buffer_k, max_staleness } => (buffer_k, max_staleness),
            _ => (10, None),
        };
        let buffer_k = if rest.is_empty() {
            base_k
        } else {
            rest.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad job mode '{spec}': '{rest}' is not a buffer size"))?
        };
        if buffer_k == 0 {
            bail!("bad job mode '{spec}': buffer_k must be >= 1");
        }
        return Ok(RoundMode::Async { buffer_k, max_staleness: base_stale });
    }
    if let Some(rest) = spec.strip_prefix("oc") {
        let base_factor = match *base {
            RoundMode::OverCommit { factor } => factor,
            _ => 1.3,
        };
        let factor = if rest.is_empty() {
            base_factor
        } else {
            rest.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad job mode '{spec}': '{rest}' is not a factor"))?
        };
        if !factor.is_finite() || factor < 1.0 {
            bail!("bad job mode '{spec}': overcommit factor must be finite and >= 1");
        }
        return Ok(RoundMode::OverCommit { factor });
    }
    if let Some(rest) = spec.strip_prefix("dl") {
        let base_deadline = match *base {
            RoundMode::Deadline { deadline } => deadline,
            _ => 100.0,
        };
        let deadline = if rest.is_empty() {
            base_deadline
        } else {
            rest.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad job mode '{spec}': '{rest}' is not a deadline"))?
        };
        if !deadline.is_finite() || deadline <= 0.0 {
            bail!("bad job mode '{spec}': deadline must be finite and positive");
        }
        return Ok(RoundMode::Deadline { deadline });
    }
    bail!("unknown job mode '{spec}' (expected oc[FACTOR], dl[SECS], or async[K])")
}

/// Compact label for a resolved round mode — the `JobStart` run-log tag and
/// the sweep axis token (`oc1.3`, `dl60`, `async4`, `async4s8`).
pub fn mode_label(mode: &RoundMode) -> String {
    match mode {
        RoundMode::OverCommit { factor } => format!("oc{factor}"),
        RoundMode::Deadline { deadline } => format!("dl{deadline}"),
        RoundMode::Async { buffer_k, max_staleness: Some(s) } => format!("async{buffer_k}s{s}"),
        RoundMode::Async { buffer_k, max_staleness: None } => format!("async{buffer_k}"),
    }
}

/// Fully-resolved per-job configuration: the per-job override vectors from
/// [`ExpConfig`] with every gap filled from the base config.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub job: u32,
    pub selector: String,
    pub mode: RoundMode,
    pub target: usize,
    pub priority: u64,
}

/// Resolve `cfg.jobs` specs from the (validated) config. Per-job override
/// vectors are either empty (every job inherits the base value) or exactly
/// `cfg.jobs` long — `ExpConfig::validate` enforces that.
pub fn resolve_jobs(cfg: &ExpConfig) -> Result<Vec<JobSpec>> {
    let mut specs = Vec::with_capacity(cfg.jobs);
    for j in 0..cfg.jobs {
        let selector = cfg
            .job_selectors
            .get(j)
            .cloned()
            .unwrap_or_else(|| cfg.selector.clone());
        let mode = match cfg.job_modes.get(j) {
            Some(spec) => parse_job_mode(spec, &cfg.mode)?,
            None => cfg.mode,
        };
        let target = cfg
            .job_targets
            .get(j)
            .copied()
            .unwrap_or(cfg.target_participants);
        let priority = cfg.job_priorities.get(j).copied().unwrap_or(0);
        specs.push(JobSpec { job: j as u32, selector, mode, target, priority });
    }
    Ok(specs)
}

/// One demanding job at an arbitration point, with the facts policies rank
/// on. Claims arrive in job-id order; a policy reorders them and jobs then
/// pick devices in that order (earlier claims see more of the pool).
#[derive(Clone, Copy, Debug)]
pub struct JobClaim {
    pub job: u32,
    pub priority: u64,
    /// The job's cumulative spent device-seconds so far.
    pub spent: f64,
}

/// Cross-job arbitration: who gets first claim on each eligibility delta.
/// Implementations must be deterministic pure functions of the claims (the
/// trait is deliberately open for richer policies — e.g. a utility market
/// bidding device-seconds against marginal model improvement).
pub trait ArbitrationPolicy: Send {
    fn name(&self) -> &'static str;

    /// Reorder `claims` into pick order (first claim picks first).
    fn order(&self, claims: &mut [JobClaim]);
}

/// Fair-share: the job that has spent the least device time picks first
/// (ties broken by job id, ascending) — long-run device-second allocation
/// evens out across jobs.
pub struct FairShare;

impl ArbitrationPolicy for FairShare {
    fn name(&self) -> &'static str {
        "fair"
    }

    fn order(&self, claims: &mut [JobClaim]) {
        claims.sort_by(|a, b| a.spent.total_cmp(&b.spent).then(a.job.cmp(&b.job)));
    }
}

/// Strict-priority: higher `priority` always picks first (ties broken by
/// job id, ascending) — low-priority jobs can starve, which is exactly what
/// the `starved-low-priority` preset demonstrates.
pub struct StrictPriority;

impl ArbitrationPolicy for StrictPriority {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn order(&self, claims: &mut [JobClaim]) {
        claims.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.job.cmp(&b.job)));
    }
}

/// Resolve an arbitration policy by config name.
pub fn policy_by_name(name: &str) -> Option<Box<dyn ArbitrationPolicy>> {
    match name {
        "fair" => Some(Box::new(FairShare)),
        "priority" => Some(Box::new(StrictPriority)),
        _ => None,
    }
}

/// Static per-job metadata carried into [`JobSummary`] (the engine derives
/// it from [`JobSpec`], the replay reducer from `JobStart` events).
#[derive(Clone, Debug)]
pub struct JobMeta {
    pub selector: String,
    /// Compact mode label (see [`mode_label`]).
    pub mode: String,
    pub target: usize,
    pub priority: u64,
}

/// One closed round (sync) or merge interval (async) of one job.
#[derive(Clone, Debug, Default)]
pub struct JobRoundRec {
    pub round: usize,
    /// Simulated seconds since run start, at round end.
    pub sim_time: f64,
    pub round_duration: f64,
    pub selected: usize,
    /// Updates aggregated into this job's model this round.
    pub fresh: usize,
    pub dropouts: usize,
    /// Deliveries discarded (corrupt, or arrived after the cohort closed).
    pub discarded: usize,
    pub failed: bool,
    pub train_loss: Option<f64>,
    pub eval_loss: Option<f64>,
    pub eval_acc: Option<f64>,
    // Per-job accounting snapshot at round end; the invariant
    // `cum_spent == cum_aggregated + cum_wasted + in_flight` holds on
    // every record.
    pub cum_spent_secs: f64,
    pub cum_aggregated_secs: f64,
    pub cum_wasted_secs: f64,
    pub in_flight_secs: f64,
}

/// Per-round scratch between `round_start` and `round_end`.
#[derive(Default)]
struct RoundScratch {
    round: u64,
    open: bool,
    selected: usize,
    dropouts: usize,
    discarded: usize,
    losses: Vec<f64>,
}

/// One job's running books: the four accounting buckets, the unique-device
/// set, and the closed-round records.
#[derive(Default)]
pub struct JobBook {
    pub spent_secs: f64,
    pub aggregated_secs: f64,
    pub wasted_secs: f64,
    pub in_flight_secs: f64,
    unique: HashSet<u64>,
    pub rounds: Vec<JobRoundRec>,
    scratch: RoundScratch,
}

impl JobBook {
    pub fn unique_participants(&self) -> usize {
        self.unique.len()
    }
}

/// The shared multi-job bookkeeping: one [`JobBook`] per job, mutated only
/// through the transition methods below. The engine calls them adjacent to
/// its run-log emits and the replay reducer calls them from the decoded
/// events — same methods, same order, same f64 operation order — which is
/// what makes engine-vs-replay results byte-identical by construction.
pub struct MultiJobBook {
    jobs: Vec<JobBook>,
}

impl MultiJobBook {
    pub fn new(jobs: usize) -> MultiJobBook {
        MultiJobBook { jobs: (0..jobs).map(|_| JobBook::default()).collect() }
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    pub fn job(&self, j: usize) -> Option<&JobBook> {
        self.jobs.get(j)
    }

    fn job_mut(&mut self, j: usize) -> Result<&mut JobBook> {
        let n = self.jobs.len();
        self.jobs
            .get_mut(j)
            .ok_or_else(|| anyhow::anyhow!("job {j} out of range (jobset has {n})"))
    }

    /// Open round `round` for `job` at time `now`.
    pub fn round_start(&mut self, job: usize, round: u64, now: f64) -> Result<()> {
        if !now.is_finite() {
            bail!("job {job}: non-finite round-start time");
        }
        let b = self.job_mut(job)?;
        if b.scratch.open {
            bail!(
                "job {job}: round {round} started while round {} is still open",
                b.scratch.round
            );
        }
        b.scratch = RoundScratch { round, open: true, ..Default::default() };
        Ok(())
    }

    /// One device claimed: `duration` device-seconds are committed (spent)
    /// up front. `dropped_after = Some(t)` means the device leaves (or
    /// crashes) after `t` seconds — all of it wasted immediately; otherwise
    /// the full duration goes in flight until its delivery.
    pub fn spawn(
        &mut self,
        job: usize,
        learner: u64,
        duration: f64,
        dropped_after: Option<f64>,
    ) -> Result<()> {
        if !duration.is_finite() || duration < 0.0 {
            bail!("job {job}: bad task duration {duration}");
        }
        if let Some(d) = dropped_after {
            if !d.is_finite() || d < 0.0 {
                bail!("job {job}: bad dropout time {d}");
            }
        }
        let b = self.job_mut(job)?;
        if !b.scratch.open {
            bail!("job {job}: spawn outside an open round");
        }
        b.unique.insert(learner);
        b.scratch.selected += 1;
        match dropped_after {
            Some(d) => {
                // Partial work, all wasted at the moment it is known lost.
                b.spent_secs += d;
                b.wasted_secs += d;
                b.scratch.dropouts += 1;
            }
            None => {
                b.spent_secs += duration;
                b.in_flight_secs += duration;
            }
        }
        Ok(())
    }

    /// One task delivered: its in-flight device-seconds move to exactly one
    /// terminal bucket — aggregated ([`FATE_TRAINED`]) or wasted
    /// ([`FATE_CORRUPT`] / [`FATE_DOOMED`]).
    pub fn delivery(
        &mut self,
        job: usize,
        _learner: u64,
        duration: f64,
        mean_loss: f64,
        fate: u8,
    ) -> Result<()> {
        let b = self.job_mut(job)?;
        b.in_flight_secs -= duration;
        match fate {
            FATE_TRAINED => {
                b.aggregated_secs += duration;
                b.scratch.losses.push(mean_loss);
            }
            FATE_CORRUPT | FATE_DOOMED => {
                b.wasted_secs += duration;
                b.scratch.discarded += 1;
            }
            other => bail!("job {job}: unknown delivery fate {other}"),
        }
        Ok(())
    }

    /// Close the open round: derives `(fresh, failed, train_loss)` from the
    /// scratch (the caller logs them; the replay reducer re-derives and
    /// bit-compares them) and snapshots the accounting buckets into a
    /// [`JobRoundRec`].
    pub fn round_end(
        &mut self,
        job: usize,
        round: u64,
        now: f64,
        round_duration: f64,
        eval_loss: Option<f64>,
        eval_acc: Option<f64>,
    ) -> Result<(u64, bool, Option<f64>)> {
        let b = self.job_mut(job)?;
        if !b.scratch.open || b.scratch.round != round {
            bail!(
                "job {job}: round {round} ended but round {} (open={}) was current",
                b.scratch.round,
                b.scratch.open
            );
        }
        let fresh = b.scratch.losses.len();
        let failed = fresh == 0;
        let train_loss = if fresh == 0 {
            None
        } else {
            Some(b.scratch.losses.iter().sum::<f64>() / fresh as f64)
        };
        b.rounds.push(JobRoundRec {
            round: round as usize,
            sim_time: now,
            round_duration,
            selected: b.scratch.selected,
            fresh,
            dropouts: b.scratch.dropouts,
            discarded: b.scratch.discarded,
            failed,
            train_loss,
            eval_loss,
            eval_acc,
            cum_spent_secs: b.spent_secs,
            cum_aggregated_secs: b.aggregated_secs,
            cum_wasted_secs: b.wasted_secs,
            in_flight_secs: b.in_flight_secs,
        });
        b.scratch.open = false;
        Ok((fresh as u64, failed, train_loss))
    }

    /// Terminal sweep: whatever is still in flight for `job` never got
    /// aggregated — move it to waste and return it (the engine logs the
    /// value; the replay reducer bit-compares it).
    pub fn sweep(&mut self, job: usize) -> Result<f64> {
        let b = self.job_mut(job)?;
        let secs = b.in_flight_secs;
        b.wasted_secs += secs;
        b.in_flight_secs = 0.0;
        Ok(secs)
    }

    /// Fleet totals `(spent, aggregated, wasted, in_flight)` — sequential
    /// sums in job-id order, so engine and replay produce identical bytes.
    pub fn fleet_totals(&self) -> (f64, f64, f64, f64) {
        let mut t = (0.0, 0.0, 0.0, 0.0);
        for b in &self.jobs {
            t.0 += b.spent_secs;
            t.1 += b.aggregated_secs;
            t.2 += b.wasted_secs;
            t.3 += b.in_flight_secs;
        }
        t
    }

    /// Freeze the books into the final [`MultiJobResult`].
    pub fn finish(&self, meta: &[JobMeta], label: &str, policy: &str) -> MultiJobResult {
        let jobs = self
            .jobs
            .iter()
            .zip(meta)
            .enumerate()
            .map(|(j, (b, m))| JobSummary {
                job: j as u32,
                selector: m.selector.clone(),
                mode: m.mode.clone(),
                target: m.target,
                priority: m.priority,
                rounds: b.rounds.clone(),
                spent_secs: b.spent_secs,
                aggregated_secs: b.aggregated_secs,
                wasted_secs: b.wasted_secs,
                in_flight_secs: b.in_flight_secs,
                unique_participants: b.unique.len(),
            })
            .collect();
        let (spent, aggregated, wasted, in_flight) = self.fleet_totals();
        MultiJobResult {
            label: label.to_string(),
            policy: policy.to_string(),
            jobs,
            fleet_spent_secs: spent,
            fleet_aggregated_secs: aggregated,
            fleet_wasted_secs: wasted,
            fleet_in_flight_secs: in_flight,
        }
    }
}

/// One job's final books and round log.
#[derive(Clone, Debug)]
pub struct JobSummary {
    pub job: u32,
    pub selector: String,
    pub mode: String,
    pub target: usize,
    pub priority: u64,
    pub rounds: Vec<JobRoundRec>,
    pub spent_secs: f64,
    pub aggregated_secs: f64,
    pub wasted_secs: f64,
    /// Zero after the terminal sweep; kept so mid-run snapshots close the
    /// identity too.
    pub in_flight_secs: f64,
    pub unique_participants: usize,
}

/// Full result of one multi-job run: per-job summaries plus fleet totals
/// (sums over jobs in job-id order).
#[derive(Clone, Debug)]
pub struct MultiJobResult {
    pub label: String,
    pub policy: String,
    pub jobs: Vec<JobSummary>,
    pub fleet_spent_secs: f64,
    pub fleet_aggregated_secs: f64,
    pub fleet_wasted_secs: f64,
    pub fleet_in_flight_secs: f64,
}

impl MultiJobResult {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("fleet_spent_secs", num(self.fleet_spent_secs)),
            ("fleet_aggregated_secs", num(self.fleet_aggregated_secs)),
            ("fleet_wasted_secs", num(self.fleet_wasted_secs)),
            ("fleet_in_flight_secs", num(self.fleet_in_flight_secs)),
            (
                "jobs",
                arr(self.jobs.iter().map(|j| {
                    obj(vec![
                        ("job", num(j.job as f64)),
                        ("selector", Json::Str(j.selector.clone())),
                        ("mode", Json::Str(j.mode.clone())),
                        ("target", num(j.target as f64)),
                        ("priority", num(j.priority as f64)),
                        ("spent_secs", num(j.spent_secs)),
                        ("aggregated_secs", num(j.aggregated_secs)),
                        ("wasted_secs", num(j.wasted_secs)),
                        ("in_flight_secs", num(j.in_flight_secs)),
                        ("unique", num(j.unique_participants as f64)),
                        (
                            "rounds",
                            arr(j.rounds.iter().map(|r| {
                                obj(vec![
                                    ("round", num(r.round as f64)),
                                    ("sim_time", num(r.sim_time)),
                                    ("round_duration", num(r.round_duration)),
                                    ("selected", num(r.selected as f64)),
                                    ("fresh", num(r.fresh as f64)),
                                    ("dropouts", num(r.dropouts as f64)),
                                    ("discarded", num(r.discarded as f64)),
                                    ("failed", Json::Bool(r.failed)),
                                    (
                                        "train_loss",
                                        r.train_loss.map(num).unwrap_or(Json::Null),
                                    ),
                                    (
                                        "eval_loss",
                                        r.eval_loss.map(num).unwrap_or(Json::Null),
                                    ),
                                    ("eval_acc", r.eval_acc.map(num).unwrap_or(Json::Null)),
                                    ("cum_spent_secs", num(r.cum_spent_secs)),
                                    ("cum_aggregated_secs", num(r.cum_aggregated_secs)),
                                    ("cum_wasted_secs", num(r.cum_wasted_secs)),
                                    ("in_flight_secs", num(r.in_flight_secs)),
                                ])
                            })),
                        ),
                    ])
                })),
            ),
        ])
    }

    /// Flatten into a single [`ExperimentResult`] (job-major concatenated
    /// rounds with running fleet cumulative sums) so the sweep's
    /// `CellSummary` machinery and report tables work on multi-job cells
    /// unchanged. The final record's cumulative buckets are patched to the
    /// fleet totals (like the single-job engine's leftover sweep).
    pub fn summary_result(&self) -> ExperimentResult {
        let mut out = ExperimentResult {
            label: self.label.clone(),
            perplexity_metric: false,
            ..Default::default()
        };
        let (mut base_spent, mut base_agg, mut base_waste) = (0.0f64, 0.0f64, 0.0f64);
        for js in &self.jobs {
            for r in &js.rounds {
                out.rounds.push(RoundRecord {
                    round: out.rounds.len(),
                    sim_time: r.sim_time,
                    round_duration: r.round_duration,
                    selected: r.selected,
                    fresh_updates: r.fresh,
                    dropouts: r.dropouts,
                    discarded: r.discarded,
                    cum_resource_secs: base_spent + r.cum_spent_secs,
                    cum_waste_secs: base_waste + r.cum_wasted_secs,
                    unique_participants: js.unique_participants,
                    failed: r.failed,
                    train_loss: r.train_loss,
                    test_accuracy: r.eval_acc,
                    test_loss: r.eval_loss,
                    cum_aggregated_secs: Some(base_agg + r.cum_aggregated_secs),
                    in_flight_secs: Some(r.in_flight_secs),
                    ..Default::default()
                });
            }
            base_spent += js.spent_secs;
            base_agg += js.aggregated_secs;
            base_waste += js.wasted_secs;
        }
        if let Some(last) = out.rounds.last_mut() {
            last.cum_resource_secs = self.fleet_spent_secs;
            last.cum_waste_secs = self.fleet_wasted_secs;
            last.cum_aggregated_secs = Some(self.fleet_aggregated_secs);
        }
        out
    }

    /// Compact per-job summary lines (CLI output).
    pub fn summary(&self) -> String {
        let mut lines = vec![format!(
            "{:<28} policy={} jobs={} fleet: spent={:>8.2}h aggregated={:>8.2}h wasted={:>8.2}h",
            self.label,
            self.policy,
            self.jobs.len(),
            self.fleet_spent_secs / 3600.0,
            self.fleet_aggregated_secs / 3600.0,
            self.fleet_wasted_secs / 3600.0,
        )];
        for j in &self.jobs {
            let acc = j
                .rounds
                .iter()
                .rev()
                .find_map(|r| r.eval_acc)
                .map(|a| format!("{:.1}%", 100.0 * a))
                .unwrap_or_else(|| "n/a".into());
            lines.push(format!(
                "  job {} {:<8} {:<9} target={:<4} prio={:<3} rounds={:<4} spent={:>8.2}h waste={:>5.1}% unique={:<5} acc={}",
                j.job,
                j.selector,
                j.mode,
                j.target,
                j.priority,
                j.rounds.len(),
                j.spent_secs / 3600.0,
                if j.spent_secs > 0.0 { 100.0 * j.wasted_secs / j.spent_secs } else { 0.0 },
                j.unique_participants,
                acc,
            ));
        }
        lines.join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_modes_parse_inherit_and_reject() {
        let oc_base = RoundMode::OverCommit { factor: 1.7 };
        let dl_base = RoundMode::Deadline { deadline: 45.0 };
        let async_base = RoundMode::Async { buffer_k: 6, max_staleness: Some(3) };

        // bare kinds inherit same-kind base parameters
        assert_eq!(
            parse_job_mode("oc", &oc_base).unwrap(),
            RoundMode::OverCommit { factor: 1.7 }
        );
        assert_eq!(
            parse_job_mode("dl", &dl_base).unwrap(),
            RoundMode::Deadline { deadline: 45.0 }
        );
        assert_eq!(
            parse_job_mode("async", &async_base).unwrap(),
            RoundMode::Async { buffer_k: 6, max_staleness: Some(3) }
        );

        // bare kinds fall back to stock defaults on a kind switch
        assert_eq!(
            parse_job_mode("oc", &dl_base).unwrap(),
            RoundMode::OverCommit { factor: 1.3 }
        );
        assert_eq!(
            parse_job_mode("dl", &oc_base).unwrap(),
            RoundMode::Deadline { deadline: 100.0 }
        );
        assert_eq!(
            parse_job_mode("async", &oc_base).unwrap(),
            RoundMode::Async { buffer_k: 10, max_staleness: None }
        );

        // explicit parameters win; async keeps the base staleness bound
        assert_eq!(
            parse_job_mode("oc1.5", &dl_base).unwrap(),
            RoundMode::OverCommit { factor: 1.5 }
        );
        assert_eq!(
            parse_job_mode("dl60", &oc_base).unwrap(),
            RoundMode::Deadline { deadline: 60.0 }
        );
        assert_eq!(
            parse_job_mode("async4", &async_base).unwrap(),
            RoundMode::Async { buffer_k: 4, max_staleness: Some(3) }
        );

        for bad in ["warp9", "", "oc0.5", "ocx", "dl0", "dl-5", "async0", "asyncx"] {
            assert!(parse_job_mode(bad, &oc_base).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn mode_labels_are_compact() {
        assert_eq!(mode_label(&RoundMode::OverCommit { factor: 1.3 }), "oc1.3");
        assert_eq!(mode_label(&RoundMode::Deadline { deadline: 60.0 }), "dl60");
        assert_eq!(
            mode_label(&RoundMode::Async { buffer_k: 4, max_staleness: None }),
            "async4"
        );
        assert_eq!(
            mode_label(&RoundMode::Async { buffer_k: 4, max_staleness: Some(8) }),
            "async4s8"
        );
    }

    #[test]
    fn specs_resolve_overrides_and_defaults() {
        let mut cfg = ExpConfig::default();
        cfg.jobs = 3;
        cfg.target_participants = 5;
        cfg.job_selectors = vec!["oort".into(), "random".into(), "priority".into()];
        cfg.job_modes = vec!["oc".into(), "dl60".into(), "async4".into()];
        cfg.job_targets = vec![4, 2, 6];
        let specs = resolve_jobs(&cfg).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].selector, "oort");
        assert_eq!(specs[0].mode, RoundMode::OverCommit { factor: 1.3 });
        assert_eq!(specs[1].mode, RoundMode::Deadline { deadline: 60.0 });
        assert_eq!(specs[2].mode, RoundMode::Async { buffer_k: 4, max_staleness: None });
        assert_eq!(specs.iter().map(|s| s.target).collect::<Vec<_>>(), vec![4, 2, 6]);
        // empty override vectors: everything inherits the base config
        cfg.job_selectors.clear();
        cfg.job_modes.clear();
        cfg.job_targets.clear();
        let specs = resolve_jobs(&cfg).unwrap();
        assert!(specs.iter().all(|s| s.selector == cfg.selector));
        assert!(specs.iter().all(|s| s.target == 5 && s.priority == 0));
    }

    #[test]
    fn fair_share_orders_by_spend_then_id() {
        let mut claims = vec![
            JobClaim { job: 2, priority: 0, spent: 10.0 },
            JobClaim { job: 0, priority: 0, spent: 30.0 },
            JobClaim { job: 1, priority: 0, spent: 10.0 },
        ];
        FairShare.order(&mut claims);
        assert_eq!(claims.iter().map(|c| c.job).collect::<Vec<_>>(), vec![1, 2, 0]);
    }

    #[test]
    fn strict_priority_orders_by_priority_then_id() {
        let mut claims = vec![
            JobClaim { job: 0, priority: 1, spent: 0.0 },
            JobClaim { job: 1, priority: 9, spent: 50.0 },
            JobClaim { job: 2, priority: 9, spent: 0.0 },
        ];
        StrictPriority.order(&mut claims);
        assert_eq!(claims.iter().map(|c| c.job).collect::<Vec<_>>(), vec![1, 2, 0]);
        assert!(policy_by_name("fair").is_some());
        assert!(policy_by_name("priority").is_some());
        assert!(policy_by_name("market").is_none());
    }

    fn identity_gap(b: &JobBook) -> f64 {
        (b.spent_secs - (b.aggregated_secs + b.wasted_secs + b.in_flight_secs)).abs()
    }

    #[test]
    fn book_keeps_the_per_job_identity_through_a_round() {
        let mut book = MultiJobBook::new(2);
        book.round_start(0, 0, 0.0).unwrap();
        // one dropout, one fresh, one straggler, one corrupt
        book.spawn(0, 1, 40.0, Some(12.5)).unwrap();
        book.spawn(0, 2, 30.0, None).unwrap();
        book.spawn(0, 3, 90.0, None).unwrap();
        book.spawn(0, 4, 20.0, None).unwrap();
        assert_eq!(identity_gap(book.job(0).unwrap()), 0.0);
        assert_eq!(book.job(0).unwrap().spent_secs, 12.5 + 30.0 + 90.0 + 20.0);
        assert_eq!(book.job(0).unwrap().in_flight_secs, 140.0);

        book.delivery(0, 2, 30.0, 0.5, FATE_TRAINED).unwrap();
        book.delivery(0, 4, 20.0, 0.0, FATE_CORRUPT).unwrap();
        let (fresh, failed, train_loss) =
            book.round_end(0, 0, 60.0, 60.0, Some(2.0), Some(0.25)).unwrap();
        assert_eq!((fresh, failed, train_loss), (1, false, Some(0.5)));
        // straggler lands after the close
        book.delivery(0, 3, 90.0, 0.0, FATE_DOOMED).unwrap();
        assert_eq!(identity_gap(book.job(0).unwrap()), 0.0);
        assert_eq!(book.sweep(0).unwrap(), 0.0);
        assert_eq!(book.job(0).unwrap().in_flight_secs, 0.0);
        let b = book.job(0).unwrap();
        assert_eq!(b.spent_secs, b.aggregated_secs + b.wasted_secs);
        assert_eq!(b.aggregated_secs, 30.0);
        assert_eq!(b.wasted_secs, 12.5 + 20.0 + 90.0);
        assert_eq!(b.unique_participants(), 4);
        let rec = &b.rounds[0];
        assert_eq!((rec.selected, rec.fresh, rec.dropouts, rec.discarded), (4, 1, 1, 1));
        assert_eq!(rec.eval_acc, Some(0.25));
        // the untouched job stayed empty
        assert_eq!(book.job(1).unwrap().spent_secs, 0.0);
        let (spent, agg, wasted, fly) = book.fleet_totals();
        assert_eq!((spent, agg, wasted, fly), (152.5, 30.0, 122.5, 0.0));
    }

    #[test]
    fn book_rejects_inconsistent_streams() {
        let mut book = MultiJobBook::new(1);
        // spawn before any round opened
        assert!(book.spawn(0, 1, 5.0, None).is_err());
        book.round_start(0, 0, 0.0).unwrap();
        // double-open
        assert!(book.round_start(0, 1, 1.0).is_err());
        // bad fate code
        book.spawn(0, 1, 5.0, None).unwrap();
        assert!(book.delivery(0, 1, 5.0, 0.0, 99).is_err());
        // round-id mismatch at close
        assert!(book.round_end(0, 3, 1.0, 1.0, None, None).is_err());
        // out-of-range job
        assert!(book.round_start(5, 0, 0.0).is_err());
        // non-finite durations
        assert!(book.spawn(0, 2, f64::NAN, None).is_err());
        assert!(book.spawn(0, 2, 5.0, Some(f64::INFINITY)).is_err());
    }

    #[test]
    fn result_serializes_and_flattens() {
        let mut book = MultiJobBook::new(2);
        for j in 0..2 {
            book.round_start(j, 0, 0.0).unwrap();
            book.spawn(j, (10 + j) as u64, 10.0, None).unwrap();
            book.delivery(j, (10 + j) as u64, 10.0, 0.5, FATE_TRAINED).unwrap();
            book.round_end(j, 0, 30.0, 30.0, Some(1.5), Some(0.5)).unwrap();
            book.sweep(j).unwrap();
        }
        let meta = vec![
            JobMeta { selector: "random".into(), mode: "oc1.3".into(), target: 2, priority: 0 },
            JobMeta { selector: "oort".into(), mode: "dl60".into(), target: 3, priority: 7 },
        ];
        let r = book.finish(&meta, "twojobs", "fair");
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("policy").unwrap().as_str(), Some("fair"));
        let jobs = parsed.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[1].get("selector").unwrap().as_str(), Some("oort"));
        assert_eq!(jobs[1].get("priority").unwrap().as_usize(), Some(7));
        assert_eq!(
            parsed.get("fleet_spent_secs").unwrap().as_f64(),
            Some(r.fleet_spent_secs)
        );

        let flat = r.summary_result();
        assert_eq!(flat.rounds.len(), 2);
        // job-major concatenation with running fleet cums: monotone, final
        // record pinned to the fleet totals
        assert!(flat.rounds[1].cum_resource_secs >= flat.rounds[0].cum_resource_secs);
        assert_eq!(flat.rounds[1].cum_resource_secs, r.fleet_spent_secs);
        assert_eq!(flat.rounds[1].cum_waste_secs, r.fleet_wasted_secs);
        assert_eq!(flat.final_accuracy(), Some(0.5));
    }
}
