//! Multi-job replay: re-derive a full [`MultiJobResult`] from a run log
//! alone, one event at a time.
//!
//! Unlike the single-job oracle (`runlog::replay`), which is a deliberately
//! independent re-implementation of the engines' bookkeeping, the multi-job
//! reducer drives the *same* [`MultiJobBook`] the engine drives, in the
//! same event order — engine-vs-replay byte-identity holds by construction,
//! and what the oracle checks instead is the *stream*: every derived
//! quantity the engine logged (per-round fresh/failed/train-loss, the
//! terminal sweep seconds) is re-derived from the raw claim/delivery events
//! and bit-compared. A log whose derived fields disagree with its own raw
//! events is a real engine/logging divergence, and replay rejects it.
//!
//! The reducer is incremental: the telemetry watcher feeds it segment by
//! segment and pulls [`MultiJobReducer::live`] snapshots mid-run, exactly
//! like the single-job `RunReducer`.

use anyhow::{bail, Result};

use crate::runlog::replay::LiveStats;
use crate::runlog::RunEvent;

use super::{JobMeta, MultiJobBook, MultiJobResult};

/// Rebuild the full multi-job result from a decoded event stream. The
/// stream must open with `JobSetStart` and close with `JobSetEnd`.
pub fn replay_multijob(events: &[RunEvent]) -> Result<MultiJobResult> {
    let mut events = events.iter();
    let first = events
        .next()
        .ok_or_else(|| anyhow::anyhow!("multi-job replay: empty run log"))?;
    let mut reducer = MultiJobReducer::start(first)?;
    for ev in events {
        reducer.step(ev)?;
    }
    if !reducer.ended() {
        bail!("multi-job replay: log ends without JobSetEnd");
    }
    Ok(reducer.result())
}

/// Incremental multi-job event reducer. Construct from the `JobSetStart`
/// header with [`MultiJobReducer::start`], feed the rest of the stream
/// through [`MultiJobReducer::step`].
pub struct MultiJobReducer {
    label: String,
    policy: String,
    njobs: usize,
    /// `jobs * rounds` — every job runs the same round count.
    rounds_total: u64,
    /// Static job specs, filled by the `JobStart` events (job-id order).
    meta: Vec<JobMeta>,
    book: MultiJobBook,
    ended: bool,
    /// Latest simulated clock witnessed (round/spawn events carry it).
    now: f64,
    rounds_done: usize,
}

impl MultiJobReducer {
    /// Start reducing from the stream's first event, which must be the
    /// `JobSetStart` header.
    pub fn start(ev: &RunEvent) -> Result<MultiJobReducer> {
        let RunEvent::JobSetStart { label, jobs, policy, rounds, eval_every } = ev else {
            bail!("multi-job replay: log must open with JobSetStart, got {ev:?}");
        };
        if *jobs == 0 {
            bail!("multi-job replay: header promises zero jobs");
        }
        if *eval_every == 0 {
            bail!("multi-job replay: eval_every must be >= 1");
        }
        Ok(MultiJobReducer {
            label: label.clone(),
            policy: policy.clone(),
            njobs: *jobs as usize,
            rounds_total: jobs * rounds,
            meta: Vec::with_capacity(*jobs as usize),
            book: MultiJobBook::new(*jobs as usize),
            ended: false,
            now: 0.0,
            rounds_done: 0,
        })
    }

    /// Consume one post-header event. Reducer state after an error is
    /// unspecified; consumers should stop reducing.
    pub fn step(&mut self, ev: &RunEvent) -> Result<()> {
        if self.ended {
            bail!("multi-job replay: event after JobSetEnd: {ev:?}");
        }
        match ev {
            RunEvent::JobStart { job, selector, mode, target, priority } => {
                if *job != self.meta.len() as u64 || *job >= self.njobs as u64 {
                    bail!(
                        "multi-job replay: JobStart for job {job}, expected {} of {}",
                        self.meta.len(),
                        self.njobs
                    );
                }
                self.meta.push(JobMeta {
                    selector: selector.clone(),
                    mode: mode.clone(),
                    target: *target as usize,
                    priority: *priority,
                });
            }
            RunEvent::JobRoundStart { job, round, now } => {
                self.book.round_start(*job as usize, *round, *now)?;
                self.now = *now;
            }
            RunEvent::JobSpawn { job, learner, now, duration, dropped_after, corrupt: _ } => {
                self.book.spawn(*job as usize, *learner, *duration, *dropped_after)?;
                self.now = *now;
            }
            RunEvent::JobDelivery { job, learner, duration, mean_loss, fate } => {
                self.book.delivery(*job as usize, *learner, *duration, *mean_loss, *fate)?;
            }
            RunEvent::JobRoundEnd {
                job,
                round,
                now,
                round_duration,
                fresh,
                failed,
                train_loss,
                eval_loss,
                eval_acc,
            } => {
                // Re-derive the round aggregates from the raw events and
                // bit-compare against what the engine logged: any drift is
                // a real bookkeeping divergence.
                let (r_fresh, r_failed, r_loss) = self.book.round_end(
                    *job as usize,
                    *round,
                    *now,
                    *round_duration,
                    *eval_loss,
                    *eval_acc,
                )?;
                if r_fresh != *fresh || r_failed != *failed {
                    bail!(
                        "multi-job replay divergence: job {job} round {round} replayed \
                         fresh={r_fresh} failed={r_failed}, log says fresh={fresh} \
                         failed={failed}"
                    );
                }
                if r_loss.map(f64::to_bits) != train_loss.map(f64::to_bits) {
                    bail!(
                        "multi-job replay divergence: job {job} round {round} replayed \
                         train_loss {r_loss:?}, log says {train_loss:?}"
                    );
                }
                self.now = *now;
                self.rounds_done += 1;
            }
            RunEvent::JobSweep { job, secs } => {
                let r_secs = self.book.sweep(*job as usize)?;
                if r_secs.to_bits() != secs.to_bits() {
                    bail!(
                        "multi-job replay divergence: job {job} sweep replayed \
                         {r_secs}, log says {secs}"
                    );
                }
            }
            RunEvent::JobSetEnd => {
                if self.meta.len() != self.njobs {
                    bail!(
                        "multi-job replay: JobSetEnd after {} JobStart headers, \
                         expected {}",
                        self.meta.len(),
                        self.njobs
                    );
                }
                self.ended = true;
            }
            RunEvent::JobSetStart { .. } => {
                bail!("multi-job replay: second JobSetStart header");
            }
            other => {
                bail!("multi-job replay: single-job event {other:?} in a multi-job log")
            }
        }
        Ok(())
    }

    /// `JobSetEnd` has been consumed cleanly.
    pub fn ended(&self) -> bool {
        self.ended
    }

    /// Run label from the header.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// The live per-job books (telemetry reads per-job gauges off these).
    pub fn book(&self) -> &MultiJobBook {
        &self.book
    }

    /// Point-in-time view for dashboards. Fleet-level sums; job-granular
    /// state is in [`MultiJobReducer::result`]. `unique_participants` sums
    /// the per-job sets (a device serving two jobs counts once per job).
    pub fn live(&self) -> LiveStats {
        let (spent, aggregated, wasted, in_flight) = self.book.fleet_totals();
        let unique = (0..self.book.len())
            .filter_map(|j| self.book.job(j))
            .map(|b| b.unique_participants())
            .sum();
        LiveStats {
            rounds_done: self.rounds_done,
            rounds_total: self.rounds_total,
            spent,
            aggregated,
            wasted,
            in_flight_secs: in_flight,
            outstanding: 0,
            buffer_fill: 0,
            unique_participants: unique,
            sim_time: self.now,
            current_round: None,
            complete: self.ended,
        }
    }

    /// The books as a result — final after `JobSetEnd`, best-effort partial
    /// before it (jobs whose `JobStart` has not arrived yet get placeholder
    /// specs), so the watcher can render a truncated log.
    pub fn result(&self) -> MultiJobResult {
        let mut meta = self.meta.clone();
        while meta.len() < self.book.len() {
            meta.push(JobMeta {
                selector: String::new(),
                mode: String::new(),
                target: 0,
                priority: 0,
            });
        }
        self.book.finish(&meta, &self.label, &self.policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runlog::{FATE_CORRUPT, FATE_TRAINED};

    fn header(jobs: u64) -> RunEvent {
        RunEvent::JobSetStart {
            label: "mj".into(),
            jobs,
            policy: "fair".into(),
            rounds: 1,
            eval_every: 1,
        }
    }

    fn job_start(job: u64) -> RunEvent {
        RunEvent::JobStart {
            job,
            selector: "random".into(),
            mode: "oc1.3".into(),
            target: 2,
            priority: 0,
        }
    }

    fn sample_log() -> Vec<RunEvent> {
        vec![
            header(2),
            job_start(0),
            job_start(1),
            RunEvent::JobRoundStart { job: 0, round: 0, now: 0.0 },
            RunEvent::JobRoundStart { job: 1, round: 0, now: 0.0 },
            RunEvent::JobSpawn {
                job: 0,
                learner: 3,
                now: 0.0,
                duration: 10.0,
                dropped_after: None,
                corrupt: false,
            },
            RunEvent::JobSpawn {
                job: 0,
                learner: 4,
                now: 0.0,
                duration: 30.0,
                dropped_after: Some(12.5),
                corrupt: false,
            },
            RunEvent::JobSpawn {
                job: 1,
                learner: 5,
                now: 0.0,
                duration: 20.0,
                dropped_after: None,
                corrupt: true,
            },
            RunEvent::JobDelivery {
                job: 0,
                learner: 3,
                duration: 10.0,
                mean_loss: 0.5,
                fate: FATE_TRAINED,
            },
            RunEvent::JobDelivery {
                job: 1,
                learner: 5,
                duration: 20.0,
                mean_loss: 0.0,
                fate: FATE_CORRUPT,
            },
            RunEvent::JobRoundEnd {
                job: 0,
                round: 0,
                now: 10.0,
                round_duration: 10.0,
                fresh: 1,
                failed: false,
                train_loss: Some(0.5),
                eval_loss: Some(1.0),
                eval_acc: Some(0.25),
            },
            RunEvent::JobRoundEnd {
                job: 1,
                round: 0,
                now: 25.0,
                round_duration: 25.0,
                fresh: 0,
                failed: true,
                train_loss: None,
                eval_loss: Some(2.0),
                eval_acc: Some(0.25),
            },
            RunEvent::JobSweep { job: 0, secs: 0.0 },
            RunEvent::JobSweep { job: 1, secs: 0.0 },
            RunEvent::JobSetEnd,
        ]
    }

    #[test]
    fn rebuilds_per_job_books_from_the_stream() {
        let r = replay_multijob(&sample_log()).unwrap();
        assert_eq!(r.label, "mj");
        assert_eq!(r.policy, "fair");
        assert_eq!(r.jobs.len(), 2);
        let j0 = &r.jobs[0];
        assert_eq!(j0.selector, "random");
        assert_eq!(j0.spent_secs, 22.5, "10 delivered + 12.5 partial dropout");
        assert_eq!(j0.aggregated_secs, 10.0);
        assert_eq!(j0.wasted_secs, 12.5);
        assert_eq!(j0.rounds.len(), 1);
        assert_eq!(j0.rounds[0].dropouts, 1);
        let j1 = &r.jobs[1];
        assert_eq!(j1.spent_secs, 20.0);
        assert_eq!(j1.wasted_secs, 20.0, "corrupt delivery is all waste");
        assert!(j1.rounds[0].failed);
        assert_eq!(r.fleet_spent_secs, 42.5);
        assert_eq!(
            r.fleet_spent_secs,
            r.fleet_aggregated_secs + r.fleet_wasted_secs + r.fleet_in_flight_secs
        );
    }

    #[test]
    fn rejects_divergent_round_aggregates() {
        let mut log = sample_log();
        // claim job 0 merged two fresh updates when the stream shows one
        if let RunEvent::JobRoundEnd { fresh, .. } = &mut log[10] {
            *fresh = 2;
        } else {
            panic!("fixture drifted");
        }
        let err = replay_multijob(&log).unwrap_err().to_string();
        assert!(err.contains("divergence"), "{err}");
    }

    #[test]
    fn rejects_divergent_sweep_seconds() {
        let mut log = sample_log();
        if let RunEvent::JobSweep { secs, .. } = &mut log[13] {
            *secs = 7.0;
        } else {
            panic!("fixture drifted");
        }
        let err = replay_multijob(&log).unwrap_err().to_string();
        assert!(err.contains("sweep"), "{err}");
    }

    #[test]
    fn rejects_wrong_headers_and_truncation() {
        assert!(replay_multijob(&[]).is_err());
        // single-job header in front
        let err = replay_multijob(&[RunEvent::RunEnd]).unwrap_err().to_string();
        assert!(err.contains("JobSetStart"), "{err}");
        // truncated: no JobSetEnd
        let mut log = sample_log();
        log.pop();
        let err = replay_multijob(&log).unwrap_err().to_string();
        assert!(err.contains("JobSetEnd"), "{err}");
        // single-job event in a multi-job stream
        let log = vec![header(1), job_start(0), RunEvent::RoundStart { round: 0, now: 0.0 }];
        let err = replay_multijob(&log).unwrap_err().to_string();
        assert!(err.contains("single-job"), "{err}");
    }

    #[test]
    fn live_snapshot_tracks_the_fleet_mid_stream() {
        let log = sample_log();
        let mut red = MultiJobReducer::start(&log[0]).unwrap();
        for ev in &log[1..10] {
            red.step(ev).unwrap();
        }
        let live = red.live();
        assert!(!live.complete);
        assert_eq!(live.rounds_total, 2);
        assert_eq!(live.rounds_done, 0);
        assert_eq!(live.spent, 42.5);
        assert_eq!(live.unique_participants, 3);
        // partial result renders without panicking
        let partial = red.result();
        assert_eq!(partial.jobs.len(), 2);
    }
}
