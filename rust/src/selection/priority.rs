//! RELAY's Intelligent Participant Selection — paper Algorithm 1.
//!
//! On check-in the server sends the learner the slot (mu_t, 2mu_t); the
//! learner answers with its forecast availability probability for that slot
//! (already materialized in `Candidate::avail_prob`). At the end of the
//! selection window the server sorts ascending, randomly shuffles ties, and
//! takes the top N_t — i.e. the *least available* learners are prioritized,
//! maximizing coverage of limited-availability learners' data.

use super::{SelectionCtx, Selector};

pub struct PrioritySelector;

impl Selector for PrioritySelector {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize> {
        let k = ctx.target.min(ctx.candidates.len());
        // Shuffle first, then stable-sort by probability: equal-probability
        // learners keep the shuffled order = Algorithm 1's random tie-break.
        let mut order: Vec<usize> = (0..ctx.candidates.len()).collect();
        ctx.rng.shuffle(&mut order);
        order.sort_by(|&a, &b| {
            ctx.candidates[a]
                .avail_prob
                .partial_cmp(&ctx.candidates[b].avail_prob)
                .unwrap()
        });
        order.truncate(k);
        order.into_iter().map(|i| ctx.candidates[i].id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::{mk_candidates, Candidate};
    use crate::util::rng::Rng;

    #[test]
    fn picks_least_available() {
        let candidates = mk_candidates(20); // avail_prob = i/20
        let mut s = PrioritySelector;
        let mut rng = Rng::new(1);
        let mut ctx = SelectionCtx {
            round: 0,
            now: 0.0,
            target: 4,
            candidates: &candidates,
            rng: &mut rng,
        };
        let mut picked = s.select(&mut ctx);
        picked.sort_unstable();
        assert_eq!(picked, vec![0, 1, 2, 3]);
    }

    #[test]
    fn ties_are_shuffled_not_positional() {
        // all-equal probabilities (the AllAvail case): selection must vary
        // across rounds -> degenerates to random, as the paper notes.
        let candidates: Vec<Candidate> = (0..30)
            .map(|i| Candidate { id: i, avail_prob: 1.0, expected_duration: 1.0 })
            .collect();
        let mut s = PrioritySelector;
        let mut rng = Rng::new(2);
        let mut seen = std::collections::HashSet::new();
        for round in 0..40 {
            let mut ctx = SelectionCtx {
                round,
                now: 0.0,
                target: 5,
                candidates: &candidates,
                rng: &mut rng,
            };
            seen.extend(s.select(&mut ctx));
        }
        assert!(seen.len() >= 25, "tie shuffle should spread selection, saw {}", seen.len());
    }

    #[test]
    fn mixed_ties_resolved_within_level() {
        // two low-prob learners + many ties at 0.9: the low two always
        // selected, remainder drawn from the tie set
        let mut candidates = vec![
            Candidate { id: 100, avail_prob: 0.1, expected_duration: 1.0 },
            Candidate { id: 101, avail_prob: 0.2, expected_duration: 1.0 },
        ];
        for i in 0..20 {
            candidates.push(Candidate { id: i, avail_prob: 0.9, expected_duration: 1.0 });
        }
        let mut s = PrioritySelector;
        let mut rng = Rng::new(3);
        for round in 0..10 {
            let mut ctx = SelectionCtx {
                round,
                now: 0.0,
                target: 5,
                candidates: &candidates,
                rng: &mut rng,
            };
            let picked = s.select(&mut ctx);
            assert!(picked.contains(&100));
            assert!(picked.contains(&101));
        }
    }
}
