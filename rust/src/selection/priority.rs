//! RELAY's Intelligent Participant Selection — paper Algorithm 1.
//!
//! On check-in the server sends the learner the slot (mu_t, 2mu_t); the
//! learner answers with its forecast availability probability for that slot
//! (materialized in `Candidate::avail_prob`, or served lazily through
//! [`super::ProbeSource`]). The server prioritizes the *least available*
//! learners: probabilities ascending, random tie-break, top N_t — maximizing
//! coverage of limited-availability learners' data.
//!
//! Selection is **level-streamed**: equal-probability learners form a level;
//! whole levels are taken ascending (id order within a level) until one no
//! longer fits, and the boundary level is cut by a uniform `choose_k` over
//! its id-ascending members — Algorithm 1's random tie-break applied exactly
//! where it matters (the boundary), with O(k) RNG draws instead of a full
//! O(n) pool shuffle. That is what lets the indexed fast path answer from a
//! **per-time-bucket availability-probability tree** in O(k log n) per
//! selection: the tree (learner → probe answer, [`ScoreIndex`]) stays valid
//! for as long as the probe's [`super::SlotSig`] time bucket does, absorbing
//! eligibility deltas from the `on_eligible`/`on_ineligible` hooks; when
//! the slot crosses an hour-of-week bin it is **delta-rebuilt** — every
//! member is re-probed but only the entries whose bucket value actually
//! changed are re-keyed, which is structurally identical to a full rebuild
//! (treap shapes are pure functions of the `(id, score)` set) without the
//! O(|eligible| log n) tree-reconstruction spike at 1M learners. Both paths
//! are element-for-element identical (same RNG draws), pinned by
//! `tests/selection_index_props.rs`.

use crate::util::rng::Rng;

use super::index::ScoreIndex;
use super::{SelectPool, SelectionCtx, Selector, SlotSig};

#[derive(Default)]
pub struct PrioritySelector {
    /// Probability tree over the eligible pool, valid while `sig` holds.
    tree: Option<ScoreIndex>,
    sig: Option<SlotSig>,
    /// Eligibility deltas logged by the hooks since the last selection.
    pending: Vec<(usize, bool)>,
}

impl PrioritySelector {
    /// Bring the probability tree in line with the pool: fold in the
    /// hook-logged eligibility deltas, then — when the probe's time bucket
    /// moved — **delta-rebuild**: re-probe every member but touch the tree
    /// only where the answer actually changed. Treap shapes are a pure
    /// function of the `(id, score)` set, so the delta-rebuilt tree is
    /// structurally identical to a from-scratch rebuild (pinned by
    /// `tests/selection_index_props.rs`) at a fraction of the tree work —
    /// hour-of-week neighbours share most bin values, so a bucket crossing
    /// at 1M learners re-keys thousands of entries, not the whole pool
    /// (ROADMAP follow-up resolved). A full rebuild remains the first-use
    /// and desync path.
    fn sync_index(&mut self, pool: &SelectPool, now: f64) {
        let sig = pool.probes.slot_sig(now, pool.mu);
        let mut rebuild = match (&self.tree, &self.sig) {
            (Some(t), Some(_)) => t.capacity() != pool.set.capacity(),
            _ => true,
        };
        if !rebuild {
            let tree = self.tree.as_mut().expect("checked above");
            for (id, elig) in self.pending.drain(..) {
                if elig {
                    tree.insert(id, pool.probes.avail_prob(id, now, pool.mu));
                } else {
                    tree.remove(id);
                }
            }
            // desync safety net: a selector driven against a pool whose
            // deltas never reached the hooks (reuse across pools) must
            // rebuild rather than panic or serve stale ids
            rebuild = tree.len() != pool.set.len();
            if !rebuild && self.sig.as_ref() != Some(&sig) {
                // hour-bucket crossing: collect the members whose probe
                // answer moved (two-pass so a membership desync can still
                // fall back to the full rebuild untouched)
                let mut changed: Vec<(usize, f64)> = Vec::new();
                let mut matched = 0usize;
                for id in pool.set.iter() {
                    let v = pool.probes.avail_prob(id, now, pool.mu);
                    if let Some(old) = tree.score(id) {
                        matched += 1;
                        if old.to_bits() != v.to_bits() {
                            changed.push((id, v));
                        }
                    }
                }
                if matched == pool.set.len() {
                    for (id, v) in changed {
                        tree.insert(id, v);
                    }
                    self.sig = Some(sig.clone());
                } else {
                    rebuild = true;
                }
            }
        }
        if rebuild {
            let mut tree =
                ScoreIndex::with_shards(pool.set.capacity(), pool.set.num_shards());
            for id in pool.set.iter() {
                tree.insert(id, pool.probes.avail_prob(id, now, pool.mu));
            }
            self.tree = Some(tree);
            self.sig = Some(sig);
            self.pending.clear();
        }
    }
}

impl Selector for PrioritySelector {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize> {
        let cands = ctx.candidates;
        let k = ctx.target.min(cands.len());
        // candidates arrive in ascending id order; a stable sort by
        // probability alone leaves each level's ids ascending (total_cmp:
        // a non-finite probability sorts deterministically, never panics)
        let mut order: Vec<usize> = (0..cands.len()).collect();
        order.sort_by(|&a, &b| cands[a].avail_prob.total_cmp(&cands[b].avail_prob));
        let mut picked = Vec::with_capacity(k);
        let mut i = 0usize;
        while picked.len() < k {
            let p = cands[order[i]].avail_prob;
            let mut j = i + 1;
            while j < order.len()
                && cands[order[j]].avail_prob.total_cmp(&p) == std::cmp::Ordering::Equal
            {
                j += 1;
            }
            let m = j - i;
            let rem = k - picked.len();
            if m <= rem {
                for &oi in &order[i..j] {
                    picked.push(cands[oi].id);
                }
            } else {
                // boundary level: Algorithm 1's random tie-break
                for pos in ctx.rng.choose_k(m, rem) {
                    picked.push(cands[order[i + pos]].id);
                }
            }
            i = j;
        }
        picked
    }

    /// Indexed fast path: stream levels ascending from the probability
    /// tree — O((k + levels) log n) per selection, independent of the pool
    /// size, with the same RNG draws as [`PrioritySelector::select`].
    fn select_from(
        &mut self,
        pool: &SelectPool,
        _round: usize,
        now: f64,
        target: usize,
        rng: &mut Rng,
    ) -> Option<Vec<usize>> {
        self.sync_index(pool, now);
        let n = pool.set.len();
        let k = target.min(n);
        let tree = self.tree.as_ref().expect("sync_index always builds");
        debug_assert_eq!(tree.len(), n, "probability tree out of sync with pool");
        let mut picked = Vec::with_capacity(k);
        let mut bound: Option<f64> = None;
        while picked.len() < k {
            let p = tree
                .min_score_gt(bound)
                .expect("k <= len guarantees a next level");
            let m = tree.level_len(p);
            let rem = k - picked.len();
            if m <= rem {
                tree.for_level_asc(p, |id| {
                    picked.push(id);
                    true
                });
            } else {
                for pos in rng.choose_k(m, rem) {
                    picked.push(tree.nth_in_level(p, pos));
                }
            }
            bound = Some(p);
        }
        Some(picked)
    }

    fn on_eligible(&mut self, id: usize) {
        if self.tree.is_some() {
            self.pending.push((id, true));
        }
    }

    fn on_ineligible(&mut self, id: usize) {
        if self.tree.is_some() {
            self.pending.push((id, false));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::population::CandidateSet;
    use crate::selection::{mk_candidates, Candidate, MockProbes};
    use crate::util::rng::Rng;

    #[test]
    fn picks_least_available() {
        let candidates = mk_candidates(20); // avail_prob = i/20
        let mut s = PrioritySelector::default();
        let mut rng = Rng::new(1);
        let mut ctx = SelectionCtx {
            round: 0,
            now: 0.0,
            target: 4,
            candidates: &candidates,
            rng: &mut rng,
        };
        let mut picked = s.select(&mut ctx);
        picked.sort_unstable();
        assert_eq!(picked, vec![0, 1, 2, 3]);
    }

    #[test]
    fn ties_are_shuffled_not_positional() {
        // all-equal probabilities (the AllAvail case): selection must vary
        // across rounds -> degenerates to random, as the paper notes.
        let candidates: Vec<Candidate> = (0..30)
            .map(|i| Candidate { id: i, avail_prob: 1.0, expected_duration: 1.0 })
            .collect();
        let mut s = PrioritySelector::default();
        let mut rng = Rng::new(2);
        let mut seen = std::collections::HashSet::new();
        for round in 0..40 {
            let mut ctx = SelectionCtx {
                round,
                now: 0.0,
                target: 5,
                candidates: &candidates,
                rng: &mut rng,
            };
            seen.extend(s.select(&mut ctx));
        }
        assert!(seen.len() >= 25, "tie shuffle should spread selection, saw {}", seen.len());
    }

    #[test]
    fn mixed_ties_resolved_within_level() {
        // two low-prob learners + many ties at 0.9: the low two always
        // selected, remainder drawn from the tie set
        let mut candidates = vec![
            Candidate { id: 100, avail_prob: 0.1, expected_duration: 1.0 },
            Candidate { id: 101, avail_prob: 0.2, expected_duration: 1.0 },
        ];
        for i in 0..20 {
            candidates.push(Candidate { id: i, avail_prob: 0.9, expected_duration: 1.0 });
        }
        let mut s = PrioritySelector::default();
        let mut rng = Rng::new(3);
        for round in 0..10 {
            let mut ctx = SelectionCtx {
                round,
                now: 0.0,
                target: 5,
                candidates: &candidates,
                rng: &mut rng,
            };
            let picked = s.select(&mut ctx);
            assert!(picked.contains(&100));
            assert!(picked.contains(&101));
        }
    }

    #[test]
    fn non_finite_probability_does_not_panic() {
        // regression: the seed's partial_cmp().unwrap() comparator panicked
        // if a NaN probability ever leaked in; total_cmp ranks it last
        // (greatest), i.e. a NaN-probed learner is selected only when the
        // target reaches its level
        let mut candidates = mk_candidates(6);
        candidates[2].avail_prob = f64::NAN;
        let mut s = PrioritySelector::default();
        let mut rng = Rng::new(4);
        let mut ctx = SelectionCtx {
            round: 0,
            now: 0.0,
            target: 5,
            candidates: &candidates,
            rng: &mut rng,
        };
        let picked = s.select(&mut ctx);
        assert_eq!(picked.len(), 5);
        assert!(!picked.contains(&2), "NaN prob must rank last, not first");
        // selecting everyone still terminates and includes the NaN learner
        let mut ctx = SelectionCtx {
            round: 1,
            now: 0.0,
            target: 6,
            candidates: &candidates,
            rng: &mut rng,
        };
        assert_eq!(s.select(&mut ctx).len(), 6);
    }

    /// The core fast-path contract: identical elements AND identical RNG
    /// consumption vs the materialized select, across churn and re-probes.
    #[test]
    fn indexed_path_bit_identical_to_select() {
        let mut gen = Rng::new(0x5EED);
        for case in 0..30 {
            let n = 5 + (case % 40);
            let candidates: Vec<Candidate> = (0..n)
                .map(|i| Candidate {
                    id: i,
                    // coarse grid => plenty of exact ties (levels)
                    avail_prob: (gen.below(5) as f64) * 0.25,
                    expected_duration: 10.0,
                })
                .collect();
            let mut set = CandidateSet::new(n);
            for c in &candidates {
                set.insert(c.id);
            }
            let probes = MockProbes::from_candidates(&candidates);
            let pool = SelectPool { set: &set, probes: &probes, mu: 60.0 };
            let target = gen.range(0, n + 3);
            let seed = gen.next_u64();
            let mut fast_sel = PrioritySelector::default();
            let mut slow_sel = PrioritySelector::default();
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let fast = fast_sel.select_from(&pool, 0, 0.0, target, &mut r1).unwrap();
            let mut ctx = SelectionCtx {
                round: 0,
                now: 0.0,
                target,
                candidates: &candidates,
                rng: &mut r2,
            };
            let slow = slow_sel.select(&mut ctx);
            assert_eq!(fast, slow, "case {case}");
            assert_eq!(r1.next_u64(), r2.next_u64(), "case {case}: rng diverged");
        }
    }

    /// Bucket crossings delta-rebuild the tree; the result must be
    /// indistinguishable from a from-scratch rebuild at the new bucket.
    #[test]
    fn bucket_change_delta_rebuild_matches_fresh_selector() {
        use crate::selection::{ProbeSource, SlotSig};
        // probe answers move with the hour bucket, on a coarse grid so some
        // learners keep their value across a crossing (the delta case)
        struct HourProbes;
        impl ProbeSource for HourProbes {
            fn avail_prob(&self, id: usize, now: f64, _mu: f64) -> f64 {
                let hour = (now / 3600.0) as usize;
                ((id * 13 + hour * 7) % 4) as f64 * 0.25
            }
            fn expected_duration(&self, id: usize) -> f64 {
                10.0 + (id % 5) as f64
            }
            fn slot_sig(&self, now: f64, _mu: f64) -> SlotSig {
                SlotSig::Bins(vec![(now / 3600.0) as u16])
            }
        }
        let n = 50usize;
        let probes = HourProbes;
        let mut set = CandidateSet::new(n);
        for id in 0..n {
            set.insert(id);
        }
        let mut maintained = PrioritySelector::default();
        let mut churn = Rng::new(21);
        let mut now = 0.0f64;
        for step in 0..12 {
            now += 3600.0 * (1 + step % 3) as f64; // every step crosses bins
            // interleave hook-driven churn with the bucket crossings
            for _ in 0..4 {
                let id = churn.below(n);
                if set.contains(id) {
                    set.remove(id);
                    maintained.on_ineligible(id);
                } else {
                    set.insert(id);
                    maintained.on_eligible(id);
                }
            }
            let pool = SelectPool { set: &set, probes: &probes, mu: 60.0 };
            let seed = 1000 + step as u64;
            let a = maintained
                .select_from(&pool, step, now, 9, &mut Rng::new(seed))
                .unwrap();
            let mut fresh = PrioritySelector::default();
            let b = fresh.select_from(&pool, step, now, 9, &mut Rng::new(seed)).unwrap();
            assert_eq!(a, b, "step {step}: delta-rebuilt tree diverged from fresh");
        }
    }

    /// Hook-maintained deltas answer identically to a fresh rebuild.
    #[test]
    fn hook_deltas_match_rebuild() {
        let n = 60usize;
        let candidates = mk_candidates(n);
        let probes = MockProbes::from_candidates(&candidates);
        let mut set = CandidateSet::new(n);
        for id in 0..n {
            set.insert(id);
        }
        let mut maintained = PrioritySelector::default();
        // warm the tree on the full pool
        {
            let pool = SelectPool { set: &set, probes: &probes, mu: 60.0 };
            maintained.select_from(&pool, 0, 0.0, 5, &mut Rng::new(1));
        }
        // churn: remove odds, re-add some, all through the hooks
        let mut churn = Rng::new(7);
        for id in 0..n {
            if id % 2 == 1 {
                set.remove(id);
                maintained.on_ineligible(id);
            }
        }
        for _ in 0..20 {
            let id = churn.below(n);
            if set.insert(id) {
                maintained.on_eligible(id);
            }
        }
        for seed in 0..5u64 {
            let pool = SelectPool { set: &set, probes: &probes, mu: 60.0 };
            let a = maintained
                .select_from(&pool, 1, 0.0, 12, &mut Rng::new(seed))
                .unwrap();
            let mut fresh = PrioritySelector::default();
            let b = fresh.select_from(&pool, 1, 0.0, 12, &mut Rng::new(seed)).unwrap();
            assert_eq!(a, b, "seed {seed}");
        }
    }
}
