//! SAFA's "selection" (Wu et al., IEEE ToC'21): there is none before
//! training — every available learner trains every round, and the round
//! ends once a pre-set fraction report (post-training selection). The
//! coordinator's SAFA protocol handles the fraction; this selector simply
//! returns all checked-in learners.

use crate::util::rng::Rng;

use super::{SelectPool, SelectionCtx, Selector};

pub struct SafaSelector;

impl Selector for SafaSelector {
    fn name(&self) -> &'static str {
        "safa"
    }

    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize> {
        ctx.candidates.iter().map(|c| c.id).collect()
    }

    /// Select-all needs no ranking state at all: stream the eligible set in
    /// ascending id order — exactly the id sequence `select` produces over
    /// the materialized candidate list, with zero RNG draws. O(|eligible|)
    /// output size, O(1) per element, independent of the total population.
    fn select_from(
        &mut self,
        pool: &SelectPool,
        _round: usize,
        _now: f64,
        _target: usize,
        _rng: &mut Rng,
    ) -> Option<Vec<usize>> {
        Some(pool.set.iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::{mk_candidates, MockProbes};
    use crate::util::rng::Rng;

    #[test]
    fn selects_everyone_regardless_of_target() {
        let candidates = mk_candidates(50);
        let mut s = SafaSelector;
        let mut rng = Rng::new(1);
        let mut ctx = SelectionCtx {
            round: 0,
            now: 0.0,
            target: 5,
            candidates: &candidates,
            rng: &mut rng,
        };
        assert_eq!(s.select(&mut ctx).len(), 50);
    }

    #[test]
    fn streamed_path_matches_select_with_no_rng_use() {
        let candidates = mk_candidates(30);
        let mut set = crate::population::CandidateSet::new(30);
        for c in &candidates {
            set.insert(c.id);
        }
        let probes = MockProbes::from_candidates(&candidates);
        let pool = SelectPool { set: &set, probes: &probes, mu: 50.0 };
        let mut s = SafaSelector;
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let fast = s.select_from(&pool, 0, 0.0, 5, &mut r1).unwrap();
        let mut ctx = SelectionCtx {
            round: 0,
            now: 0.0,
            target: 5,
            candidates: &candidates,
            rng: &mut r2,
        };
        assert_eq!(fast, s.select(&mut ctx));
        assert_eq!(r1.next_u64(), r2.next_u64(), "rng must be untouched");
    }
}
