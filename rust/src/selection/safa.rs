//! SAFA's "selection" (Wu et al., IEEE ToC'21): there is none before
//! training — every available learner trains every round, and the round
//! ends once a pre-set fraction report (post-training selection). The
//! coordinator's SAFA protocol handles the fraction; this selector simply
//! returns all checked-in learners.

use super::{SelectionCtx, Selector};

pub struct SafaSelector;

impl Selector for SafaSelector {
    fn name(&self) -> &'static str {
        "safa"
    }

    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize> {
        ctx.candidates.iter().map(|c| c.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::mk_candidates;
    use crate::util::rng::Rng;

    #[test]
    fn selects_everyone_regardless_of_target() {
        let candidates = mk_candidates(50);
        let mut s = SafaSelector;
        let mut rng = Rng::new(1);
        let mut ctx = SelectionCtx {
            round: 0,
            now: 0.0,
            target: 5,
            candidates: &candidates,
            rng: &mut rng,
        };
        assert_eq!(s.select(&mut ctx).len(), 50);
    }
}
