//! RELAY's Adaptive Participant Target (paper §4.1 "APT").
//!
//! The server keeps a moving-average estimate of round duration
//! `mu_t = (1 - alpha) * D_{t-1} + alpha * mu_{t-1}` (alpha = 0.25 in the
//! paper), probes each in-flight straggler for its expected remaining
//! upload time RT_s, counts how many will land within the coming round
//! (B_t = |{s : RT_s <= mu_t}|), and shrinks the selection target to
//! N_t = max(1, N_0 - B_t) — incoming stale updates substitute for fresh
//! participants, saving their resources.

use crate::util::stats::Ema;

#[derive(Clone, Debug)]
pub struct AdaptiveTarget {
    /// Developer-set baseline target N_0.
    pub n0: usize,
    mu: Ema,
    initialized: bool,
}

impl AdaptiveTarget {
    pub fn new(n0: usize, alpha: f64, initial_mu: f64) -> Self {
        let mut mu = Ema::new(alpha);
        mu.update(initial_mu);
        AdaptiveTarget { n0, mu, initialized: true }
    }

    /// Record the duration of the just-finished round.
    pub fn observe_round(&mut self, duration: f64) {
        self.mu.update(duration);
    }

    /// Current round-duration estimate mu_t.
    pub fn mu(&self) -> f64 {
        self.mu.value
    }

    /// The slot (mu_t, 2 mu_t) sent to learners at check-in (Algorithm 1).
    pub fn slot(&self) -> (f64, f64) {
        (self.mu(), 2.0 * self.mu())
    }

    /// N_t given the remaining times of current stragglers.
    pub fn target(&self, straggler_remaining: &[f64]) -> usize {
        let b_t = straggler_remaining
            .iter()
            .filter(|&&rt| rt <= self.mu())
            .count();
        self.n0.saturating_sub(b_t).max(1)
    }

    pub fn is_initialized(&self) -> bool {
        self.initialized
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_follows_paper_rule() {
        let mut apt = AdaptiveTarget::new(10, 0.25, 100.0);
        assert_eq!(apt.mu(), 100.0);
        apt.observe_round(200.0);
        // mu = 0.75*200 + 0.25*100 = 175
        assert!((apt.mu() - 175.0).abs() < 1e-12);
        assert_eq!(apt.slot(), (175.0, 350.0));
    }

    #[test]
    fn target_shrinks_by_imminent_stragglers() {
        let apt = AdaptiveTarget::new(10, 0.25, 100.0);
        // 3 stragglers land within mu, 2 don't
        let rts = [50.0, 99.0, 100.0, 150.0, 400.0];
        assert_eq!(apt.target(&rts), 7);
    }

    #[test]
    fn target_floors_at_one() {
        let apt = AdaptiveTarget::new(2, 0.25, 100.0);
        let rts = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(apt.target(&rts), 1);
    }

    #[test]
    fn no_stragglers_keeps_n0() {
        let apt = AdaptiveTarget::new(10, 0.25, 100.0);
        assert_eq!(apt.target(&[]), 10);
    }
}
