//! The selection-index subsystem: **samplable utility structures** so
//! rank-the-pool selectors scale sub-linearly in the population
//! (ROADMAP item resolved by this subsystem).
//!
//! [`ScoreIndex`] is a sharded ordered-statistic score tree mapping
//! learner id → score. Shards cover contiguous id ranges (mirroring
//! [`crate::population::CandidateSet`]'s layout); each shard is an
//! arena treap ordered by `(score, id)` under `f64::total_cmp` with
//! subtree counts and score sums. Costs:
//!
//! * insert / update / remove — O(log n)
//! * top-k extraction (score-descending, id-ascending ties) — O(k log n)
//! * rank / level queries (`count_lt`, `level_len`, `nth_in_level`) —
//!   O(S log n) for S shards
//! * weighted sampling proportional to score — O(L · S log n) for L
//!   distinct positive score levels (the bucketed probability/utility
//!   trees this index serves keep L small)
//!
//! Every query — weighted sampling included — is defined over the *global*
//! `(score, id)` order, and treap shapes are a pure function of the member
//! set (priorities derive from the id), so results are **byte-identical
//! for any shard count** and for any maintenance history —
//! rebuilt-from-scratch and hook-maintained indices answer identically
//! (`tests/selection_index_props.rs` locks both in).
//! [`ScoreIndex::weighted_sample`] resolves its draw with a level walk
//! over that global order (ROADMAP follow-up: the original shard-major
//! prefix walk was distribution-invariant but not byte-invariant across
//! shard layouts, which blocked engine paths from relying on it).
//!
//! Ordering uses `total_cmp`, a *total* order: a non-finite score that
//! leaks in degrades ranking quality but can never panic a comparator,
//! matching the `total_cmp` hardening of the selector sort paths.

mod treap;

use std::collections::HashMap;

use crate::population::DEFAULT_SHARDS;
use crate::util::rng::Rng;
use treap::Treap;

/// Sharded ordered-statistic score tree (see the module docs).
pub struct ScoreIndex {
    shards: Vec<Treap>,
    /// id → current score, the O(1) membership/update side table.
    keys: HashMap<usize, f64>,
    shard_size: usize,
    n: usize,
}

impl ScoreIndex {
    /// Empty index over ids `0..n` with the default shard count.
    pub fn new(n: usize) -> ScoreIndex {
        ScoreIndex::with_shards(n, DEFAULT_SHARDS)
    }

    /// Empty index over ids `0..n` split into `num_shards` contiguous id
    /// ranges. The shard count affects only internal layout, never results.
    pub fn with_shards(n: usize, num_shards: usize) -> ScoreIndex {
        let num_shards = num_shards.max(1);
        let shard_size = n.div_ceil(num_shards).max(1);
        let count = n.div_ceil(shard_size).max(1);
        ScoreIndex {
            shards: (0..count).map(|_| Treap::new()).collect(),
            keys: HashMap::new(),
            shard_size,
            n,
        }
    }

    /// Number of ids the index ranges over (the population size).
    pub fn capacity(&self) -> usize {
        self.n
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn contains(&self, id: usize) -> bool {
        self.keys.contains_key(&id)
    }

    /// Current score of `id`, if present.
    pub fn score(&self, id: usize) -> Option<f64> {
        self.keys.get(&id).copied()
    }

    #[inline]
    fn shard_of(&self, id: usize) -> usize {
        id / self.shard_size
    }

    /// Insert or update `id` with `score`; returns the previous score.
    pub fn insert(&mut self, id: usize, score: f64) -> Option<f64> {
        assert!(id < self.n, "id {id} out of range (capacity {})", self.n);
        let s = self.shard_of(id);
        let old = self.keys.insert(id, score);
        if let Some(old_key) = old {
            self.shards[s].remove(old_key, id);
        }
        self.shards[s].insert(score, id);
        old
    }

    /// Remove `id`; returns its score if it was present.
    pub fn remove(&mut self, id: usize) -> Option<f64> {
        let old = self.keys.remove(&id)?;
        let s = self.shard_of(id);
        self.shards[s].remove(old, id);
        Some(old)
    }

    pub fn clear(&mut self) {
        for sh in &mut self.shards {
            sh.clear();
        }
        self.keys.clear();
    }

    /// Number of entries with score strictly below `score` (total order).
    pub fn count_lt(&self, score: f64) -> usize {
        self.shards.iter().map(|sh| sh.count_lt(score)).sum()
    }

    /// Number of entries with score exactly `score` (total-order equality).
    pub fn level_len(&self, score: f64) -> usize {
        self.shards
            .iter()
            .map(|sh| sh.count_le(score) - sh.count_lt(score))
            .sum()
    }

    /// The `i`-th smallest id among entries scored exactly `score`.
    /// Requires `i < level_len(score)`.
    pub fn nth_in_level(&self, score: f64, mut i: usize) -> usize {
        // shards are contiguous ascending id ranges, so within a level the
        // global id-ascending order is the shard-order concatenation
        for sh in &self.shards {
            let c = sh.count_le(score) - sh.count_lt(score);
            if i < c {
                let (_, id) = sh.select(sh.count_lt(score) + i);
                return id;
            }
            i -= c;
        }
        panic!("nth_in_level index out of range");
    }

    /// Visit the ids scored exactly `score` in ascending id order while `f`
    /// returns true.
    pub fn for_level_asc(&self, score: f64, mut f: impl FnMut(usize) -> bool) {
        let mut go = true;
        for sh in &self.shards {
            if !go {
                break;
            }
            sh.for_eq(score, &mut |id| {
                go = f(id);
                go
            });
        }
    }

    /// Smallest score strictly greater than `bound` (`None` = the global
    /// minimum). Drives ascending level streaming.
    pub fn min_score_gt(&self, bound: Option<f64>) -> Option<f64> {
        let mut best: Option<f64> = None;
        for sh in &self.shards {
            if let Some(k) = sh.min_key_gt(bound) {
                best = Some(match best {
                    None => k,
                    Some(b) => {
                        if k.total_cmp(&b) == std::cmp::Ordering::Less {
                            k
                        } else {
                            b
                        }
                    }
                });
            }
        }
        best
    }

    /// Largest score strictly less than `bound` (`None` = the global
    /// maximum). Drives descending level streaming.
    pub fn max_score_lt(&self, bound: Option<f64>) -> Option<f64> {
        let mut best: Option<f64> = None;
        for sh in &self.shards {
            if let Some(k) = sh.max_key_lt(bound) {
                best = Some(match best {
                    None => k,
                    Some(b) => {
                        if k.total_cmp(&b) == std::cmp::Ordering::Greater {
                            k
                        } else {
                            b
                        }
                    }
                });
            }
        }
        best
    }

    /// The top `k` entries by score descending, ascending id within a score
    /// tie — exactly the order a stable descending sort over an ascending-id
    /// candidate list produces. O(k log n).
    pub fn top_k_desc(&self, k: usize, mut f: impl FnMut(usize, f64)) {
        let mut taken = 0usize;
        let mut bound: Option<f64> = None;
        while taken < k {
            let Some(p) = self.max_score_lt(bound) else { break };
            let want = (k - taken).min(self.level_len(p));
            let mut c = 0usize;
            self.for_level_asc(p, |id| {
                f(id, p);
                c += 1;
                c < want
            });
            taken += want;
            bound = Some(p);
        }
    }

    /// The top `k` entries **of one shard** by score descending, ascending
    /// id within a score tie — [`ScoreIndex::top_k_desc`] restricted to
    /// shard `si`'s contiguous id range. This is the per-shard level walk
    /// the sharded coordination layer fans out: each shard's walk touches
    /// only its own treap, so all K walks can run independently and feed
    /// [`ScoreIndex::top_k_desc_merged`].
    pub fn shard_top_k_desc(&self, si: usize, k: usize, mut f: impl FnMut(usize, f64)) {
        let sh = &self.shards[si];
        let mut taken = 0usize;
        let mut bound: Option<f64> = None;
        while taken < k {
            let Some(p) = sh.max_key_lt(bound) else { break };
            sh.for_eq(p, &mut |id| {
                f(id, p);
                taken += 1;
                taken < k
            });
            bound = Some(p);
        }
    }

    /// The top `k` entries via the K-way merge of the per-shard walks:
    /// every shard contributes its own top-k ([`ScoreIndex::shard_top_k_desc`]),
    /// and the lists are merged on `(score desc, shard asc)` — shard index
    /// breaks score ties because shards are ascending id ranges, so the
    /// merged stream is **exactly** the global (score desc, id asc) order
    /// [`ScoreIndex::top_k_desc`] produces, element for element.
    pub fn top_k_desc_merged(&self, k: usize, mut f: impl FnMut(usize, f64)) {
        let mut lists: Vec<Vec<(usize, f64)>> = Vec::with_capacity(self.shards.len());
        for si in 0..self.shards.len() {
            let mut v = Vec::new();
            self.shard_top_k_desc(si, k, |id, s| v.push((id, s)));
            lists.push(v);
        }
        let mut cursors = vec![0usize; lists.len()];
        for _ in 0..k {
            let mut best: Option<usize> = None;
            for (si, list) in lists.iter().enumerate() {
                let Some(&(_, s)) = list.get(cursors[si]) else { continue };
                best = Some(match best {
                    None => si,
                    Some(b) => {
                        let bs = lists[b][cursors[b]].1;
                        // strict Greater keeps the earlier shard on ties —
                        // earlier shard == smaller ids == the flat order
                        if s.total_cmp(&bs) == std::cmp::Ordering::Greater {
                            si
                        } else {
                            b
                        }
                    }
                });
            }
            let Some(b) = best else { break };
            let (id, s) = lists[b][cursors[b]];
            cursors[b] += 1;
            f(id, s);
        }
    }

    /// Visit every entry in ascending `(score, id)` order (tests, rebuilds).
    pub fn for_each_asc(&self, mut f: impl FnMut(usize, f64)) {
        let mut bound: Option<f64> = None;
        while let Some(p) = self.min_score_gt(bound) {
            self.for_level_asc(p, |id| {
                f(id, p);
                true
            });
            bound = Some(p);
        }
    }

    /// Total score mass (shard partial sums combined in shard order).
    pub fn total_score(&self) -> f64 {
        self.shards.iter().map(|sh| sh.total_sum()).sum()
    }

    /// Draw one id with probability proportional to its score (requires
    /// non-negative scores; returns None on empty/zero-mass indices).
    /// Consumes exactly one `rng.f64()` draw.
    ///
    /// **Level walk**: both the total mass and the draw resolve against the
    /// global ascending `(score, id)` order — level by level, the mass of a
    /// level being `score * level_len` and the hit position within it
    /// `u / score` — so the drawn element is **byte-identical across shard
    /// layouts**, like every other query (the original shard-major prefix
    /// walk was only distribution-invariant). Zero, negative, and NaN
    /// scores carry no mass and are never drawn. O(L · S log n) for L
    /// distinct positive levels.
    pub fn weighted_sample(&self, rng: &mut Rng) -> Option<usize> {
        // one walk in ascending level order collects (score, len); the
        // total accumulates in that same order, so both the mass and the
        // draw below are pure functions of the member set
        let mut levels: Vec<(f64, usize)> = Vec::new();
        let mut total = 0.0f64;
        let mut bound: Option<f64> = None;
        while let Some(p) = self.min_score_gt(bound) {
            if p > 0.0 {
                let len = self.level_len(p);
                total += p * len as f64;
                levels.push((p, len));
            }
            bound = Some(p);
        }
        if !(total > 0.0) {
            return None;
        }
        let mut u = rng.f64() * total;
        for &(p, len) in &levels {
            let mass = p * len as f64;
            if u < mass {
                let i = ((u / p) as usize).min(len - 1);
                return Some(self.nth_in_level(p, i));
            }
            u -= mass;
        }
        // float round-off pushed u past the end: clamp to the last entry
        levels.last().map(|&(p, len)| self.nth_in_level(p, len - 1))
    }

    /// Global rank of `id` in `(score, id)` order, if present.
    pub fn rank_of(&self, id: usize) -> Option<usize> {
        let score = self.score(id)?;
        let mut rank = self.count_lt(score);
        // entries on the same level in shards before this one, plus
        // same-level smaller ids within this shard
        for (si, sh) in self.shards.iter().enumerate() {
            let in_level = sh.count_le(score) - sh.count_lt(score);
            if si < self.shard_of(id) {
                rank += in_level;
            } else {
                break;
            }
        }
        let mut smaller = 0usize;
        self.shards[self.shard_of(id)].for_eq(score, &mut |other| {
            if other < id {
                smaller += 1;
                true
            } else {
                false
            }
        });
        Some(rank + smaller)
    }

    /// All `(id, score)` entries in ascending `(score, id)` order (tests).
    pub fn to_sorted_vec(&self) -> Vec<(usize, f64)> {
        let mut out = Vec::with_capacity(self.len());
        for sh in &self.shards {
            sh.for_each(&mut |key, id| out.push((id, key)));
        }
        out.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn brute(entries: &[(usize, f64)]) -> Vec<(usize, f64)> {
        let mut v = entries.to_vec();
        v.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        v
    }

    #[test]
    fn insert_update_remove_roundtrip() {
        let mut idx = ScoreIndex::with_shards(100, 4);
        assert!(idx.is_empty());
        assert_eq!(idx.insert(7, 1.5), None);
        assert_eq!(idx.insert(7, 2.5), Some(1.5), "update returns old score");
        assert_eq!(idx.insert(3, 2.5), None);
        assert_eq!(idx.insert(99, 0.25), None);
        assert_eq!(idx.len(), 3);
        assert_eq!(idx.score(7), Some(2.5));
        assert_eq!(idx.remove(7), Some(2.5));
        assert_eq!(idx.remove(7), None);
        assert_eq!(idx.len(), 2);
        assert_eq!(idx.to_sorted_vec(), vec![(99, 0.25), (3, 2.5)]);
    }

    #[test]
    fn top_k_is_score_desc_id_asc() {
        let mut idx = ScoreIndex::with_shards(50, 3);
        for (id, s) in [(4usize, 5.0f64), (9, 7.0), (11, 5.0), (2, 5.0), (30, 1.0)] {
            idx.insert(id, s);
        }
        let mut got = Vec::new();
        idx.top_k_desc(4, |id, s| got.push((id, s)));
        assert_eq!(got, vec![(9, 7.0), (2, 5.0), (4, 5.0), (11, 5.0)]);
        // k beyond len caps
        let mut all = Vec::new();
        idx.top_k_desc(10, |id, _| all.push(id));
        assert_eq!(all, vec![9, 2, 4, 11, 30]);
    }

    #[test]
    fn level_queries_match_brute_force() {
        let mut idx = ScoreIndex::with_shards(64, 5);
        let entries: Vec<(usize, f64)> = (0..40).map(|i| (i, (i % 4) as f64)).collect();
        for &(id, s) in &entries {
            idx.insert(id, s);
        }
        for level in 0..4 {
            let p = level as f64;
            let want: Vec<usize> =
                entries.iter().filter(|e| e.1 == p).map(|e| e.0).collect();
            assert_eq!(idx.level_len(p), want.len());
            assert_eq!(idx.count_lt(p), entries.iter().filter(|e| e.1 < p).count());
            for (i, &id) in want.iter().enumerate() {
                assert_eq!(idx.nth_in_level(p, i), id, "level {level} pos {i}");
            }
            let mut seen = Vec::new();
            idx.for_level_asc(p, |id| {
                seen.push(id);
                true
            });
            assert_eq!(seen, want);
        }
        assert_eq!(idx.to_sorted_vec(), brute(&entries));
    }

    #[test]
    fn rank_of_matches_sorted_position() {
        let mut idx = ScoreIndex::with_shards(40, 4);
        let entries: Vec<(usize, f64)> =
            (0..30).map(|i| (i, ((i * 7) % 5) as f64)).collect();
        for &(id, s) in &entries {
            idx.insert(id, s);
        }
        let sorted = brute(&entries);
        for (rank, &(id, _)) in sorted.iter().enumerate() {
            assert_eq!(idx.rank_of(id), Some(rank), "id {id}");
        }
        assert_eq!(idx.rank_of(39), None);
    }

    #[test]
    fn non_finite_scores_are_ordered_not_panicking() {
        let mut idx = ScoreIndex::new(10);
        idx.insert(0, f64::NAN);
        idx.insert(1, f64::INFINITY);
        idx.insert(2, 1.0);
        idx.insert(3, f64::NEG_INFINITY);
        // total_cmp order: -inf < 1.0 < +inf < NaN
        let ids: Vec<usize> = idx.to_sorted_vec().iter().map(|e| e.0).collect();
        assert_eq!(ids, vec![3, 2, 1, 0]);
        let mut top = Vec::new();
        idx.top_k_desc(2, |id, _| top.push(id));
        assert_eq!(top, vec![0, 1]);
    }

    #[test]
    fn weighted_sample_follows_scores() {
        let mut idx = ScoreIndex::with_shards(16, 2);
        idx.insert(1, 1.0);
        idx.insert(5, 0.0);
        idx.insert(9, 3.0);
        let mut rng = Rng::new(11);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..4000 {
            let id = idx.weighted_sample(&mut rng).unwrap();
            *counts.entry(id).or_insert(0usize) += 1;
        }
        assert_eq!(counts.get(&5), None, "zero-score id must never be drawn");
        let ratio = counts[&9] as f64 / counts[&1] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio {ratio}");
        // empty / zero-mass
        let empty = ScoreIndex::new(4);
        assert_eq!(empty.weighted_sample(&mut rng), None);
    }

    #[test]
    fn weighted_sample_is_byte_identical_across_shard_layouts() {
        // the level-walk draw must land on the same id for the same RNG
        // state regardless of how ids are sharded (ROADMAP follow-up)
        let entries: Vec<(usize, f64)> =
            (0..150).map(|i| (i, ((i * 11) % 6) as f64 * 0.5)).collect();
        let build = |shards: usize| {
            let mut idx = ScoreIndex::with_shards(150, shards);
            for &(id, s) in &entries {
                idx.insert(id, s);
            }
            idx
        };
        let a = build(1);
        for shards in [2usize, 5, 11] {
            let b = build(shards);
            for seed in 0..40u64 {
                let mut ra = Rng::new(seed);
                let mut rb = Rng::new(seed);
                assert_eq!(
                    a.weighted_sample(&mut ra),
                    b.weighted_sample(&mut rb),
                    "{shards} shards, seed {seed}: draw diverged"
                );
                // exactly one RNG draw consumed on both sides
                assert_eq!(ra.next_u64(), rb.next_u64(), "{shards} shards: rng diverged");
            }
        }
    }

    #[test]
    fn shard_count_never_changes_results() {
        let entries: Vec<(usize, f64)> =
            (0..200).map(|i| (i, ((i * 13) % 7) as f64 * 0.25)).collect();
        let build = |shards: usize| {
            let mut idx = ScoreIndex::with_shards(200, shards);
            for &(id, s) in &entries {
                idx.insert(id, s);
            }
            idx
        };
        let a = build(1);
        for shards in [2usize, 8, 13] {
            let b = build(shards);
            assert_eq!(a.to_sorted_vec(), b.to_sorted_vec(), "{shards} shards");
            let mut ta = Vec::new();
            let mut tb = Vec::new();
            a.top_k_desc(17, |id, s| ta.push((id, s)));
            b.top_k_desc(17, |id, s| tb.push((id, s)));
            assert_eq!(ta, tb, "{shards} shards: top-k diverged");
            for level in 0..7 {
                let p = level as f64 * 0.25;
                assert_eq!(a.count_lt(p), b.count_lt(p), "{shards} shards");
                assert_eq!(a.level_len(p), b.level_len(p), "{shards} shards");
            }
        }
    }

    #[test]
    fn merged_top_k_equals_flat_top_k() {
        // the K-way merge of per-shard walks must reproduce the flat
        // global walk element-for-element, for any shard layout and k
        let entries: Vec<(usize, f64)> =
            (0..180).map(|i| (i, ((i * 17) % 9) as f64 * 0.5)).collect();
        for shards in [1usize, 2, 5, 11, 64] {
            let mut idx = ScoreIndex::with_shards(180, shards);
            for &(id, s) in &entries {
                idx.insert(id, s);
            }
            for k in [0usize, 1, 7, 40, 200] {
                let mut flat = Vec::new();
                let mut merged = Vec::new();
                idx.top_k_desc(k, |id, s| flat.push((id, s)));
                idx.top_k_desc_merged(k, |id, s| merged.push((id, s)));
                assert_eq!(flat, merged, "{shards} shards, k={k}");
            }
        }
        // per-shard walks are the flat walk filtered to the shard's range
        let idx = {
            let mut idx = ScoreIndex::with_shards(60, 4);
            for &(id, s) in entries.iter().take(60) {
                idx.insert(id, s);
            }
            idx
        };
        let mut all = Vec::new();
        idx.top_k_desc(60, |id, s| all.push((id, s)));
        for si in 0..idx.num_shards() {
            let (lo, hi) = (si * 15, (si + 1) * 15);
            let want: Vec<(usize, f64)> =
                all.iter().copied().filter(|&(id, _)| id >= lo && id < hi).collect();
            let mut got = Vec::new();
            idx.shard_top_k_desc(si, 60, |id, s| got.push((id, s)));
            assert_eq!(got, want, "shard {si}");
        }
    }

    #[test]
    fn tiny_and_empty_capacities() {
        let mut idx = ScoreIndex::with_shards(1, 8);
        assert_eq!(idx.capacity(), 1);
        idx.insert(0, 4.0);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.min_score_gt(None), Some(4.0));
        let z = ScoreIndex::new(0);
        assert_eq!(z.len(), 0);
        assert_eq!(z.max_score_lt(None), None);
    }
}
