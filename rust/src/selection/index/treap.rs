//! One shard of the samplable score index: an arena-backed treap ordered by
//! `(score, id)` under `f64::total_cmp`, with subtree counts (order
//! statistics) and subtree score sums (score-mass totals; the weighted
//! sampler itself walks levels of the global order, see
//! [`super::ScoreIndex::weighted_sample`]).
//!
//! Node priorities are derived from the learner id alone (splitmix64), so
//! the tree *shape* — and therefore every query result — is a pure function
//! of the member set, never of the insertion/removal order. That is what
//! lets the incremental maintenance paths (hook-driven deltas, lazy
//! re-keying, full rebuilds) all land on identical structures.

use crate::util::rng::splitmix64;

const NIL: usize = usize::MAX;

struct Node {
    key: f64,
    id: usize,
    prio: u64,
    left: usize,
    right: usize,
    /// Subtree entry count.
    size: usize,
    /// Subtree score sum (for weighted sampling).
    sum: f64,
}

/// Strict `(key, id)` order under `total_cmp` (a total order, so non-finite
/// scores cannot panic a comparator — the seed's `partial_cmp().unwrap()`
/// hazard).
#[inline]
fn before(a_key: f64, a_id: usize, b_key: f64, b_id: usize) -> bool {
    match a_key.total_cmp(&b_key) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => a_id < b_id,
    }
}

pub(super) struct Treap {
    nodes: Vec<Node>,
    free: Vec<usize>,
    root: usize,
}

impl Treap {
    pub(super) fn new() -> Treap {
        Treap { nodes: Vec::new(), free: Vec::new(), root: NIL }
    }

    pub(super) fn len(&self) -> usize {
        self.size(self.root)
    }

    pub(super) fn clear(&mut self) {
        self.nodes.clear();
        self.free.clear();
        self.root = NIL;
    }

    #[inline]
    fn size(&self, t: usize) -> usize {
        if t == NIL {
            0
        } else {
            self.nodes[t].size
        }
    }

    #[inline]
    fn sum(&self, t: usize) -> f64 {
        if t == NIL {
            0.0
        } else {
            self.nodes[t].sum
        }
    }

    fn pull(&mut self, t: usize) {
        let (l, r) = (self.nodes[t].left, self.nodes[t].right);
        self.nodes[t].size = 1 + self.size(l) + self.size(r);
        self.nodes[t].sum = self.nodes[t].key + self.sum(l) + self.sum(r);
    }

    fn alloc(&mut self, key: f64, id: usize) -> usize {
        let node = Node {
            key,
            id,
            prio: splitmix64(&mut (id as u64 ^ 0x5EED_5C0E_1D11_D0E5)),
            left: NIL,
            right: NIL,
            size: 1,
            sum: key,
        };
        match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Split into (entries before `(key, id)`, the rest).
    fn split(&mut self, t: usize, key: f64, id: usize) -> (usize, usize) {
        if t == NIL {
            return (NIL, NIL);
        }
        if before(self.nodes[t].key, self.nodes[t].id, key, id) {
            let r = self.nodes[t].right;
            let (a, b) = self.split(r, key, id);
            self.nodes[t].right = a;
            self.pull(t);
            (t, b)
        } else {
            let l = self.nodes[t].left;
            let (a, b) = self.split(l, key, id);
            self.nodes[t].left = b;
            self.pull(t);
            (a, t)
        }
    }

    fn merge(&mut self, a: usize, b: usize) -> usize {
        if a == NIL {
            return b;
        }
        if b == NIL {
            return a;
        }
        if self.nodes[a].prio >= self.nodes[b].prio {
            let r = self.nodes[a].right;
            let m = self.merge(r, b);
            self.nodes[a].right = m;
            self.pull(a);
            a
        } else {
            let l = self.nodes[b].left;
            let m = self.merge(a, l);
            self.nodes[b].left = m;
            self.pull(b);
            b
        }
    }

    /// Insert `(key, id)`; the caller guarantees `id` is not present.
    pub(super) fn insert(&mut self, key: f64, id: usize) {
        let n = self.alloc(key, id);
        let root = self.root;
        let (a, b) = self.split(root, key, id);
        let left = self.merge(a, n);
        self.root = self.merge(left, b);
    }

    /// Remove `(key, id)`; the caller guarantees it is present.
    pub(super) fn remove(&mut self, key: f64, id: usize) {
        let root = self.root;
        let (a, rest) = self.split(root, key, id);
        // `(key, id + 1)` is strictly after `(key, id)` and strictly before
        // any other entry that follows it, so this isolates exactly one node
        let (mid, b) = self.split(rest, key, id + 1);
        debug_assert!(mid != NIL && self.nodes[mid].id == id, "remove of absent entry");
        if mid != NIL {
            self.free.push(mid);
        }
        self.root = self.merge(a, b);
    }

    /// Number of entries with key strictly less than `key` (total order).
    pub(super) fn count_lt(&self, key: f64) -> usize {
        let mut t = self.root;
        let mut acc = 0usize;
        while t != NIL {
            if self.nodes[t].key.total_cmp(&key) == std::cmp::Ordering::Less {
                acc += 1 + self.size(self.nodes[t].left);
                t = self.nodes[t].right;
            } else {
                t = self.nodes[t].left;
            }
        }
        acc
    }

    /// Number of entries with key less than or equal to `key`.
    pub(super) fn count_le(&self, key: f64) -> usize {
        let mut t = self.root;
        let mut acc = 0usize;
        while t != NIL {
            if self.nodes[t].key.total_cmp(&key) != std::cmp::Ordering::Greater {
                acc += 1 + self.size(self.nodes[t].left);
                t = self.nodes[t].right;
            } else {
                t = self.nodes[t].left;
            }
        }
        acc
    }

    /// The `rank`-th entry (0-based) in `(key, id)` order: `(key, id)`.
    pub(super) fn select(&self, rank: usize) -> (f64, usize) {
        debug_assert!(rank < self.len());
        let mut t = self.root;
        let mut rem = rank;
        loop {
            let ls = self.size(self.nodes[t].left);
            if rem < ls {
                t = self.nodes[t].left;
            } else if rem == ls {
                return (self.nodes[t].key, self.nodes[t].id);
            } else {
                rem -= ls + 1;
                t = self.nodes[t].right;
            }
        }
    }

    /// Smallest key strictly greater than `bound` (`None` bound = smallest
    /// key overall).
    pub(super) fn min_key_gt(&self, bound: Option<f64>) -> Option<f64> {
        let mut t = self.root;
        let mut best: Option<f64> = None;
        while t != NIL {
            let k = self.nodes[t].key;
            let above = match bound {
                None => true,
                Some(b) => k.total_cmp(&b) == std::cmp::Ordering::Greater,
            };
            if above {
                best = Some(k);
                t = self.nodes[t].left;
            } else {
                t = self.nodes[t].right;
            }
        }
        best
    }

    /// Largest key strictly less than `bound` (`None` bound = largest key).
    pub(super) fn max_key_lt(&self, bound: Option<f64>) -> Option<f64> {
        let mut t = self.root;
        let mut best: Option<f64> = None;
        while t != NIL {
            let k = self.nodes[t].key;
            let below = match bound {
                None => true,
                Some(b) => k.total_cmp(&b) == std::cmp::Ordering::Less,
            };
            if below {
                best = Some(k);
                t = self.nodes[t].right;
            } else {
                t = self.nodes[t].left;
            }
        }
        best
    }

    /// Total score mass of this shard.
    pub(super) fn total_sum(&self) -> f64 {
        self.sum(self.root)
    }

    /// Visit the ids of every entry with key exactly `key` (total-order
    /// equality), in ascending id order, while `f` returns true.
    pub(super) fn for_eq(&self, key: f64, f: &mut dyn FnMut(usize) -> bool) {
        self.for_eq_node(self.root, key, f);
    }

    fn for_eq_node(&self, t: usize, key: f64, f: &mut dyn FnMut(usize) -> bool) -> bool {
        if t == NIL {
            return true;
        }
        match self.nodes[t].key.total_cmp(&key) {
            std::cmp::Ordering::Less => self.for_eq_node(self.nodes[t].right, key, f),
            std::cmp::Ordering::Greater => self.for_eq_node(self.nodes[t].left, key, f),
            std::cmp::Ordering::Equal => {
                if !self.for_eq_node(self.nodes[t].left, key, f) {
                    return false;
                }
                if !f(self.nodes[t].id) {
                    return false;
                }
                self.for_eq_node(self.nodes[t].right, key, f)
            }
        }
    }

    /// In-order `(key, id)` visit of the whole shard (tests + rebuilds).
    pub(super) fn for_each(&self, f: &mut dyn FnMut(f64, usize)) {
        self.for_each_node(self.root, f);
    }

    fn for_each_node(&self, t: usize, f: &mut dyn FnMut(f64, usize)) {
        if t == NIL {
            return;
        }
        self.for_each_node(self.nodes[t].left, f);
        f(self.nodes[t].key, self.nodes[t].id);
        self.for_each_node(self.nodes[t].right, f);
    }
}
