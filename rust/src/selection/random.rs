//! Uniform random participant selection — the FedAvg / Google-scale default
//! (Bonawitz et al.) and the paper's "Random" baseline.

use crate::util::rng::Rng;

use super::{SelectPool, SelectionCtx, Selector};

pub struct RandomSelector;

impl Selector for RandomSelector {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize> {
        let k = ctx.target.min(ctx.candidates.len());
        ctx.rng
            .choose_k(ctx.candidates.len(), k)
            .into_iter()
            .map(|i| ctx.candidates[i].id)
            .collect()
    }

    /// Uniform sampling needs no probe answers: draw ranks straight from
    /// the candidate set. `CandidateSet::sample_k` replays `Rng::choose_k`
    /// over the ascending-id member list exactly, so this is bit-identical
    /// to [`RandomSelector::select`] on the materialized candidates — the
    /// engines' O(k log n) fast path at million-learner populations.
    fn select_from(
        &mut self,
        pool: &SelectPool,
        _round: usize,
        _now: f64,
        target: usize,
        rng: &mut Rng,
    ) -> Option<Vec<usize>> {
        Some(pool.set.sample_k(rng, target))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::mk_candidates;

    #[test]
    fn sampled_path_bit_identical_to_materialized_select() {
        // the fast path's contract: same RNG draws, same picked ids as
        // select() over the ascending-id candidate list
        let ids: Vec<usize> = (0..200).filter(|i| i % 3 != 0).collect();
        let mut set = crate::population::CandidateSet::new(200);
        for &id in &ids {
            set.insert(id);
        }
        let candidates: Vec<crate::selection::Candidate> = ids
            .iter()
            .map(|&id| crate::selection::Candidate {
                id,
                avail_prob: 0.5,
                expected_duration: 10.0,
            })
            .collect();
        let probes = crate::selection::MockProbes::from_candidates(&candidates);
        let pool = SelectPool { set: &set, probes: &probes, mu: 100.0 };
        for seed in 0..10u64 {
            let mut s = RandomSelector;
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let fast = s.select_from(&pool, 0, 0.0, 9, &mut r1).unwrap();
            let mut ctx = SelectionCtx {
                round: 0,
                now: 0.0,
                target: 9,
                candidates: &candidates,
                rng: &mut r2,
            };
            let slow = s.select(&mut ctx);
            assert_eq!(fast, slow, "seed {seed}");
            assert_eq!(r1.next_u64(), r2.next_u64(), "seed {seed}: rng state diverged");
        }
    }

    #[test]
    fn covers_population_over_rounds() {
        let candidates = mk_candidates(30);
        let mut s = RandomSelector;
        let mut rng = Rng::new(5);
        let mut seen = std::collections::HashSet::new();
        for round in 0..60 {
            let mut ctx = SelectionCtx {
                round,
                now: 0.0,
                target: 5,
                candidates: &candidates,
                rng: &mut rng,
            };
            seen.extend(s.select(&mut ctx));
        }
        assert!(seen.len() >= 28, "random should cover population, saw {}", seen.len());
    }

    #[test]
    fn unbiased_wrt_avail_prob() {
        // random must NOT correlate with availability (that's priority's job)
        let candidates = mk_candidates(100);
        let mut s = RandomSelector;
        let mut rng = Rng::new(6);
        let mut low = 0usize;
        let mut total = 0usize;
        for round in 0..200 {
            let mut ctx = SelectionCtx {
                round,
                now: 0.0,
                target: 10,
                candidates: &candidates,
                rng: &mut rng,
            };
            for id in s.select(&mut ctx) {
                total += 1;
                if candidates[id].avail_prob < 0.5 {
                    low += 1;
                }
            }
        }
        let frac = low as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.06, "low-avail fraction {frac}");
    }
}
