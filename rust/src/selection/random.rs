//! Uniform random participant selection — the FedAvg / Google-scale default
//! (Bonawitz et al.) and the paper's "Random" baseline.

use super::{SelectionCtx, Selector};

pub struct RandomSelector;

impl Selector for RandomSelector {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize> {
        let k = ctx.target.min(ctx.candidates.len());
        ctx.rng
            .choose_k(ctx.candidates.len(), k)
            .into_iter()
            .map(|i| ctx.candidates[i].id)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::mk_candidates;
    use crate::util::rng::Rng;

    #[test]
    fn covers_population_over_rounds() {
        let candidates = mk_candidates(30);
        let mut s = RandomSelector;
        let mut rng = Rng::new(5);
        let mut seen = std::collections::HashSet::new();
        for round in 0..60 {
            let mut ctx = SelectionCtx {
                round,
                now: 0.0,
                target: 5,
                candidates: &candidates,
                rng: &mut rng,
            };
            seen.extend(s.select(&mut ctx));
        }
        assert!(seen.len() >= 28, "random should cover population, saw {}", seen.len());
    }

    #[test]
    fn unbiased_wrt_avail_prob() {
        // random must NOT correlate with availability (that's priority's job)
        let candidates = mk_candidates(100);
        let mut s = RandomSelector;
        let mut rng = Rng::new(6);
        let mut low = 0usize;
        let mut total = 0usize;
        for round in 0..200 {
            let mut ctx = SelectionCtx {
                round,
                now: 0.0,
                target: 10,
                candidates: &candidates,
                rng: &mut rng,
            };
            for id in s.select(&mut ctx) {
                total += 1;
                if candidates[id].avail_prob < 0.5 {
                    low += 1;
                }
            }
        }
        let frac = low as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.06, "low-avail fraction {frac}");
    }
}
