//! Participant-selection strategies (paper §2.2, §4.1):
//!
//! * [`random::RandomSelector`] — uniform sampling (FedAvg default),
//! * [`oort::OortSelector`] — utility-guided selection with pacer
//!   (Lai et al., OSDI'21), the paper's main baseline,
//! * [`priority::PrioritySelector`] — RELAY's IPS (Algorithm 1):
//!   least-available-first, boundary-level ties randomly sampled,
//! * [`safa::SafaSelector`] — SAFA's post-training selection (select all),
//! * [`apt`] — RELAY's Adaptive Participant Target (N_t adjustment),
//! * [`index`] — the samplable utility structures (sharded
//!   ordered-statistic score trees) behind the indexed `select_from`
//!   fast paths, fed by the `on_eligible`/`on_ineligible` hooks.

pub mod apt;
pub mod index;
pub mod oort;
pub mod priority;
pub mod random;
pub mod safa;

use crate::population::CandidateSet;
use crate::util::rng::Rng;

/// Identity of the piecewise-constant validity window of the availability
/// probe at some `(now, mu)`: **equal sigs guarantee bitwise-equal
/// `avail_prob` answers for every learner**. Under `AllAvail` the probe is
/// the constant 1.0; under `DynAvail` it is a mean of the (static, trained
/// at first touch) seasonal forecaster's hour-of-week bins, so the answer
/// only moves when a slot midpoint crosses an hour bin — the "finite bucket
/// values" that make per-time-bucket probability trees reusable across many
/// selections instead of re-probing the pool each time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SlotSig {
    /// Probe is a constant (AllAvail): one validity window forever.
    Const,
    /// The hour-of-week bins the slot's probe midpoints land in.
    Bins(Vec<u16>),
}

/// On-demand per-learner facts an indexed selector may query during
/// `select_from` — the same values `Candidate` materialization would have
/// carried, served lazily so a selection only pays for the ids it touches.
pub trait ProbeSource {
    /// The learner's probe answer P(available during [now+mu, now+2mu]) —
    /// bitwise-identical to the `Candidate::avail_prob` the materialized
    /// path produces.
    fn avail_prob(&self, id: usize, now: f64, mu: f64) -> f64;

    /// Profile-based expected task duration — `Candidate::expected_duration`.
    fn expected_duration(&self, id: usize) -> f64;

    /// Validity signature of `avail_prob` at `(now, mu)` (see [`SlotSig`]).
    fn slot_sig(&self, now: f64, mu: f64) -> SlotSig;
}

/// What an indexed selector draws from: the incrementally-maintained
/// eligible-id set plus lazy probe access. `mu` is the server's current
/// round-duration estimate (the probe slot is [now+mu, now+2mu]).
pub struct SelectPool<'a> {
    pub set: &'a CandidateSet,
    pub probes: &'a dyn ProbeSource,
    pub mu: f64,
}

/// A checked-in learner visible to the selector this round.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub id: usize,
    /// Learner-reported P(available during the next round's slot [mu, 2mu]).
    /// 1.0 under AllAvail — which makes IPS degenerate to Random, exactly as
    /// the paper notes (§5.2 "Stale Aggregation").
    pub avail_prob: f64,
    /// Expected task duration for this learner (profile-based estimate);
    /// Oort's system-utility term uses this.
    pub expected_duration: f64,
}

/// Everything a selector sees when picking participants.
pub struct SelectionCtx<'a> {
    pub round: usize,
    pub now: f64,
    /// Number of participants to pick (already APT/overcommit adjusted).
    pub target: usize,
    pub candidates: &'a [Candidate],
    pub rng: &'a mut Rng,
}

/// Post-round feedback a selector may learn from (Oort does).
pub struct RoundFeedback<'a> {
    pub round: usize,
    /// (learner, statistical utility, task duration) for participants whose
    /// updates were received this round.
    pub completed: &'a [(usize, f64, f64)],
    /// Learners that were selected but produced nothing in time.
    pub missed: &'a [usize],
    pub round_duration: f64,
}

pub trait Selector: Send {
    fn name(&self) -> &'static str;

    /// Pick up to `ctx.target` participants from `ctx.candidates`.
    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize>;

    /// Population-scale fast path: draw up to `target` participants
    /// directly from the incrementally-maintained eligible pool without
    /// materializing `Vec<Candidate>`. Selectors without an indexed
    /// implementation return `None` and the engine falls back to
    /// [`Selector::select`] over the materialized eligible list.
    ///
    /// The contract that lets engines switch paths freely: a `Some` result
    /// must be **element-for-element identical** to what `select` would
    /// return over the ascending-id candidate list for the same pool —
    /// same RNG draws, same ids, same order, same selector-state updates.
    /// When the pool is empty the engines skip `select` entirely, so an
    /// indexed path must return `Some(vec![])` *without* touching the RNG
    /// or per-call state (e.g. Oort's epsilon decay) in that case.
    /// `tests/selection_index_props.rs` pins the equivalence per selector;
    /// `tests/kernel_equivalence.rs` pins it end-to-end against the frozen
    /// reference engine.
    fn select_from(
        &mut self,
        _pool: &SelectPool,
        _round: usize,
        _now: f64,
        _target: usize,
        _rng: &mut Rng,
    ) -> Option<Vec<usize>> {
        None
    }

    /// Index-maintenance hook: `id` entered the eligible pool. Wired from
    /// the population's eligible-set insert transitions (availability
    /// flips, cooldown/busy expiry, task completion). Stateless selectors
    /// ignore it; indexed selectors log the delta and fold it into their
    /// structures at the next `select_from`.
    fn on_eligible(&mut self, _id: usize) {}

    /// Index-maintenance hook: `id` left the eligible pool (went busy,
    /// entered cooldown, or lost availability).
    fn on_ineligible(&mut self, _id: usize) {}

    /// Observe the round outcome (default: stateless).
    fn feedback(&mut self, _fb: &RoundFeedback) {}

    /// Async-regime hook: one update arrived outside the round cadence.
    /// `round` is the server's merge-version counter and `completed` is the
    /// usual (learner, statistical utility, task duration) triple. Defaults
    /// to a single-entry [`Selector::feedback`], so stateful selectors
    /// (Oort) learn per arrival; note this also ticks Oort's pacer window
    /// per arrival instead of per round — in async mode the window is
    /// measured in arrivals.
    fn on_arrival(&mut self, round: usize, completed: (usize, f64, f64), round_duration: f64) {
        self.feedback(&RoundFeedback {
            round,
            completed: &[completed],
            missed: &[],
            round_duration,
        });
    }

    /// Async-regime hook: a selected learner departed (dropout) without
    /// delivering. Defaults to a single-entry missed [`Selector::feedback`].
    fn on_departure(&mut self, round: usize, learner: usize, round_duration: f64) {
        self.feedback(&RoundFeedback {
            round,
            completed: &[],
            missed: &[learner],
            round_duration,
        });
    }
}

/// Construct a selector by name ("random" | "oort" | "priority" | "safa").
pub fn by_name(name: &str) -> Option<Box<dyn Selector>> {
    match name {
        "random" => Some(Box::new(random::RandomSelector)),
        "oort" => Some(Box::new(oort::OortSelector::default())),
        "priority" => Some(Box::new(priority::PrioritySelector::default())),
        "safa" => Some(Box::new(safa::SafaSelector)),
        _ => None,
    }
}

#[cfg(test)]
pub(crate) fn mk_candidates(n: usize) -> Vec<Candidate> {
    (0..n)
        .map(|i| Candidate {
            id: i,
            avail_prob: (i as f64) / (n as f64),
            expected_duration: 10.0 + i as f64,
        })
        .collect()
}

/// Test-only [`ProbeSource`] answering from fixed per-id tables, so selector
/// unit/property tests can drive `select_from` without a `Population`.
#[cfg(test)]
pub(crate) struct MockProbes {
    pub probs: std::collections::HashMap<usize, f64>,
    pub eds: std::collections::HashMap<usize, f64>,
    pub sig: SlotSig,
}

#[cfg(test)]
impl MockProbes {
    pub(crate) fn from_candidates(cands: &[Candidate]) -> MockProbes {
        MockProbes {
            probs: cands.iter().map(|c| (c.id, c.avail_prob)).collect(),
            eds: cands.iter().map(|c| (c.id, c.expected_duration)).collect(),
            sig: SlotSig::Const,
        }
    }
}

#[cfg(test)]
impl ProbeSource for MockProbes {
    fn avail_prob(&self, id: usize, _now: f64, _mu: f64) -> f64 {
        self.probs[&id]
    }

    fn expected_duration(&self, id: usize) -> f64 {
        self.eds[&id]
    }

    fn slot_sig(&self, _now: f64, _mu: f64) -> SlotSig {
        self.sig.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_constructs_all() {
        for n in ["random", "oort", "priority", "safa"] {
            assert_eq!(by_name(n).unwrap().name(), n);
        }
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn all_selectors_respect_target_and_candidates() {
        let candidates = mk_candidates(20);
        for n in ["random", "oort", "priority", "safa"] {
            let mut s = by_name(n).unwrap();
            let mut rng = Rng::new(1);
            let mut ctx = SelectionCtx {
                round: 0,
                now: 0.0,
                target: 5,
                candidates: &candidates,
                rng: &mut rng,
            };
            let picked = s.select(&mut ctx);
            // SAFA is select-all by design: everyone trains, the round's
            // reporting fraction does the cutting — so it ignores `target`
            let want = if n == "safa" { 20 } else { 5 };
            assert_eq!(picked.len(), want, "{n}");
            let mut d = picked.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), want, "{n}: duplicates");
            assert!(picked.iter().all(|&p| p < 20), "{n}: unknown id");
        }
    }

    #[test]
    fn selectors_handle_fewer_candidates_than_target() {
        let candidates = mk_candidates(3);
        for n in ["random", "oort", "priority", "safa"] {
            let mut s = by_name(n).unwrap();
            let mut rng = Rng::new(2);
            let mut ctx = SelectionCtx {
                round: 1,
                now: 0.0,
                target: 10,
                candidates: &candidates,
                rng: &mut rng,
            };
            let picked = s.select(&mut ctx);
            assert_eq!(picked.len(), 3, "{n} should take all 3");
        }
    }

    #[test]
    fn selectors_fill_target_around_cooldowns() {
        // Engine-style cooldown interaction: learners on cooldown never
        // appear among the candidates (coordinator::checked_in filters
        // them), and a selector must fill its target from whoever is left
        // rather than stall or resurrect a cooling id.
        for name in ["random", "oort", "priority"] {
            let mut s = by_name(name).unwrap();
            let mut rng = Rng::new(7);
            let mut cooldown_until = vec![0usize; 12];
            let cooldown_rounds = 2;
            for round in 0..8 {
                let candidates: Vec<Candidate> = (0..12)
                    .filter(|&id| cooldown_until[id] <= round)
                    .map(|id| Candidate {
                        id,
                        avail_prob: 0.5,
                        expected_duration: 15.0,
                    })
                    .collect();
                let mut ctx = SelectionCtx {
                    round,
                    now: 0.0,
                    target: 4,
                    candidates: &candidates,
                    rng: &mut rng,
                };
                let picked = s.select(&mut ctx);
                assert_eq!(
                    picked.len(),
                    4usize.min(candidates.len()),
                    "{name}: short pick in round {round}"
                );
                for &id in &picked {
                    assert!(
                        cooldown_until[id] <= round,
                        "{name}: picked cooling learner {id} in round {round}"
                    );
                    cooldown_until[id] = round + 1 + cooldown_rounds;
                }
            }
        }
    }

    #[test]
    fn arrival_and_departure_hooks_route_through_feedback() {
        // a recording selector proves the default hook implementations fold
        // per-arrival/per-departure events into the feedback channel
        struct Recorder {
            completed: Vec<(usize, f64, f64)>,
            missed: Vec<usize>,
        }
        impl Selector for Recorder {
            fn name(&self) -> &'static str {
                "recorder"
            }
            fn select(&mut self, _ctx: &mut SelectionCtx) -> Vec<usize> {
                Vec::new()
            }
            fn feedback(&mut self, fb: &RoundFeedback) {
                self.completed.extend_from_slice(fb.completed);
                self.missed.extend_from_slice(fb.missed);
            }
        }
        let mut s = Recorder { completed: Vec::new(), missed: Vec::new() };
        s.on_arrival(3, (7, 42.0, 10.5), 60.0);
        s.on_arrival(4, (9, 1.0, 2.0), 60.0);
        s.on_departure(4, 11, 60.0);
        assert_eq!(s.completed, vec![(7, 42.0, 10.5), (9, 1.0, 2.0)]);
        assert_eq!(s.missed, vec![11]);
    }

    #[test]
    fn selectors_handle_zero_candidates() {
        for n in ["random", "oort", "priority", "safa"] {
            let mut s = by_name(n).unwrap();
            let mut rng = Rng::new(3);
            let mut ctx = SelectionCtx {
                round: 1,
                now: 0.0,
                target: 10,
                candidates: &[],
                rng: &mut rng,
            };
            assert!(s.select(&mut ctx).is_empty(), "{n}");
        }
    }
}
