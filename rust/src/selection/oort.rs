//! Oort participant selection (Lai et al., OSDI'21) — the paper's main
//! baseline (§2.2). Reimplemented from the Oort paper's description:
//!
//! * **statistical utility** of learner i: |B_i| * sqrt(mean of squared
//!   per-step training losses) from its latest participation;
//! * **system utility**: (T / t_i)^alpha penalty when the learner's task
//!   duration t_i exceeds the developer-preferred round duration T;
//! * **exploration/exploitation**: epsilon-greedy over never-explored
//!   learners, with epsilon decaying per round;
//! * **pacer**: when accumulated exploited utility stops improving, relax T
//!   by a step (trading longer rounds for unexplored/slow learners).
//!
//! At population scale the selector maintains **incremental indices**
//! instead of ranking a materialized candidate list each round: explored
//! eligible learners live in a [`ScoreIndex`] utility tree (re-scored on
//! feedback, lazily re-keyed when the pacer moves the preferred duration),
//! never-explored eligible learners in a [`CandidateSet`] that serves the
//! epsilon share via `sample_k`. Eligibility deltas arrive through the
//! `on_eligible`/`on_ineligible` hooks; `select_from` folds them in and
//! answers in O(k log n) — independent of the total population — while
//! staying element-for-element identical (same RNG draws) to
//! [`OortSelector::select`] over the ascending-id candidate list.

use std::collections::HashMap;

use crate::population::CandidateSet;
use crate::util::rng::Rng;

use super::index::ScoreIndex;
use super::{RoundFeedback, SelectPool, SelectionCtx, Selector};

#[derive(Clone, Copy, Debug)]
pub struct OortConfig {
    pub epsilon0: f64,
    pub epsilon_decay: f64,
    pub epsilon_min: f64,
    /// System-utility exponent (Oort's alpha).
    pub alpha: f64,
    /// Initial preferred round duration T (seconds).
    pub preferred_duration: f64,
    /// Pacer window W (rounds) and step (seconds).
    pub pacer_window: usize,
    pub pacer_step: f64,
}

impl Default for OortConfig {
    fn default() -> Self {
        OortConfig {
            epsilon0: 0.9,
            epsilon_decay: 0.98,
            epsilon_min: 0.2,
            alpha: 2.0,
            preferred_duration: 60.0,
            pacer_window: 20,
            pacer_step: 10.0,
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct LearnerStats {
    stat_util: f64,
    duration: f64,
    last_round: usize,
}

/// The incrementally-maintained eligible-pool view: explored learners in a
/// utility tree, never-explored learners in a samplable id set. Rebuilt
/// from the pool on first use; hook/feedback deltas keep it exact after.
struct OortIndex {
    unexplored: CandidateSet,
    tree: ScoreIndex,
    /// Eligibility deltas logged by the hooks since the last selection.
    pending: Vec<(usize, bool)>,
    /// Learners whose stats changed (feedback) since the last selection.
    dirty: Vec<usize>,
    /// The pacer moved `preferred_duration`: every tree score is stale.
    rekey_all: bool,
}

pub struct OortSelector {
    cfg: OortConfig,
    epsilon: f64,
    explored: HashMap<usize, LearnerStats>,
    /// Exploited utility accumulated in the current/previous pacer windows.
    window_util: f64,
    prev_window_util: f64,
    rounds_in_window: usize,
    preferred_duration: f64,
    index: Option<OortIndex>,
}

impl Default for OortSelector {
    fn default() -> Self {
        Self::new(OortConfig::default())
    }
}

impl OortSelector {
    pub fn new(cfg: OortConfig) -> Self {
        OortSelector {
            epsilon: cfg.epsilon0,
            preferred_duration: cfg.preferred_duration,
            cfg,
            explored: HashMap::new(),
            window_util: 0.0,
            prev_window_util: 0.0,
            rounds_in_window: 0,
            index: None,
        }
    }

    /// Combined utility of an explored learner.
    fn utility(&self, id: usize, expected_duration: f64) -> f64 {
        let s = &self.explored[&id];
        let stat = s.stat_util;
        let dur = if s.duration > 0.0 { s.duration } else { expected_duration };
        let sys = if dur > self.preferred_duration {
            (self.preferred_duration / dur).powf(self.cfg.alpha)
        } else {
            1.0
        };
        stat * sys
    }

    pub fn current_preferred_duration(&self) -> f64 {
        self.preferred_duration
    }

    /// Rebuild the index from scratch over the pool's current membership.
    fn rebuilt_index(&self, pool: &SelectPool) -> OortIndex {
        let mut ix = OortIndex {
            unexplored: CandidateSet::with_shards(pool.set.capacity(), pool.set.num_shards()),
            tree: ScoreIndex::with_shards(pool.set.capacity(), pool.set.num_shards()),
            pending: Vec::new(),
            dirty: Vec::new(),
            rekey_all: false,
        };
        for id in pool.set.iter() {
            if self.explored.contains_key(&id) {
                let u = self.utility(id, pool.probes.expected_duration(id));
                ix.tree.insert(id, u);
            } else {
                ix.unexplored.insert(id);
            }
        }
        ix
    }

    /// Bring the index in line with the pool: full rebuild on first use (or
    /// pool change), otherwise fold in eligibility deltas, stat re-scores,
    /// and the lazy pacer re-key.
    fn sync_index(&mut self, pool: &SelectPool) {
        let rebuild = match &self.index {
            None => true,
            Some(ix) => ix.unexplored.capacity() != pool.set.capacity(),
        };
        if rebuild {
            self.index = Some(self.rebuilt_index(pool));
            return;
        }
        let mut ix = self.index.take().expect("checked above");
        for (id, elig) in std::mem::take(&mut ix.pending) {
            if elig {
                if self.explored.contains_key(&id) {
                    let u = self.utility(id, pool.probes.expected_duration(id));
                    ix.tree.insert(id, u);
                } else {
                    ix.unexplored.insert(id);
                }
            } else {
                ix.tree.remove(id);
                ix.unexplored.remove(id);
            }
        }
        for id in std::mem::take(&mut ix.dirty) {
            if ix.tree.contains(id) {
                let u = self.utility(id, pool.probes.expected_duration(id));
                ix.tree.insert(id, u);
            } else if id < ix.unexplored.capacity()
                && ix.unexplored.contains(id)
                && self.explored.contains_key(&id)
            {
                // first feedback arrived while eligible: promote from
                // the exploration pool into the utility tree
                ix.unexplored.remove(id);
                let u = self.utility(id, pool.probes.expected_duration(id));
                ix.tree.insert(id, u);
            }
        }
        if ix.rekey_all {
            // pacer moved T: every explored score is stale — re-key the
            // (bounded-by-participants-ever) tree, not the population
            ix.rekey_all = false;
            let members: Vec<usize> = ix.tree.to_sorted_vec().iter().map(|e| e.0).collect();
            for id in members {
                let u = self.utility(id, pool.probes.expected_duration(id));
                ix.tree.insert(id, u);
            }
        }
        if ix.tree.len() + ix.unexplored.len() != pool.set.len() {
            // desync safety net: a selector driven against a pool whose
            // deltas never reached the hooks (reuse across pools) must
            // rebuild rather than serve a stale partition
            self.index = Some(self.rebuilt_index(pool));
            return;
        }
        self.index = Some(ix);
    }
}

impl Selector for OortSelector {
    fn name(&self) -> &'static str {
        "oort"
    }

    fn select(&mut self, ctx: &mut SelectionCtx) -> Vec<usize> {
        let k = ctx.target.min(ctx.candidates.len());
        let mut picked = Vec::with_capacity(k);

        let (explored, unexplored): (Vec<&super::Candidate>, Vec<&super::Candidate>) = ctx
            .candidates
            .iter()
            .partition(|c| self.explored.contains_key(&c.id));

        // exploration: epsilon share from never-explored learners (random)
        let n_explore = ((k as f64) * self.epsilon).round() as usize;
        let n_explore = n_explore.min(unexplored.len());
        for i in ctx.rng.choose_k(unexplored.len(), n_explore) {
            picked.push(unexplored[i].id);
        }

        // exploitation: top utility among explored (total_cmp: a non-finite
        // utility ranks deterministically instead of panicking the sort)
        let n_exploit = k - picked.len();
        let mut ranked: Vec<(f64, usize)> = explored
            .iter()
            .map(|c| (self.utility(c.id, c.expected_duration), c.id))
            .collect();
        ranked.sort_by(|a, b| b.0.total_cmp(&a.0));
        for (u, id) in ranked.into_iter().take(n_exploit) {
            self.window_util += u;
            picked.push(id);
        }

        // backfill from unexplored if explored pool was too small
        if picked.len() < k {
            let already: std::collections::HashSet<usize> = picked.iter().copied().collect();
            for c in unexplored {
                if picked.len() >= k {
                    break;
                }
                if !already.contains(&c.id) {
                    picked.push(c.id);
                }
            }
        }

        self.epsilon = (self.epsilon * self.cfg.epsilon_decay).max(self.cfg.epsilon_min);
        picked
    }

    /// Indexed fast path: epsilon share sampled from the unexplored set
    /// (bit-compatible with `choose_k` over the ascending unexplored list),
    /// exploitation streamed from the utility tree (score-descending,
    /// id-ascending ties — a stable descending sort's exact order), backfill
    /// from the unexplored set. O(k log n) per selection; same RNG draws and
    /// state updates as [`OortSelector::select`].
    fn select_from(
        &mut self,
        pool: &SelectPool,
        _round: usize,
        _now: f64,
        target: usize,
        rng: &mut Rng,
    ) -> Option<Vec<usize>> {
        self.sync_index(pool);
        let n = pool.set.len();
        if n == 0 {
            // the engines skip select() entirely on an empty pool: no
            // epsilon decay, no RNG draws
            return Some(Vec::new());
        }
        let ix = self.index.take().expect("sync_index always builds");
        debug_assert_eq!(
            ix.tree.len() + ix.unexplored.len(),
            n,
            "oort index out of sync with pool"
        );
        let k = target.min(n);
        let mut picked = Vec::with_capacity(k);

        let n_explore = ((k as f64) * self.epsilon).round() as usize;
        let n_explore = n_explore.min(ix.unexplored.len());
        picked.extend(ix.unexplored.sample_k(rng, n_explore));

        let n_exploit = k - picked.len();
        // single-pass per-shard level walks + exact K-way merge: same
        // (utility desc, id asc) stream as `top_k_desc`, element for
        // element, without re-scanning every shard per score level
        ix.tree.top_k_desc_merged(n_exploit, |id, u| {
            self.window_util += u;
            picked.push(id);
        });

        if picked.len() < k {
            let already: std::collections::HashSet<usize> = picked.iter().copied().collect();
            for id in ix.unexplored.iter() {
                if picked.len() >= k {
                    break;
                }
                if !already.contains(&id) {
                    picked.push(id);
                }
            }
        }

        self.epsilon = (self.epsilon * self.cfg.epsilon_decay).max(self.cfg.epsilon_min);
        self.index = Some(ix);
        Some(picked)
    }

    fn on_eligible(&mut self, id: usize) {
        if let Some(ix) = self.index.as_mut() {
            ix.pending.push((id, true));
        }
    }

    fn on_ineligible(&mut self, id: usize) {
        if let Some(ix) = self.index.as_mut() {
            ix.pending.push((id, false));
        }
    }

    fn feedback(&mut self, fb: &RoundFeedback) {
        for &(id, stat_util, duration) in fb.completed {
            let e = self.explored.entry(id).or_default();
            e.stat_util = stat_util;
            e.duration = duration;
            e.last_round = fb.round;
            if let Some(ix) = self.index.as_mut() {
                ix.dirty.push(id);
            }
        }
        // learners that missed the deadline get their utility dampened
        for id in fb.missed {
            if let Some(e) = self.explored.get_mut(id) {
                e.stat_util *= 0.5;
                if let Some(ix) = self.index.as_mut() {
                    ix.dirty.push(*id);
                }
            }
        }
        // pacer: if exploited utility in this window dropped vs the
        // previous one, allow longer rounds to reach new learners.
        self.rounds_in_window += 1;
        if self.rounds_in_window >= self.cfg.pacer_window {
            if self.window_util < 0.95 * self.prev_window_util {
                self.preferred_duration += self.cfg.pacer_step;
                // every indexed utility embeds T: re-key lazily at the
                // next selection instead of eagerly per pacer move
                if let Some(ix) = self.index.as_mut() {
                    ix.rekey_all = true;
                }
            }
            self.prev_window_util = self.window_util;
            self.window_util = 0.0;
            self.rounds_in_window = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::selection::{Candidate, MockProbes, SelectPool};
    use crate::util::rng::Rng;

    fn candidates(n: usize) -> Vec<Candidate> {
        (0..n)
            .map(|i| Candidate {
                id: i,
                avail_prob: 1.0,
                // learner i is slower with larger i
                expected_duration: 10.0 + 5.0 * i as f64,
            })
            .collect()
    }

    fn run_round(s: &mut OortSelector, cands: &[Candidate], round: usize, seed: u64) -> Vec<usize> {
        let mut rng = Rng::new(seed);
        let mut ctx = SelectionCtx {
            round,
            now: 0.0,
            target: 5,
            candidates: cands,
            rng: &mut rng,
        };
        s.select(&mut ctx)
    }

    #[test]
    fn explores_initially_exploits_later() {
        let cands = candidates(40);
        // low exploration so the exploitation behaviour is visible quickly
        let mut s = OortSelector::new(OortConfig { epsilon0: 0.2, ..OortConfig::default() });
        // round 0: nothing explored -> all picks are exploration/backfill
        let picked0 = run_round(&mut s, &cands, 0, 1);
        assert_eq!(picked0.len(), 5);

        // feed back high utility for fast learners 0..5, low for others
        for r in 0..50 {
            let completed: Vec<(usize, f64, f64)> = (0..10)
                .map(|id| {
                    let util = if id < 5 { 100.0 } else { 1.0 };
                    (id, util, 10.0 + 5.0 * id as f64)
                })
                .collect();
            s.feedback(&RoundFeedback {
                round: r,
                completed: &completed,
                missed: &[],
                round_duration: 60.0,
            });
        }
        // epsilon has decayed; exploitation should prefer ids 0..5
        let mut hits = 0;
        for r in 100..120 {
            for id in run_round(&mut s, &cands, r, r as u64) {
                if id < 5 {
                    hits += 1;
                }
            }
        }
        assert!(hits > 50, "oort should exploit high-utility fast learners, hits={hits}");
    }

    #[test]
    fn exploitation_ranks_strictly_by_utility() {
        // epsilon pinned to 0 => pure exploitation: the pick must be the
        // top-`target` explored learners ordered by descending utility
        let mut s = OortSelector::new(OortConfig {
            epsilon0: 0.0,
            epsilon_min: 0.0,
            ..OortConfig::default()
        });
        let cands: Vec<Candidate> = (0..8)
            .map(|i| Candidate { id: i, avail_prob: 1.0, expected_duration: 10.0 })
            .collect();
        // all durations are below the preferred duration, so ranking is by
        // statistical utility alone
        s.feedback(&RoundFeedback {
            round: 0,
            completed: &[
                (3, 50.0, 10.0),
                (1, 40.0, 10.0),
                (6, 30.0, 10.0),
                (0, 20.0, 10.0),
                (4, 10.0, 10.0),
                (7, 5.0, 10.0),
            ],
            missed: &[],
            round_duration: 60.0,
        });
        let picked = run_round(&mut s, &cands, 1, 42);
        assert_eq!(picked, vec![3, 1, 6, 0, 4]);
    }

    #[test]
    fn system_utility_penalizes_slow_learners() {
        let mut s = OortSelector::default();
        s.explored.insert(1, LearnerStats { stat_util: 10.0, duration: 30.0, last_round: 0 });
        s.explored.insert(2, LearnerStats { stat_util: 10.0, duration: 240.0, last_round: 0 });
        let fast = s.utility(1, 30.0);
        let slow = s.utility(2, 240.0);
        assert!(fast > 3.0 * slow, "fast {fast} vs slow {slow}");
    }

    #[test]
    fn pacer_relaxes_preferred_duration_on_utility_drop() {
        let mut s = OortSelector::new(OortConfig {
            pacer_window: 2,
            ..OortConfig::default()
        });
        let t0 = s.current_preferred_duration();
        // window 1: high exploited utility
        s.window_util = 100.0;
        for r in 0..2 {
            s.feedback(&RoundFeedback {
                round: r,
                completed: &[],
                missed: &[],
                round_duration: 60.0,
            });
        }
        // window 2: low utility -> pacer must step T up
        s.window_util = 10.0;
        for r in 2..4 {
            s.feedback(&RoundFeedback {
                round: r,
                completed: &[],
                missed: &[],
                round_duration: 60.0,
            });
        }
        assert!(s.current_preferred_duration() > t0);
    }

    #[test]
    fn epsilon_decays_to_floor() {
        let cands = candidates(10);
        let mut s = OortSelector::default();
        for r in 0..500 {
            run_round(&mut s, &cands, r, r as u64);
        }
        assert!((s.epsilon - s.cfg.epsilon_min).abs() < 1e-9);
    }

    #[test]
    fn per_arrival_feedback_updates_exploration_state() {
        // async-regime hooks: each arrival registers the learner as
        // explored with its observed utility; each departure dampens it
        let mut s = OortSelector::default();
        s.on_arrival(0, (3, 12.0, 20.0), 60.0);
        assert!((s.explored[&3].stat_util - 12.0).abs() < 1e-12);
        assert!((s.explored[&3].duration - 20.0).abs() < 1e-12);
        s.on_departure(1, 3, 60.0);
        assert!((s.explored[&3].stat_util - 6.0).abs() < 1e-12);
    }

    #[test]
    fn missed_deadline_dampens_utility() {
        let mut s = OortSelector::default();
        s.explored.insert(7, LearnerStats { stat_util: 8.0, duration: 10.0, last_round: 0 });
        s.feedback(&RoundFeedback {
            round: 1,
            completed: &[],
            missed: &[7],
            round_duration: 60.0,
        });
        assert!((s.explored[&7].stat_util - 4.0).abs() < 1e-12);
    }

    #[test]
    fn non_finite_utility_feedback_does_not_panic() {
        // regression: the seed's partial_cmp().unwrap() exploitation sort
        // panicked if a NaN utility ever leaked in via feedback
        let cands = candidates(8);
        let mut s = OortSelector::new(OortConfig {
            epsilon0: 0.0,
            epsilon_min: 0.0,
            ..OortConfig::default()
        });
        s.feedback(&RoundFeedback {
            round: 0,
            completed: &[(1, f64::NAN, 10.0), (2, 5.0, 10.0), (3, 1.0, 10.0)],
            missed: &[],
            round_duration: 60.0,
        });
        let picked = run_round(&mut s, &cands, 1, 9);
        assert_eq!(picked.len(), 5, "NaN utility must degrade ranking, not panic");
        // total_cmp ranks (positive) NaN greatest: the poisoned learner
        // leads, the finite ones keep their relative order behind it
        assert_eq!(&picked[..3], &[1, 2, 3]);
    }

    /// The fast-path contract under ongoing feedback, pacer re-keys, and
    /// eligibility churn: identical picks AND identical RNG consumption vs
    /// the materialized select at every step.
    #[test]
    fn indexed_path_bit_identical_to_select_under_churn() {
        let n = 30usize;
        let all = candidates(n);
        let probes = MockProbes::from_candidates(&all);
        let mut fast_sel = OortSelector::new(OortConfig {
            pacer_window: 3,
            ..OortConfig::default()
        });
        let mut slow_sel = OortSelector::new(OortConfig {
            pacer_window: 3,
            ..OortConfig::default()
        });
        let mut set = crate::population::CandidateSet::new(n);
        let mut eligible = vec![true; n];
        for id in 0..n {
            set.insert(id);
        }
        let mut churn = Rng::new(0xC0FFEE);
        for round in 0..40 {
            // random eligibility churn, mirrored into the fast selector
            for _ in 0..churn.range(0, 6) {
                let id = churn.below(n);
                if eligible[id] {
                    eligible[id] = false;
                    set.remove(id);
                    fast_sel.on_ineligible(id);
                } else {
                    eligible[id] = true;
                    set.insert(id);
                    fast_sel.on_eligible(id);
                }
            }
            let cands: Vec<Candidate> =
                all.iter().filter(|c| eligible[c.id]).cloned().collect();
            let target = churn.range(1, 8);
            let seed = churn.next_u64();
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let pool = SelectPool { set: &set, probes: &probes, mu: 60.0 };
            let fast = fast_sel.select_from(&pool, round, 0.0, target, &mut r1).unwrap();
            let slow = if cands.is_empty() {
                Vec::new()
            } else {
                let mut ctx = SelectionCtx {
                    round,
                    now: 0.0,
                    target,
                    candidates: &cands,
                    rng: &mut r2,
                };
                slow_sel.select(&mut ctx)
            };
            assert_eq!(fast, slow, "round {round}");
            assert_eq!(r1.next_u64(), r2.next_u64(), "round {round}: rng diverged");
            // identical feedback to both (drives dirty re-scores + pacer)
            let completed: Vec<(usize, f64, f64)> = fast
                .iter()
                .take(3)
                .map(|&id| (id, churn.uniform(1.0, 50.0), 10.0 + 5.0 * id as f64))
                .collect();
            let missed: Vec<usize> = fast.iter().skip(3).take(1).copied().collect();
            let fb = RoundFeedback {
                round,
                completed: &completed,
                missed: &missed,
                round_duration: 60.0,
            };
            fast_sel.feedback(&fb);
            slow_sel.feedback(&fb);
        }
    }
}
